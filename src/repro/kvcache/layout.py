"""Iris-planned KV-cache stream layouts: per-page bundles and tables.

The KV-cache is the first *mutable* Iris-planned stream in the repo.
The growth model is paged: a slot's cache is a sequence of fixed
``page_tokens``-sized token pages, each packed with the same per-page
layout.  The layout problem depends only on
``(page_tokens, n_kv_heads, head_dim, bits, m)`` — never on sequence
length — so the scheduling instance is planned once, appends never
re-plan, and every layer / slot / page rebinds the one cached layout
exactly like the uniform weight stacks in :func:`repro.api.plan_layer_stack`
(which is the planning entry this module routes through).

Three table families are derived from the lowered
:class:`~repro.core.exec_plan.ExecProgram` and memoized on its
``jit_cache`` (shared across :class:`~repro.core.iris.LayoutCache`
rebinds):

* :func:`append_tables` — the write path.  Inverts
  :func:`~repro.core.exec_plan.pack_kernel_tables` per *token*: each
  destination u32 word knows its <= K contributing pieces, their shift
  codes, the precomputed bit mask each contribution covers, and which
  in-page token owns it.  Appending token ``t`` is then a masked
  read-modify-write ``new = (old & ~mask_t) | value_t`` over the page
  words — the ``pack_layout_fused`` gather/shift/OR structure, restricted
  to one token's bits.
* :func:`page_stream_tables` — per-page global bit offsets of every
  K/V code and scale (the :class:`~repro.core.exec_plan.StreamTables`
  convention: word index ``tab >> 5``, shift ``tab & 31``).
* :func:`full_stream_tables` — the per-page tables broadcast across
  ``n_pages`` by adding each page's bit stride, giving the attention
  prologue one flat (smax, ...) table over a slot's concatenated pages.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.exec_plan import ExecProgram, pack_kernel_tables
from repro.core.packing import BundleTensor

#: bundle array order (index into the lowered program's arrays)
KV_ARRAYS = ("kv/k", "kv/k_scales", "kv/v", "kv/v_scales")


def kv_bundle(cfg, bits: int, page_tokens: int) -> list[BundleTensor]:
    """The Iris bundle for one KV-cache token page.

    ``cfg`` is any object with ``n_kv_heads`` / ``head_dim``.  Codes are
    quantized per head-vector (one bf16 scale per (token, head) — the
    group always divides, so non-power-of-two head dims and any
    ``2 <= bits <= 8`` pack).  K feeds the score matmul before V feeds
    the output matmul, hence the two dataflow stages.
    """
    if not 2 <= bits <= 8:
        raise ValueError(f"kv bits must be in [2, 8], got {bits}")
    if page_tokens <= 0:
        raise ValueError(f"page_tokens must be positive, got {page_tokens}")
    hkv, hd = int(cfg.n_kv_heads), int(cfg.head_dim)
    n_codes = page_tokens * hkv * hd
    n_scales = page_tokens * hkv
    return [
        BundleTensor("kv/k", bits, n_codes, 0),
        BundleTensor("kv/k_scales", 16, n_scales, 0),
        BundleTensor("kv/v", bits, n_codes, 1),
        BundleTensor("kv/v_scales", 16, n_scales, 1),
    ]


def plan_kv_stack(cfg, *, bits: int, page_tokens: int,
                  n_layers: int | None = None, m: int = 512,
                  mode: str = "auto", cache=None):
    """Plan the per-page KV layout for every layer of a model.

    Routed through :func:`repro.api.plan_layer_stack` with the KV bundle
    substituted for the weight bundle, so the per-head layouts share the
    process-wide :class:`~repro.core.iris.LayoutCache`: one scheduler run
    (zero on a warm cache) plus ``n_layers - 1`` rebinds, with the
    ``scheduler_runs`` / ``cache_hits`` accounting callers assert on to
    prove appends never re-plan.
    """
    from repro.api import DEFAULT_CACHE, plan_layer_stack  # lazy

    if cache is None:
        cache = DEFAULT_CACHE
    return plan_layer_stack(
        cfg, None, m=m, n_layers=n_layers, mode=mode, cache=cache,
        bundle=kv_bundle(cfg, bits, page_tokens))


# ----------------------------------------------------------------------
# write-path tables
# ----------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class AppendTables:
    """Per-word contribution tables for the token-masked page pack.

    All tables are ``(c_max, words32, K)``; ``src`` indexes a flat
    piece-order vector with a zero sentinel at index 0 (piece ``p``
    stored as ``p + 1``), ``scode >= 0`` shifts left / ``< 0`` right
    (:func:`~repro.core.exec_plan.pack_kernel_tables` conventions),
    ``tok`` is the in-page token owning the contribution (-1 = empty or
    residual padding piece, never written), and ``maskbits`` is the
    precomputed u32 bit mask the shifted contribution covers.
    """

    K: int
    src: np.ndarray          # int32
    scode: np.ndarray        # int32
    tok: np.ndarray          # int32
    maskbits: np.ndarray     # uint32
    piece_base: tuple[int, ...]
    per_token: tuple[int, ...]   # pieces per token, per array
    logical: tuple[int, ...]     # logical pieces per array (pre-padding)


def append_tables(prog: ExecProgram, *, page_tokens: int,
                  logical: tuple[int, ...]) -> AppendTables:
    """Derive (and memoize) the append pack tables for one page layout.

    ``logical`` gives each array's *bundle* element count — the planner
    pads depths up with residual fill, so token ownership must be
    computed against the pre-padding counts (padding pieces get token -1
    and are never written; their bits stay zero for the page's life).
    """
    key = ("kv_append", page_tokens, tuple(logical))
    cached = prog.jit_cache.get(key)
    if cached is not None:
        return cached
    n_arr = len(prog.piece_depths)
    if len(logical) != n_arr:
        raise ValueError(
            f"logical has {len(logical)} entries for {n_arr} arrays")
    for i, n in enumerate(logical):
        if n % page_tokens:
            raise ValueError(
                f"array {i}: {n} elements not divisible by "
                f"page_tokens={page_tokens}")
        if n > prog.piece_depths[i]:
            raise ValueError(
                f"array {i}: {n} logical elements exceed the program's "
                f"{prog.piece_depths[i]} pieces")
    src_t, sc_t, k = pack_kernel_tables(prog)
    w32 = prog.kernel.words32
    src = src_t.reshape(prog.c_max, w32, k).astype(np.int32)
    scode = sc_t.reshape(prog.c_max, w32, k).astype(np.int32)

    base = np.asarray(prog.piece_base, dtype=np.int64)
    per_token = tuple(n // page_tokens for n in logical)
    piece = src.astype(np.int64) - 1                       # -1 = empty
    arr_of = np.clip(np.searchsorted(base[1:], piece, side="right"),
                     0, n_arr - 1)
    local = piece - base[arr_of]
    widths = np.asarray(prog.elem_widths, dtype=np.int64)[arr_of]
    ones = (np.uint64(1) << widths.astype(np.uint64)) - np.uint64(1)
    sc64 = scode.astype(np.int64)
    shifted = np.where(sc64 >= 0,
                       ones << np.maximum(sc64, 0).astype(np.uint64),
                       ones >> np.maximum(-sc64, 0).astype(np.uint64))
    maskbits = np.where(src > 0, shifted & np.uint64(0xFFFFFFFF),
                        np.uint64(0)).astype(np.uint32)

    pt = np.asarray(per_token, dtype=np.int64)[arr_of]
    in_range = (src > 0) & (local < np.asarray(logical)[arr_of])
    tok = np.where(in_range & (pt > 0), local // np.maximum(pt, 1), -1)
    # a residual-padding contribution is never written: mask it out too
    maskbits = np.where(tok >= 0, maskbits, np.uint32(0))
    tables = AppendTables(
        K=k, src=src, scode=scode, tok=tok.astype(np.int32),
        maskbits=maskbits,
        piece_base=tuple(int(b) for b in base),
        per_token=per_token,
        logical=tuple(int(x) for x in logical),
    )
    prog.jit_cache[key] = tables
    return tables


# ----------------------------------------------------------------------
# read-path tables
# ----------------------------------------------------------------------
def page_bit_stride(prog: ExecProgram) -> int:
    """Bits one packed page occupies in the flattened u32 word view."""
    return prog.c_max * prog.kernel.words32 * 32


def page_stream_tables(prog: ExecProgram, *, page_tokens: int,
                       n_kv_heads: int, head_dim: int
                       ) -> dict[str, np.ndarray]:
    """Per-page bit-offset tables of every logical KV element.

    ``k`` / ``v``: ``(page_tokens, n_kv_heads, head_dim)`` uint32;
    ``k_scales`` / ``v_scales``: ``(page_tokens, n_kv_heads)`` uint32.
    """
    key = ("kv_page_tabs", page_tokens, n_kv_heads, head_dim)
    cached = prog.jit_cache.get(key)
    if cached is not None:
        return cached
    n_codes = page_tokens * n_kv_heads * head_dim
    n_scales = page_tokens * n_kv_heads
    tabs = {
        "k": prog.stream_bit_offsets(0)[:n_codes].reshape(
            page_tokens, n_kv_heads, head_dim),
        "k_scales": prog.stream_bit_offsets(1)[:n_scales].reshape(
            page_tokens, n_kv_heads),
        "v": prog.stream_bit_offsets(2)[:n_codes].reshape(
            page_tokens, n_kv_heads, head_dim),
        "v_scales": prog.stream_bit_offsets(3)[:n_scales].reshape(
            page_tokens, n_kv_heads),
    }
    prog.jit_cache[key] = tabs
    return tabs


def full_stream_tables(prog: ExecProgram, *, page_tokens: int,
                       n_kv_heads: int, head_dim: int, n_pages: int
                       ) -> dict[str, np.ndarray]:
    """Page tables broadcast over ``n_pages`` along the token axis.

    Token ``s`` of a slot lives in page ``s // page_tokens`` at in-page
    index ``s % page_tokens``; its global bit offset is the per-page
    offset plus the page's bit stride.  Validated against the uint32
    addressing range of the stream tables.
    """
    key = ("kv_full_tabs", page_tokens, n_kv_heads, head_dim, n_pages)
    cached = prog.jit_cache.get(key)
    if cached is not None:
        return cached
    page = page_stream_tables(prog, page_tokens=page_tokens,
                              n_kv_heads=n_kv_heads, head_dim=head_dim)
    stride = page_bit_stride(prog)
    if n_pages * stride > (1 << 32):
        raise ValueError(
            f"{n_pages} pages x {stride} bits exceed the 2^32-bit "
            "addressing range of the uint32 stream tables")
    offs = (np.arange(n_pages, dtype=np.int64) * stride)
    full = {}
    for name, tab in page.items():
        t = tab.astype(np.int64)[None] + offs.reshape(
            (n_pages,) + (1,) * tab.ndim)
        full[name] = t.reshape((n_pages * page_tokens,) + tab.shape[1:]) \
            .astype(np.uint32)
    prog.jit_cache[key] = full
    return full
