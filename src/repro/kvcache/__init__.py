"""repro.kvcache: Iris-planned packed KV-cache streams.

The paper's layout machinery applied to the first *mutable* stream in
the repo: per-head quantized K/V token pages planned once per
``(page_tokens, heads, head_dim, bits, m)`` signature (sequence-length
independent — appends never re-plan), packed through token-masked
write tables derived from the device pack kernel, and decoded inside
the attention prologue by a stream-direct Pallas kernel.

Front doors:

* :class:`PackedKVCache` — the paged pytree container
  (``create`` / ``append`` / ``reset`` / ``evict`` / ``dense_kv``);
* :func:`kv_bundle` / :func:`plan_kv_stack` — bundle construction and
  planning, routed through :func:`repro.api.plan_layer_stack`;
* :func:`~repro.kvcache.kernels.stream_attention` — the fused decode
  attention kernel over packed pages.
"""
from .cache import (  # noqa: F401
    KVManifest,
    PackedKVCache,
    dequantize_kv,
    quantize_kv,
)
from .kernels import stream_attention, stream_attention_cache  # noqa: F401
from .layout import (  # noqa: F401
    append_tables,
    full_stream_tables,
    kv_bundle,
    page_stream_tables,
    plan_kv_stack,
)

__all__ = [
    "KVManifest",
    "PackedKVCache",
    "append_tables",
    "dequantize_kv",
    "full_stream_tables",
    "kv_bundle",
    "page_stream_tables",
    "plan_kv_stack",
    "quantize_kv",
    "stream_attention",
    "stream_attention_cache",
]
