"""PackedKVCache: a mutable Iris-planned KV stream as a jax pytree.

Storage is a single uint32 word tensor
``pages[n_layers, n_slots, n_pages, c_max, words32]`` — each
``(c_max, words32)`` block is one token page packed with the per-page
layout planned by :mod:`repro.kvcache.layout` (the
:meth:`~repro.core.exec_plan.ExecProgram.buffer_words32` view, so the
attention prologue and the host analysis passes read the same bytes).

The container mirrors :class:`repro.tree.PackedTree`: the words are the
only pytree child (``jit`` / ``device_put`` / ``NamedSharding``
compatible), the frozen :class:`KVManifest` rides as aux data, and the
layout / program / tables are rebuilt lazily after unflatten via the
process :class:`~repro.core.iris.LayoutCache` — a cache hit, never a
scheduler run.

``append`` is the new write path: token codes are placed into a sparse
piece vector and OR-merged into the slot's current page through the
token-masked contribution tables of :func:`repro.kvcache.layout.append_tables`
(``new = (old & ~mask) | value``), i.e. the ``pack_layout_fused``
gather/shift/OR structure restricted to one token's bits.  Appends are
pure functional updates (the engine threads the new cache through decode
state) and never touch the planner.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exec_plan import lower_exec
from repro.core.iris import DEFAULT_CACHE, schedule
from repro.core.packing import BundleTensor, bundle_problem

from .layout import append_tables, full_stream_tables, plan_kv_stack

__all__ = ["KVManifest", "PackedKVCache", "quantize_kv", "dequantize_kv"]


# ----------------------------------------------------------------------
# quantization (per head-vector: one bf16 scale per (token, head))
# ----------------------------------------------------------------------
def quantize_kv(x: jax.Array, bits: int) -> tuple[jax.Array, jax.Array]:
    """``x: (..., head_dim)`` float -> (codes uint32, scale16 uint32).

    Mirrors :func:`repro.quant.qtypes.quantize` arithmetic with the
    group fixed to the head vector: symmetric, biased codes, amax/qmax
    scale computed in f32, stored as a bf16 bit pattern.
    """
    qmax = float(2 ** (bits - 1) - 1)
    bias = float(2 ** (bits - 1))
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -qmax, qmax)
    codes = (q + bias).astype(jnp.uint32)
    sc16 = jax.lax.bitcast_convert_type(
        scale.astype(jnp.bfloat16), jnp.uint16).astype(jnp.uint32)
    return codes, sc16


def dequantize_kv(codes: jax.Array, sc16: jax.Array, bits: int
                  ) -> jax.Array:
    """Inverse of :func:`quantize_kv` against the *stored* bf16 scale."""
    bias = float(2 ** (bits - 1))
    scale = jax.lax.bitcast_convert_type(
        (sc16.astype(jnp.uint32) << 16), jnp.float32)
    return (codes.astype(jnp.float32) - bias) * scale[..., None]


def _extract_words(words: jax.Array, tab: np.ndarray, width: int
                   ) -> jax.Array:
    """Funnel-shift gather: ``words (B, W) uint32`` + bit-offset table.

    The :mod:`repro.kernels.stream_matmul` extraction, batched over
    leading rows: word index ``tab >> 5``, shift ``tab & 31``, hi word
    completes pieces straddling a u32 boundary.
    """
    w_last = words.shape[1] - 1
    wi = (tab >> 5).astype(np.int32).reshape(-1)
    sh = jnp.asarray((tab & 31).astype(np.uint32).reshape(-1))
    lo = jnp.take(words, wi, axis=1)
    hi = jnp.take(words, np.minimum(wi + 1, w_last), axis=1)
    v = (lo >> sh) | jnp.where(sh > 0, hi << ((32 - sh) & 31),
                               jnp.uint32(0))
    v = v & jnp.uint32((1 << width) - 1)
    return v.reshape((words.shape[0],) + tab.shape)


# ----------------------------------------------------------------------
# manifest
# ----------------------------------------------------------------------
def signature_string(problem) -> str:
    """JSON-canonical form of ``problem.canonical_signature()`` — the
    raw signature is a nested tuple, which a JSON round-trip (checkpoint
    extras) would silently turn into lists and break equality."""
    return json.dumps(problem.canonical_signature())


@dataclasses.dataclass(frozen=True)
class KVManifest:
    """Frozen description of a packed KV cache: geometry + layout identity.

    Enough to rebuild the layout (via the process
    :class:`~repro.core.iris.LayoutCache`, or a fresh scheduler run whose
    signature is verified against the recorded one) and to interpret the
    page words — the KV twin of :class:`repro.tree.LayoutManifest`.
    """

    bits: int
    page_tokens: int
    n_kv_heads: int
    head_dim: int
    n_layers: int
    n_slots: int
    n_pages: int
    m: int
    mode: str
    c_max: int
    row_bytes: int
    words32: int
    bundle: tuple[tuple[str, int, int, int], ...]
    signature: str

    @property
    def smax(self) -> int:
        return self.n_pages * self.page_tokens

    def bundle_tensors(self) -> list[BundleTensor]:
        return [BundleTensor(*t) for t in self.bundle]

    def elem_widths(self) -> tuple[int, ...]:
        return tuple(t[1] for t in self.bundle)

    def logical(self) -> tuple[int, ...]:
        return tuple(t[2] for t in self.bundle)

    def problem(self):
        return bundle_problem(self.bundle_tensors(), m=self.m)

    def resolve_layout(self, cache=DEFAULT_CACHE):
        """(layout, provenance) — cache hit or verified scheduler rerun."""
        prob = self.problem()
        sig = signature_string(prob)
        if sig != self.signature:
            raise ValueError(
                "KV manifest signature mismatch: recorded "
                f"{self.signature[:12]}..., rebuilt {sig[:12]}... — the "
                "manifest does not describe this scheduling instance")
        if cache is not None:
            lay = cache.lookup(prob)
            if lay is not None:
                return lay, "cache-hit"
        lay = schedule(prob, mode=self.mode, cache=None)
        if cache is not None:
            cache.insert(prob, False, lay)
        return lay, "manifest"

    def to_json_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["bundle"] = [list(t) for t in self.bundle]
        return d

    @classmethod
    def from_json_dict(cls, d: dict) -> "KVManifest":
        d = dict(d)
        d["bundle"] = tuple(
            (str(n), int(w), int(e), int(s)) for n, w, e, s in d["bundle"])
        for k in ("bits", "page_tokens", "n_kv_heads", "head_dim",
                  "n_layers", "n_slots", "n_pages", "m", "c_max",
                  "row_bytes", "words32"):
            d[k] = int(d[k])
        return cls(**d)


# ----------------------------------------------------------------------
# the cache container
# ----------------------------------------------------------------------
@jax.tree_util.register_pytree_with_keys_class
class PackedKVCache:
    """Paged Iris-packed KV cache for ``n_slots`` continuous-batching rows.

    Functional container: ``append`` / ``reset`` / ``evict`` return new
    caches sharing the manifest.  Only ``pages`` is a pytree leaf.
    """

    def __init__(self, pages, manifest: KVManifest,
                 provenance: str = "created") -> None:
        self.pages = pages
        self.manifest = manifest
        self.provenance = provenance
        self._layout = None
        self._program = None
        self.plan_stats: dict[str, int] = {}

    # -- pytree protocol ------------------------------------------------
    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("pages"), self.pages),), \
            self.manifest

    @classmethod
    def tree_unflatten(cls, manifest, children):
        return cls(children[0], manifest, provenance="pytree")

    # -- construction ---------------------------------------------------
    @classmethod
    def create(cls, cfg, *, bits: int, page_tokens: int, n_slots: int,
               max_seq: int, n_layers: int | None = None, m: int = 512,
               mode: str = "auto", cache=None) -> "PackedKVCache":
        """Plan (through the shared layer-stack planner) and allocate.

        The per-page layout signature is sequence-length-independent:
        growing ``max_seq`` only adds zeroed pages, and a second
        ``create`` against a warm :class:`LayoutCache` runs the
        scheduler zero times (``plan_stats`` records the counters).
        """
        stack = plan_kv_stack(cfg, bits=bits, page_tokens=page_tokens,
                              n_layers=n_layers, m=m, mode=mode,
                              cache=cache)
        prog = stack.exec_program()
        nl = len(stack.plans)
        n_pages = max(1, math.ceil(max_seq / page_tokens))
        manifest = KVManifest(
            bits=bits, page_tokens=page_tokens,
            n_kv_heads=int(cfg.n_kv_heads), head_dim=int(cfg.head_dim),
            n_layers=nl, n_slots=int(n_slots), n_pages=int(n_pages),
            m=int(m), mode=str(mode), c_max=int(prog.c_max),
            row_bytes=int(prog.row_bytes),
            words32=int(prog.kernel.words32),
            bundle=tuple((b.name, b.width_bits, b.n_elems, b.stage)
                         for b in stack.bundle),
            signature=signature_string(stack.problem),
        )
        pages = jnp.zeros((nl, n_slots, n_pages, prog.c_max,
                           prog.kernel.words32), jnp.uint32)
        obj = cls(pages, manifest, provenance=stack.plans[0].provenance)
        obj._layout = stack.plans[0].layout
        obj._program = prog
        obj.plan_stats = {"scheduler_runs": stack.scheduler_runs,
                          "cache_hits": stack.cache_hits}
        return obj

    # -- lazy layout/program (rebuilt after unflatten / restore) --------
    @property
    def layout(self):
        if self._layout is None:
            self._layout, prov = self.manifest.resolve_layout()
            if self.provenance == "pytree":
                self.provenance = prov
        return self._layout

    def program(self):
        if self._program is None:
            self._program = lower_exec(self.layout,
                                       self.manifest.elem_widths())
        return self._program

    # -- geometry -------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return self.manifest.n_layers

    @property
    def n_slots(self) -> int:
        return self.manifest.n_slots

    @property
    def n_pages(self) -> int:
        return self.manifest.n_pages

    @property
    def smax(self) -> int:
        return self.manifest.smax

    @property
    def bits(self) -> int:
        return self.manifest.bits

    def stream_bytes(self) -> int:
        """Total packed page bytes resident for the whole cache."""
        return int(np.prod(self.pages.shape)) * 4

    def _replace_pages(self, pages) -> "PackedKVCache":
        obj = PackedKVCache(pages, self.manifest,
                            provenance=self.provenance)
        obj._layout = self._layout
        obj._program = self._program
        obj.plan_stats = self.plan_stats
        return obj

    # -- write path -----------------------------------------------------
    def append(self, k: jax.Array, v: jax.Array, pos: jax.Array,
               slot_ids: jax.Array, *, layer: int) -> "PackedKVCache":
        """Write one token per active slot into layer ``layer``.

        ``k`` / ``v``: ``(b, n_kv_heads, head_dim)`` float (post-rope);
        ``pos``: ``(b,)`` token positions being written; ``slot_ids``:
        ``(b,)`` distinct cache rows.  Jit-traceable (``layer`` static);
        the planner is never consulted — all tables are lowered-once
        numpy constants.
        """
        man = self.manifest
        prog = self.program()
        tabs = append_tables(prog, page_tokens=man.page_tokens,
                             logical=man.logical())
        kcodes, ks16 = quantize_kv(k, man.bits)
        vcodes, vs16 = quantize_kv(v, man.bits)
        b = kcodes.shape[0]
        t_in = (pos % man.page_tokens).astype(jnp.int32)
        page = (pos // man.page_tokens).astype(jnp.int32)

        base = tabs.piece_base
        per_tok = tabs.per_token
        n_flat = prog.n_pieces + 1

        def place(kc, ks, vc, vs, t):
            f = jnp.zeros((n_flat,), jnp.uint32)
            for ai, vals in zip(range(4), (kc, ks, vc, vs)):
                start = 1 + base[ai] + t * per_tok[ai]
                f = jax.lax.dynamic_update_slice(f, vals, (start,))
            return f

        flat = jax.vmap(place)(kcodes.reshape(b, -1), ks16.reshape(b, -1),
                               vcodes.reshape(b, -1), vs16.reshape(b, -1),
                               t_in)

        src = tabs.src.reshape(-1)                     # numpy constants
        vals = jnp.take(flat, src, axis=1).reshape(
            (b,) + tabs.src.shape)
        sl = jnp.asarray(np.maximum(tabs.scode, 0).astype(np.uint32))
        sr = jnp.asarray(np.maximum(-tabs.scode, 0).astype(np.uint32))
        left = jnp.asarray(tabs.scode >= 0)
        shifted = jnp.where(left, vals << sl, vals >> sr)
        sel = jnp.asarray(tabs.tok)[None] == t_in[:, None, None, None]
        contrib = jnp.where(sel, shifted, jnp.uint32(0))
        maskc = jnp.where(sel, jnp.asarray(tabs.maskbits)[None],
                          jnp.uint32(0))
        value = contrib[..., 0]
        mask = maskc[..., 0]
        for j in range(1, tabs.K):                     # K is tiny, static
            value = value | contrib[..., j]
            mask = mask | maskc[..., j]

        pages_l = self.pages[layer]
        old = pages_l[slot_ids, page]                  # (b, c_max, w32)
        new = (old & ~mask) | value
        pages_l = pages_l.at[slot_ids, page].set(new)
        return self._replace_pages(self.pages.at[layer].set(pages_l))

    # -- slot lifecycle -------------------------------------------------
    def reset(self, slot_ids) -> "PackedKVCache":
        """Zero the given slot(s) across every layer and page."""
        slots = jnp.atleast_1d(jnp.asarray(slot_ids, jnp.int32))
        return self._replace_pages(self.pages.at[:, slots].set(0))

    def evict(self, slot_ids) -> "PackedKVCache":
        """Continuous-batching eviction: alias of :meth:`reset`."""
        return self.reset(slot_ids)

    # -- read path ------------------------------------------------------
    def slot_words(self, layer: int, slot_ids=None) -> jax.Array:
        """Flat uint32 word stream per selected slot: ``(b, W)``."""
        pages_l = self.pages[layer]
        if slot_ids is not None:
            pages_l = pages_l[slot_ids]
        return pages_l.reshape(pages_l.shape[0], -1)

    def stream_tables(self) -> dict[str, np.ndarray]:
        """Full-sequence bit-offset tables over a slot's pages."""
        man = self.manifest
        return full_stream_tables(
            self.program(), page_tokens=man.page_tokens,
            n_kv_heads=man.n_kv_heads, head_dim=man.head_dim,
            n_pages=man.n_pages)

    def dense_kv(self, layer: int, slot_ids=None
                 ) -> tuple[jax.Array, jax.Array]:
        """Dequantized dense K/V for the oracle attention path.

        Returns f32 ``(b, smax, n_kv_heads, head_dim)`` pairs — the
        exact values the stream kernel's prologue dequantizes in
        registers, materialized (this is what ``stream_attention`` makes
        unnecessary; it exists as the bit-identity oracle).
        """
        man = self.manifest
        words = self.slot_words(layer, slot_ids)
        tabs = self.stream_tables()
        kc = _extract_words(words, tabs["k"], man.bits)
        ks = _extract_words(words, tabs["k_scales"], 16)
        vc = _extract_words(words, tabs["v"], man.bits)
        vs = _extract_words(words, tabs["v_scales"], 16)
        return (dequantize_kv(kc, ks, man.bits),
                dequantize_kv(vc, vs, man.bits))

    # -- host views -----------------------------------------------------
    def host_pages(self) -> np.ndarray:
        return np.asarray(self.pages)

    def page_rows_u8(self, layer: int, slot: int, page: int) -> np.ndarray:
        """One page as ``(c_max, row_bytes)`` uint8 rows (analysis view)."""
        man = self.manifest
        words = np.asarray(self.pages[layer, slot, page])
        return np.ascontiguousarray(words).view(np.uint8).reshape(
            man.c_max, man.words32 * 4)[:, :man.row_bytes]

    def verify(self, **kw) -> Any:
        from repro import analysis  # lazy

        return analysis.verify_kvcache(self, **kw)
