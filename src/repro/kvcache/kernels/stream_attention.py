"""Stream-direct decode attention: Iris KV pages -> registers -> dot.

The K/V prologue consults the exec-plan stream tables the way
``repro.kernels.stream_matmul`` does: each program instance (one batch
slot) funnel-shifts its codes and bf16 scale bit patterns straight out
of the slot's packed page words, dequantizes in registers, and feeds
the decode attention math — no dense K/V tensor ever exists in HBM.

The attention body reproduces
:func:`repro.models.attention.decode_attention` op for op (same einsum
contraction, ``preferred_element_type=f32``, position mask at
``NEG_INF``, f32 softmax and V contraction) so the kernel's output is
bit-identical to running the dense path on the materialized dequantized
K/V — the gate ``tests/test_kvcache.py`` asserts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.models.attention import NEG_INF


def _extract(flat: jax.Array, tab: jax.Array, width: int) -> jax.Array:
    """Funnel-shift ``width``-bit fields of ``flat`` u32 words (in-kernel)."""
    last = flat.shape[0] - 1
    wi = (tab >> 5).astype(jnp.int32)
    sh = (tab & 31).astype(jnp.uint32)
    lo = jnp.take(flat, wi)
    hi = jnp.take(flat, jnp.minimum(wi + 1, last))
    v = (lo >> sh) | jnp.where(sh > 0, hi << ((32 - sh) & 31),
                               jnp.uint32(0))
    return v & jnp.uint32((1 << width) - 1)


def _dequant(codes, sc16, bits):
    bias = float(2 ** (bits - 1))
    scale = jax.lax.bitcast_convert_type(sc16 << 16, jnp.float32)
    return (codes.astype(jnp.float32) - bias) * scale[..., None]


def _attention_kernel(words_ref, q_ref, pos_ref, kt_ref, kst_ref, vt_ref,
                      vst_ref, o_ref, *, bits, n_heads, smax):
    flat = words_ref[0]                              # (W,) uint32
    kf = _dequant(_extract(flat, kt_ref[...], bits),
                  _extract(flat, kst_ref[...], 16), bits)
    vf = _dequant(_extract(flat, vt_ref[...], bits),
                  _extract(flat, vst_ref[...], 16), bits)
    hkv = kf.shape[1]
    if hkv != n_heads:                               # GQA replication
        kf = jnp.repeat(kf, n_heads // hkv, axis=1)
        vf = jnp.repeat(vf, n_heads // hkv, axis=1)
    q = q_ref[...].reshape(1, 1, *q_ref.shape[1:])   # (1, 1, H, hd)
    hd = q.shape[-1]
    kc = kf[None].astype(q.dtype)                    # (1, smax, H, hd)
    vc = vf[None].astype(q.dtype)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, smax), 3)
    s = jnp.where(iota <= pos_ref[0, 0], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vc.astype(jnp.float32))
    o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "n_heads",
                                             "interpret"))
def stream_attention(words: jax.Array, q: jax.Array, pos: jax.Array,
                     k_tab: jax.Array, ks_tab: jax.Array,
                     v_tab: jax.Array, vs_tab: jax.Array, *,
                     bits: int, n_heads: int,
                     interpret: bool = True) -> jax.Array:
    """Decode attention over packed KV pages, one program per slot.

    ``words``: ``(B, W)`` uint32 — each row a slot's concatenated page
    words (:meth:`repro.kvcache.PackedKVCache.slot_words`);
    ``q``: ``(B, 1, H, hd)``; ``pos``: ``(B,)`` per-slot positions;
    tables: full-sequence bit offsets from
    :func:`repro.kvcache.layout.full_stream_tables` (``k``/``v``:
    ``(smax, Hkv, hd)``, scales: ``(smax, Hkv)``).  Returns
    ``(B, 1, H, hd)`` in ``q.dtype``.
    """
    b, _, h, hd = q.shape
    if h != n_heads:
        raise ValueError(f"q has {h} heads, n_heads={n_heads}")
    smax = k_tab.shape[0]
    w = words.shape[1]
    q3 = q.reshape(b, h, hd)
    pos2 = pos.reshape(b, 1).astype(jnp.int32)
    kernel = functools.partial(_attention_kernel, bits=bits,
                               n_heads=n_heads, smax=smax)

    def full(shape):
        return pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i: (i, 0)),
            pl.BlockSpec((1, h, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            full(k_tab.shape),
            full(ks_tab.shape),
            full(v_tab.shape),
            full(vs_tab.shape),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(words, q3, pos2, k_tab, ks_tab, v_tab, vs_tab)
    return out.reshape(b, 1, h, hd)


def stream_attention_cache(kvc, q: jax.Array, pos: jax.Array,
                           slot_ids: jax.Array, *, layer: int,
                           interpret: bool = True) -> jax.Array:
    """Convenience front door: gather a :class:`PackedKVCache` layer's
    active slots and run :func:`stream_attention` against its tables."""
    tabs = kvc.stream_tables()
    words = kvc.slot_words(layer, slot_ids)
    as_dev = {k: jnp.asarray(t) for k, t in tabs.items()}
    return stream_attention(
        words, q, pos, as_dev["k"], as_dev["k_scales"], as_dev["v"],
        as_dev["v_scales"], bits=kvc.bits, n_heads=q.shape[2],
        interpret=interpret)


__all__ = ["stream_attention", "stream_attention_cache"]
