"""Pallas kernels for the packed KV-cache subsystem."""
from .stream_attention import (  # noqa: F401
    stream_attention,
    stream_attention_cache,
)

__all__ = ["stream_attention", "stream_attention_cache"]
