"""Fault-tolerant checkpointing: atomic, async, keep-N, reshard-on-load.

Layout on disk (one directory per step):

    <root>/step_000100.tmp-<nonce>/   # written here first
    <root>/step_000100/               # atomic rename when complete
        manifest.json                 # tree structure + shapes + dtypes
        arr_00000.npy ...             # one file per leaf (host numpy)

Checkpoints are **mesh-free**: every leaf is gathered to host numpy, so a
checkpoint written on a 512-chip mesh restores onto 256 chips (or 1 CPU) —
``restore(..., shardings=...)`` re-places each leaf with the target
sharding via ``jax.make_array_from_callback`` (each device reads only its
shard's slice).  This is the elastic-rescale path.

The async writer runs in a daemon thread: ``save_async`` snapshots to host
memory synchronously (cheap) and serializes in the background so the train
loop never blocks on the filesystem.  ``keep_n`` old checkpoints are
garbage-collected after each successful save.
"""
from __future__ import annotations

import json
import os
import pathlib
import queue
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

#: distinguishes "use the process-wide layout cache" from an explicit
#: ``cache=None`` (restore without touching any cache)
_DEFAULT_CACHE_SENTINEL = object()


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree: Any) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(k, "key",
                                 getattr(k, "idx", getattr(k, "name", k))))
            for k in path) for path, _ in flat]


def _skeletonize(tree: Any) -> tuple[Any, list]:
    """Replace every leaf with ``{"__leaf__": i}``; return (skeleton, leaves).

    The skeleton is plain JSON (dict/list/None), so a checkpoint can
    rebuild the exact tree structure without a ``like`` template — keys
    containing ``/`` (e.g. ``"attn/bq"``) stay unambiguous, unlike
    path-string encodings.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    counter = iter(range(len(leaves)))
    skeleton = jax.tree_util.tree_unflatten(
        treedef, [{"__leaf__": next(counter)} for _ in leaves])

    def jsonify(node):
        if isinstance(node, dict) and "__leaf__" in node:
            return node
        if isinstance(node, dict):
            return {k: jsonify(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [jsonify(v) for v in node]
        return node
    return jsonify(skeleton), leaves


def _unskeletonize(skeleton: Any, leaves: list) -> Any:
    if isinstance(skeleton, dict) and "__leaf__" in skeleton:
        return leaves[skeleton["__leaf__"]]
    if isinstance(skeleton, dict):
        return {k: _unskeletonize(v, leaves) for k, v in skeleton.items()}
    if isinstance(skeleton, list):
        return [_unskeletonize(v, leaves) for v in skeleton]
    return skeleton


class CheckpointManager:
    def __init__(self, root: str | os.PathLike, keep_n: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._q: queue.Queue = queue.Queue()
        self._worker: threading.Thread | None = None
        self._errors: list[str] = []

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        """Synchronous atomic save.  Returns the final directory path."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any,
                   extra: dict | None = None) -> None:
        """Snapshot to host now, serialize in the background."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()
        self._q.put((step, host_tree, dict(extra or {})))

    def wait(self) -> None:
        """Block until all queued async saves are on disk."""
        self._q.join()
        if self._errors:
            errs, self._errors = self._errors, []
            raise RuntimeError("async checkpoint failures: " + "; ".join(errs))

    def _drain(self) -> None:
        while True:
            step, tree, extra = self._q.get()
            try:
                self._write(step, tree, extra)
            except Exception as e:  # noqa: BLE001
                self._errors.append(f"step {step}: {e!r}")
            finally:
                self._q.task_done()

    def _write(self, step: int, host_tree: Any, extra: dict) -> str:
        final = self.root / f"step_{step:08d}"
        tmp = self.root / f"step_{step:08d}.tmp-{os.getpid()}-{time.time_ns()}"
        tmp.mkdir(parents=True)
        leaves, treedef = _flatten(host_tree)
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex()
            if hasattr(treedef, "serialize_using_proto") else None,
            "paths": _tree_paths(host_tree),
            "leaves": [
                {"file": f"arr_{i:05d}.npy", "shape": list(x.shape),
                 "dtype": str(x.dtype)} for i, x in enumerate(leaves)
            ],
            "extra": extra,
        }
        for i, x in enumerate(leaves):
            # ml_dtypes (bf16, fp8) don't survive np.save round-trips:
            # store the raw-int view; manifest records the true dtype
            if x.dtype.kind not in "biufc":
                x = x.view(f"u{x.dtype.itemsize}")
            np.save(tmp / f"arr_{i:05d}.npy", x)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)        # atomicity: readers only see complete dirs
        self._gc()
        return str(final)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)
        # drop stale tmp dirs from crashed writers
        for p in self.root.glob("step_*.tmp-*"):
            shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.name.endswith(".json") or ".tmp-" in p.name:
                continue
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: int | None = None,
                shardings: Any | None = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like``.

        ``shardings``: optional pytree of NamedShardings (same structure) —
        each leaf is placed shard-by-shard on the target mesh (elastic
        restore onto a different topology).  Returns (tree, extra).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        like_leaves, treedef = _flatten(like)
        if len(like_leaves) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target structure has {len(like_leaves)}")
        shard_leaves = (_flatten(shardings)[0] if shardings is not None
                        else [None] * len(like_leaves))
        out = []
        for i, (meta, tgt, shd) in enumerate(
                zip(manifest["leaves"], like_leaves, shard_leaves)):
            arr = np.load(d / meta["file"], mmap_mode="r")
            want_dtype = np.dtype(jax.numpy.dtype(meta["dtype"]))
            if arr.dtype != want_dtype:
                arr = arr.view(want_dtype)
            want_shape = tuple(getattr(tgt, "shape", arr.shape))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"leaf {manifest['paths'][i]}: checkpoint shape "
                    f"{arr.shape} != target {want_shape}")
            if shd is None:
                out.append(np.array(arr))
            else:
                out.append(jax.make_array_from_callback(
                    want_shape, shd, lambda idx, a=arr: np.asarray(a[idx])))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]

    # ------------------------------------------------------------------
    # packed checkpoints: the HBM stream *is* the checkpoint
    # ------------------------------------------------------------------
    _PACKED_KEY = "packed_tree_manifest"
    _SKELETON_KEY = "packed_tree_skeleton"
    _DIGEST_KEY = "packed_stream_sha256"
    _KV_KEY = "packed_kv_manifest"
    _KV_DIGEST_KEY = "packed_kv_sha256"

    def save_packed(self, step: int, pt: Any,
                    extra: dict | None = None, *,
                    kv: Any = None) -> str:
        """Save a :class:`repro.tree.PackedTree` — packed bytes only.

        What hits disk is the per-layer unified Iris stream buffers
        (exactly the bytes that live in HBM) plus the unquantized
        leaves; dense weights are never materialized and the lane-packed
        kernel views are not duplicated (restore regenerates them
        bit-identically from the streams).  The tree's
        :class:`~repro.tree.LayoutManifest` rides in the checkpoint
        manifest JSON, so restore *rebinds* the layout instead of
        re-scheduling.

        The stream bytes are whatever :func:`repro.tree.pack_tree`
        produced — build the tree with ``pack_backend="pallas"`` to pack
        them with the fused device kernel
        (:func:`repro.kernels.layout_pack.pack_layout_fused`); the
        buffers are bit-identical either way, so the digest and restore
        path are backend-agnostic.

        ``kv`` (optional): a :class:`repro.kvcache.PackedKVCache` —
        its packed page words are saved alongside the weight streams
        with their own manifest and content digest, so a mid-stream
        serving snapshot round-trips (``restore_kv``) and decode
        continues bit-identically.  Checkpoints written without ``kv``
        (including all pre-KV checkpoints) load unchanged.
        """
        if pt.streams is None:
            raise ValueError(
                "PackedTree was built with with_streams=False; packed "
                "checkpointing needs the stream buffers"
            )
        from repro.analysis import stream_sha256

        payload = {
            "streams": np.asarray(pt.streams),
            "other": jax.tree.map(lambda x: np.asarray(x), pt.other),
        }
        if kv is not None:
            payload["kv_pages"] = np.asarray(kv.pages)
        skeleton, _ = _skeletonize(payload)
        merged = dict(extra or {})
        merged[self._PACKED_KEY] = pt.manifest.to_json_dict()
        merged[self._SKELETON_KEY] = skeleton
        # content digest of the stream bytes: layout tables cannot see
        # bit-flips, so restore verifies the bytes themselves
        merged[self._DIGEST_KEY] = stream_sha256(payload["streams"])
        if kv is not None:
            merged[self._KV_KEY] = kv.manifest.to_json_dict()
            merged[self._KV_DIGEST_KEY] = stream_sha256(
                payload["kv_pages"])
        return self.save(step, payload, merged)

    def _load_packed(self, step: int | None):
        """Load a packed checkpoint's pieces without rebinding anything.

        Returns ``(tree_manifest, payload, extra, digest, kv_manifest,
        kv_digest)`` where ``payload`` holds the host leaves
        (``streams`` / ``other`` / optionally ``kv_pages``), ``digest``
        is the recorded stream sha256 (``None`` for packed checkpoints
        from before digests were stored), and the kv pair is the raw
        :class:`~repro.kvcache.KVManifest` JSON dict + page digest
        (both ``None`` when the checkpoint carries no KV pages).
        """
        from repro.tree import LayoutManifest

        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        extra = dict(manifest["extra"])
        if self._PACKED_KEY not in extra:
            raise ValueError(
                f"step {step} is not a packed checkpoint; use restore()"
            )
        tree_manifest = LayoutManifest.from_json_dict(
            extra.pop(self._PACKED_KEY))
        skeleton = extra.pop(self._SKELETON_KEY)
        digest = extra.pop(self._DIGEST_KEY, None)
        kv_manifest = extra.pop(self._KV_KEY, None)
        kv_digest = extra.pop(self._KV_DIGEST_KEY, None)
        leaves = []
        for meta in manifest["leaves"]:
            arr = np.load(d / meta["file"])
            want_dtype = np.dtype(jax.numpy.dtype(meta["dtype"]))
            if arr.dtype != want_dtype:
                arr = arr.view(want_dtype)
            leaves.append(arr)
        payload = _unskeletonize(skeleton, leaves)
        return tree_manifest, payload, extra, digest, kv_manifest, kv_digest

    def verify_packed(self, step: int | None = None):
        """Statically verify a packed checkpoint **without restoring it**.

        Runs the :mod:`repro.analysis` manifest-consistency pass set over
        the stored manifest, intervals, stream byte-lengths and content
        digest; returns the :class:`~repro.analysis.Report` (never
        raises on findings — this is the inspection surface;
        :meth:`restore_packed` is the one that refuses).  When the
        checkpoint carries KV pages, the KV-cache pass set
        (:func:`repro.analysis.verify_kvcache`) runs too and its
        findings merge into the same report — ``python -m repro.analysis
        ckpt`` therefore gates a mid-stream KV snapshot as well.
        """
        from repro.analysis import verify_manifest

        tree_manifest, payload, _extra, digest, kv_man, kv_digest = \
            self._load_packed(step)
        report = verify_manifest(
            tree_manifest, streams=payload["streams"],
            stream_digest=digest,
            subject=f"ckpt[{self.root.name}/{tree_manifest.arch}]")
        if kv_man is not None:
            sub = self._verify_kv(payload, kv_man, kv_digest)
            report.findings.extend(sub.findings)
            report.passes.extend(p for p in sub.passes
                                 if p not in report.passes)
        return report

    def _rebuild_kv(self, payload: dict, kv_man: dict):
        """KV pieces -> a host-backed :class:`PackedKVCache`."""
        import jax.numpy as jnp

        from repro.kvcache import KVManifest, PackedKVCache

        return PackedKVCache(
            jnp.asarray(payload["kv_pages"], jnp.uint32),
            KVManifest.from_json_dict(kv_man),
            provenance="checkpoint")

    def _verify_kv(self, payload: dict, kv_man: dict,
                   kv_digest: str | None):
        from repro.analysis import Finding, Report, Severity, verify_kvcache

        if "kv_pages" not in payload:
            r = Report(subject=f"ckpt[{self.root.name}/kv]")
            r.findings.append(Finding(
                "kvcache/pages-missing", Severity.ERROR,
                "checkpoint records a KV manifest but stores no "
                "kv_pages leaf"))
            return r
        return verify_kvcache(
            self._rebuild_kv(payload, kv_man), pages_digest=kv_digest,
            subject=f"ckpt[{self.root.name}/kv]")

    def restore_packed(self, step: int | None = None, *,
                       cache: Any = _DEFAULT_CACHE_SENTINEL,
                       verify: bool = True) -> tuple[Any, dict]:
        """Restore a :class:`repro.tree.PackedTree` from a packed save.

        Mesh-free like :meth:`restore` (host numpy; re-place with
        ``jax.device_put(pt, packed_tree_shardings(pt, mesh))``).  The
        layout comes from the shared cache when warm (O(intervals)
        rebind) or from the manifest's recorded count-intervals when
        cold — the scheduler never runs; packed codes and scale bit
        patterns are reconstructed bit-identically.

        Before rebinding, the static analyzer proves the checkpoint
        self-consistent (manifest vs bundle vs intervals vs stream
        byte-lengths vs content digest); a corrupted checkpoint raises
        :class:`~repro.analysis.AnalysisError` naming the violated rule
        instead of surfacing as a shape error or silently-garbage
        weights (``verify=False`` skips, for forensics on a checkpoint
        the analyzer already rejected).  Returns ``(PackedTree, extra)``
        with the packed bookkeeping keys stripped from ``extra``.
        """
        from repro.tree import unpack_streams

        tree_manifest, payload, extra, digest, _kv_man, _kv_digest = \
            self._load_packed(step)
        if verify:
            from repro.analysis import verify_manifest

            verify_manifest(
                tree_manifest, streams=payload["streams"],
                stream_digest=digest,
                subject=f"ckpt[{self.root.name}]").raise_if_errors()
        if cache is _DEFAULT_CACHE_SENTINEL:
            from repro.core.iris import DEFAULT_CACHE
            cache = DEFAULT_CACHE
        pt = unpack_streams(tree_manifest, payload["streams"],
                            payload["other"], cache=cache)
        return pt, extra

    def restore_kv(self, step: int | None = None, *,
                   verify: bool = True) -> Any:
        """Restore the :class:`repro.kvcache.PackedKVCache` a packed
        checkpoint carries (``save_packed(..., kv=...)``).

        Returns ``None`` when the checkpoint has no KV pages (every
        pre-KV checkpoint), so callers can probe without a try/except.
        With ``verify=True`` the KV-cache analysis pass set must come
        back clean (page geometry, content digest, write-mask soundness,
        append idempotence) before the cache is handed out — a corrupted
        snapshot raises :class:`~repro.analysis.AnalysisError` instead
        of decoding garbage attention.
        """
        _man, payload, _extra, _digest, kv_man, kv_digest = \
            self._load_packed(step)
        if kv_man is None:
            return None
        kvc = self._rebuild_kv(payload, kv_man)
        if verify:
            self._verify_kv(payload, kv_man, kv_digest).raise_if_errors()
        return kvc
