from .qtypes import (  # noqa: F401
    QuantSpec,
    QuantizedTensor,
    dequantize,
    pack_codes_u32,
    quantize,
    unpack_codes_u32,
)
