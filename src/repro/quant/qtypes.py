"""Custom-precision integer tensor types (paper §1/§2 motivation).

Symmetric, group-wise integer quantization at arbitrary bitwidths 2..8.
Codes are stored *biased* (unsigned: ``q + 2^(bits-1)``) so they behave as
plain unsigned bit-fields for the Iris packer, exactly like the paper's
``ap_uint<W>`` elements.

Two storage formats:

* **element codes** — one unsigned code per element (any width), consumed
  by the Iris layout packer (``core.codegen``);
* **lane-packed u32** — ``32/bits`` codes per uint32 word, the
  hardware-aligned format consumed by the dequant-on-load Pallas matmul
  (``kernels.packed_matmul``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    bits: int = 4            # element width W
    group_size: int = 128    # contraction elements sharing one scale
    scale_dtype: str = "bfloat16"

    def __post_init__(self) -> None:
        if not 2 <= self.bits <= 8:
            raise ValueError(f"bits must be in [2, 8], got {self.bits}")
        if self.group_size <= 0:
            raise ValueError("group_size must be positive")

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    @property
    def bias(self) -> int:
        return 1 << (self.bits - 1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Biased unsigned codes + per-(group, out-channel) scales."""

    codes: jax.Array     # (K, N) uint8 — biased codes, one per element
    scales: jax.Array    # (K // group_size, N)
    spec: QuantSpec
    shape: tuple[int, int]

    def tree_flatten(self):
        return (self.codes, self.scales), (self.spec, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scales = children
        spec, shape = aux
        return cls(codes=codes, scales=scales, spec=spec, shape=shape)


@partial(jax.jit, static_argnames=("spec",))
def quantize(w: jax.Array, spec: QuantSpec) -> QuantizedTensor:
    """Quantize a (K, N) matrix group-wise along K (the contraction dim)."""
    k, n = w.shape
    if k % spec.group_size != 0:
        raise ValueError(f"K={k} not divisible by group_size={spec.group_size}")
    g = k // spec.group_size
    wg = w.astype(jnp.float32).reshape(g, spec.group_size, n)
    amax = jnp.max(jnp.abs(wg), axis=1)                      # (g, n)
    scale = jnp.where(amax > 0, amax / spec.qmax, 1.0)       # (g, n)
    q = jnp.round(wg / scale[:, None, :])
    q = jnp.clip(q, -spec.qmax, spec.qmax)
    codes = (q + spec.bias).astype(jnp.uint8).reshape(k, n)
    return QuantizedTensor(
        codes=codes,
        scales=scale.astype(jnp.dtype(spec.scale_dtype)),
        spec=spec,
        shape=(k, n),
    )


@partial(jax.jit, static_argnames=())
def dequantize(qt: QuantizedTensor) -> jax.Array:
    k, n = qt.shape
    g = k // qt.spec.group_size
    q = qt.codes.astype(jnp.float32) - qt.spec.bias
    q = q.reshape(g, qt.spec.group_size, n)
    w = q * qt.scales.astype(jnp.float32)[:, None, :]
    return w.reshape(k, n)


# ----------------------------------------------------------------------
# lane-packed u32 storage (hardware-aligned fast path)
# ----------------------------------------------------------------------
def pack_codes_u32(codes: jax.Array, bits: int) -> jax.Array:
    """(K, N) uint8 codes -> (K // lanes, N) uint32, lanes = 32 // bits.

    Lane ``l`` of word ``r`` holds code ``codes[r * lanes + l]`` at bit
    position ``l * bits`` (LSB-first) — matching the Iris bus convention.
    Requires ``32 % bits == 0`` (bits in {2, 4, 8}); other widths go through
    the general Iris layout packer instead.
    """
    if 32 % bits != 0:
        raise ValueError(f"lane packing needs 32 % bits == 0, got {bits}")
    lanes = 32 // bits
    k, n = codes.shape
    if k % lanes != 0:
        raise ValueError(f"K={k} not divisible by lanes={lanes}")
    c = codes.astype(jnp.uint32).reshape(k // lanes, lanes, n)
    shifts = (jnp.arange(lanes, dtype=jnp.uint32) * bits)[None, :, None]
    return jnp.bitwise_or.reduce(c << shifts, axis=1)


def unpack_codes_u32(packed: jax.Array, bits: int, k: int) -> jax.Array:
    """Inverse of :func:`pack_codes_u32` -> (K, N) uint8 codes."""
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = (jnp.arange(lanes, dtype=jnp.uint32) * bits)[None, :, None]
    c = (packed[:, None, :] >> shifts) & mask
    return c.reshape(k, packed.shape[-1]).astype(jnp.uint8)


def quant_error_bound(spec: QuantSpec) -> float:
    """Half an LSB of the symmetric grid, in units of the group amax."""
    return 0.5 / spec.qmax


def codes_as_numpy_elements(qt: QuantizedTensor) -> np.ndarray:
    """Flatten codes to uint64 element stream for the Iris packer."""
    return np.asarray(qt.codes).reshape(-1).astype(np.uint64)
