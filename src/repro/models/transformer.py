"""Unified layer stack for all 10 assigned architectures.

Every model is a ``jax.lax.scan`` over *periods* of stacked per-layer
params, keeping the HLO size depth-independent (essential for 40-cell
512-device dry-runs).  A period is the smallest repeating sublayer
template:

* dense / moe / vlm:  1 sublayer  [attn -> mlp|moe]
* ssm (rwkv6):        1 sublayer  [time-mix -> channel-mix]
* hybrid (jamba):     ``attn_every`` sublayers, the last one attention,
                      the rest mamba; FFNs alternate mlp/moe per parity
* encdec (whisper):   decoder periods carry a cross-attention; a separate
                      encoder stack runs first.

Within a period the (static, heterogeneous) sublayers are unrolled; across
periods everything is scanned.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn
from . import mamba as mam
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from .layers import apply_mlp, apply_norm, init_mlp, init_norm, rope_freqs
from .shard_utils import dp_spec, maybe_shard


@dataclasses.dataclass(frozen=True)
class SubLayerSpec:
    mixer: str                   # "attn" | "mamba" | "rwkv"
    ffn: str                     # "mlp" | "moe" | "rwkv_channel"
    cross: bool = False          # whisper decoder cross-attention


def period_template(cfg: ModelConfig) -> tuple[SubLayerSpec, ...]:
    p = max(1, cfg.attn_every)
    subs = []
    for s in range(p):
        if cfg.family == "ssm":
            subs.append(SubLayerSpec("rwkv", "rwkv_channel"))
            continue
        mixer = "attn" if cfg.layer_is_attn(s) else "mamba"
        ffn = "moe" if cfg.layer_is_moe(s) else "mlp"
        subs.append(SubLayerSpec(mixer, ffn, cross=cfg.family == "encdec"))
    return tuple(subs)


def n_periods(cfg: ModelConfig) -> int:
    p = max(1, cfg.attn_every)
    if cfg.n_layers % p:
        raise ValueError(f"n_layers={cfg.n_layers} not divisible by "
                         f"period {p}")
    return cfg.n_layers // p


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def _init_sublayer(key, cfg: ModelConfig, spec: SubLayerSpec) -> dict:
    ks = iter(jax.random.split(key, 8))
    p: dict[str, Any] = {"norm1": init_norm(cfg, cfg.d_model),
                         "norm2": init_norm(cfg, cfg.d_model)}
    if spec.mixer == "attn":
        p["attn"] = attn.init_attention(next(ks), cfg)
    elif spec.mixer == "mamba":
        p["mamba"] = mam.init_mamba(next(ks), cfg)
    elif spec.mixer == "rwkv":
        p["rwkv_t"] = rwkv_mod.init_rwkv_time_mix(next(ks), cfg)
    if spec.cross:
        p["cross"] = attn.init_attention(next(ks), cfg)
        p["norm_cross"] = init_norm(cfg, cfg.d_model)
    if spec.ffn == "mlp":
        p["mlp"] = init_mlp(next(ks), cfg)
    elif spec.ffn == "moe":
        p["moe"] = moe_mod.init_moe(next(ks), cfg)
    elif spec.ffn == "rwkv_channel":
        p["rwkv_c"] = rwkv_mod.init_rwkv_channel_mix(next(ks), cfg)
    return p


def init_stack(key, cfg: ModelConfig) -> list[dict]:
    """Per-sublayer param trees, each leaf stacked over n_periods."""
    template = period_template(cfg)
    np_ = n_periods(cfg)
    out = []
    for si, spec in enumerate(template):
        sub_key = jax.random.fold_in(key, si)
        keys = jax.random.split(sub_key, np_)
        stacked = jax.vmap(
            lambda k, _spec=spec: _init_sublayer(k, cfg, _spec))(keys)
        out.append(stacked)
    return out


# ----------------------------------------------------------------------
# forward (full sequence: train / prefill / encoder)
# ----------------------------------------------------------------------
def _sublayer_forward(cfg: ModelConfig, spec: SubLayerSpec, p: dict,
                      x: jax.Array, positions: jax.Array, inv_freq,
                      cross_memory=None, causal: bool = True,
                      collect_cache: bool = False):
    """Returns (x, aux_loss, cache_kv or None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = apply_norm(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        b, s, _ = h.shape
        if collect_cache:
            k = attn._project(cfg, p["attn"], h, "k").reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim)
            v = attn._project(cfg, p["attn"], h, "v").reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim)
            k = attn.apply_rope(k, positions, inv_freq, cfg.mrope_sections)
            cache = (k, v)
        x = x + attn.attention_block(cfg, p["attn"], h, positions, inv_freq,
                                     causal=causal)
    elif spec.mixer == "mamba":
        y, _ = mam.apply_mamba(cfg, p["mamba"], h)
        x = x + y
    elif spec.mixer == "rwkv":
        y, _, _ = rwkv_mod.apply_rwkv_time_mix(cfg, p["rwkv_t"], h)
        x = x + y
    if spec.cross and cross_memory is not None:
        hc = apply_norm(cfg, p["norm_cross"], x)
        x = x + attn.cross_attention_block(cfg, p["cross"], hc,
                                           memory=cross_memory)
    h2 = apply_norm(cfg, p["norm2"], x)
    if spec.ffn == "mlp":
        x = x + apply_mlp(cfg, p["mlp"], h2)
    elif spec.ffn == "moe":
        # leave the SP (sequence-sharded) regime *once*, in bf16, before
        # the dispatch: the capacity scatter cannot be sequence-sharded,
        # and letting GSPMD discover that lazily re-gathers the much
        # larger (B, S*k, d) f32 dispatch tensors many times per layer
        # (measured: 3 GiB x ~13 per layer on moonshot; EXPERIMENTS §Perf)
        h2 = maybe_shard(h2, dp_spec(), None, None)
        y, aux = moe_mod.apply_moe(cfg, p["moe"], h2)
        x = x + y
    elif spec.ffn == "rwkv_channel":
        y, _ = rwkv_mod.apply_rwkv_channel_mix(cfg, p["rwkv_c"], h2)
        x = x + y
    return x, aux, cache


def forward_stack(cfg: ModelConfig, blocks: list[dict], x: jax.Array,
                  positions: jax.Array, *, cross_memory=None,
                  causal: bool = True, collect_cache: bool = False,
                  remat: str = "full"):
    """Scan the period stack.  Returns (x, total_aux, caches or None).

    caches: per attention sublayer, (k, v) stacked over periods.
    """
    template = period_template(cfg)
    inv_freq = rope_freqs(cfg)

    def period_fn(carry, period_params):
        x = carry
        # Megatron-style sequence-parallel boundary: the scan carry (the
        # only activation saved per period under remat) lives with S
        # sharded over 'model'.  GSPMD turns the surrounding TP
        # all-reduces into reduce-scatter + all-gather pairs (same bytes)
        # while the saved residuals shrink by the TP degree — this is
        # what keeps 100B+ training under HBM (EXPERIMENTS.md §Perf).
        x = maybe_shard(x, dp_spec(), "model", None)
        aux_sum = jnp.zeros((), jnp.float32)
        caches = []
        for si, spec in enumerate(template):
            x, aux, cache = _sublayer_forward(
                cfg, spec, period_params[si], x, positions, inv_freq,
                cross_memory=cross_memory, causal=causal,
                collect_cache=collect_cache and spec.mixer == "attn")
            aux_sum = aux_sum + aux
            if cache is not None:
                caches.append(cache)
        x = maybe_shard(x, dp_spec(), "model", None)
        return x, (aux_sum, tuple(caches))

    if remat == "full":
        period_fn = jax.checkpoint(period_fn)
    elif remat == "dots":
        period_fn = jax.checkpoint(
            period_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat != "none":
        raise ValueError(f"unknown remat policy {remat!r}")

    x, (aux_per_period, caches) = jax.lax.scan(period_fn, x, blocks)
    return x, aux_per_period.sum(), caches
