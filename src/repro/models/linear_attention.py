"""Chunked recurrent linear attention — shared by RWKV-6 and Mamba(SSD).

State-space recurrence with per-token, per-channel decay:

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t          (B, H, dk, dv) state
    out_t = q_t . S_t                              (mamba/SSD form)
    out_t = q_t . (S_{t-1} + diag(u) k_t (x) v_t)  (rwkv form, bonus u)

Executed as ``lax.scan`` over token mini-chunks with a small unrolled
inner loop: state memory stays O(B*H*dk*dv), compute is the exact
O(T*H*dk*dv) of the linear-attention family, and the HLO is scan-shaped
(constant-size, sequence-length independent) — which is what keeps the
40-cell dry-run tractable.  DESIGN.md §Hardware-adaptation discusses why
this replaces the CUDA chunk-parallel kernels of the source papers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .shard_utils import dp_spec, maybe_shard


@functools.partial(jax.jit, static_argnames=("chunk", "rwkv_mode"))
def recurrent_scan(q: jax.Array, k: jax.Array, v: jax.Array,
                   log_decay: jax.Array, u: jax.Array | None = None,
                   state0: jax.Array | None = None, *, chunk: int = 32,
                   rwkv_mode: bool = False
                   ) -> tuple[jax.Array, jax.Array]:
    """q/k: (B,T,H,dk), v: (B,T,H,dv), log_decay: (B,T,H,dk) or
    (B,T,H,1) (<= 0).  A trailing 1 (scalar-per-head decay, mamba/SSD)
    is broadcast lazily inside the step — materializing it to dk first
    costs dk x the scan-input memory (measured on jamba; §Perf iterD4).

    u: (H, dk) rwkv 'bonus' for the current token (rwkv_mode only).
    Returns (out (B,T,H,dv), final_state (B,H,dk,dv)).
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    if t % chunk:
        pad = chunk - t % chunk
        q, k, v, log_decay = (
            jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
            for a in (q, k, v, log_decay))
    else:
        pad = 0
    tp = t + pad
    n = tp // chunk
    # (n, chunk, B, H, d*)
    def to_chunks(a):
        return a.reshape(b, n, chunk, h, -1).transpose(1, 2, 0, 3, 4)
    qc, kc, vc, wc = map(to_chunks, (q, k, v, jnp.exp(log_decay)))

    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    @jax.checkpoint
    def step(s, inputs):
        # remat'd: the backward recomputes the chunk's rank-1 updates from
        # the (B,H,dk,dv) carry instead of saving one kv outer product per
        # token — without this, training peaks at O(T/chunk) saved states
        # (measured: jamba train 2.1 TiB/dev -> see EXPERIMENTS §Perf).
        qi, ki, vi, wi = inputs
        outs = []
        for c in range(chunk):           # small unrolled inner loop
            qt = qi[c].astype(jnp.float32)       # (B, H, dk)
            kt = ki[c].astype(jnp.float32)
            vt = vi[c].astype(jnp.float32)       # (B, H, dv)
            wt = wi[c].astype(jnp.float32)       # (B, H, dk)
            kv = kt[..., :, None] * vt[..., None, :]     # (B, H, dk, dv)
            if rwkv_mode:
                eff = s + (u.astype(jnp.float32)[None, :, :, None] * kv
                           if u is not None else kv)
                out = jnp.einsum("bhk,bhkv->bhv", qt, eff)
                s = wt[..., None] * s + kv
            else:
                s = wt[..., None] * s + kv
                out = jnp.einsum("bhk,bhkv->bhv", qt, s)
            outs.append(out)
        # keep the carried state head-sharded: it is saved once per outer
        # step for the backward pass, and unsharded it dominates training
        # memory for large-H hybrids (jamba: 67 MB/step -> 4 MB/step)
        s = maybe_shard(s, dp_spec(), "model", None, None)
        return s, jnp.stack(outs)        # (chunk, B, H, dv)

    final, out_chunks = jax.lax.scan(step, state0, (qc, kc, vc, wc))
    out = out_chunks.reshape(n * chunk, b, h, dv).transpose(1, 0, 2, 3)
    return out[:, :t].astype(q.dtype), final


def recurrent_step(q: jax.Array, k: jax.Array, v: jax.Array,
                   log_decay: jax.Array, state: jax.Array,
                   u: jax.Array | None = None, *, rwkv_mode: bool = False
                   ) -> tuple[jax.Array, jax.Array]:
    """Single-token decode step.  q/k/log_decay: (B,H,dk), v: (B,H,dv);
    state: (B,H,dk,dv).  Returns (out (B,H,dv), new_state)."""
    qt = q.astype(jnp.float32)
    kv = k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    w = jnp.exp(log_decay.astype(jnp.float32))
    if rwkv_mode:
        eff = state + (u.astype(jnp.float32)[None, :, :, None] * kv
                       if u is not None else kv)
        out = jnp.einsum("bhk,bhkv->bhv", qt, eff)
        state = w[..., None] * state + kv
    else:
        state = w[..., None] * state + kv
        out = jnp.einsum("bhk,bhkv->bhv", qt, state)
    return out, state
