"""Activation sharding constraints that degrade gracefully.

``maybe_shard(x, *spec)`` applies a with_sharding_constraint iff a mesh
context is active; each axis is divisibility-checked against its dim and
dropped when it doesn't fit.  Model code can therefore annotate its
activations unconditionally — smoke tests (no mesh) and every arch
(heterogeneous dims) run the same code path.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _active_mesh():
    from jax._src import mesh as mesh_lib

    env = mesh_lib.thread_resources.env
    return None if env.physical_mesh.empty else env.physical_mesh


def maybe_shard(x: jax.Array, *spec) -> jax.Array:
    """spec: one entry per dim — None, 'axis', or ('ax1', 'ax2')."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and size > 0 and dim % size == 0:
            fixed.append(axes if len(axes) > 1 else axes[0])
        else:
            fixed.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


def dp_spec() -> tuple:
    """The data-parallel axis group for activation batch dims."""
    mesh = _active_mesh()
    if mesh is None:
        return ("data",)
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
