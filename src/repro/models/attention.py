"""GQA attention: double-chunked flash for train/prefill, direct for decode.

Train/prefill uses an online-softmax formulation chunked over BOTH query
and key/value blocks (``lax.map`` over q blocks, ``lax.scan`` over kv
blocks) so peak memory is O(q_chunk * kv_chunk) per head instead of
O(S^2) — the TPU-native equivalent of flash attention, expressed in pure
lax so GSPMD can shard it.

Decode (one query token) uses the direct einsum path: logits are
(B, 1, H, S) which is small at any context length and — crucially for
long_500k — contracts cleanly against a sequence-sharded KV cache (XLA
inserts the partial-softmax psum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype, scale=(h * hd) ** -0.5),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def _project(cfg, p, x, name):
    y = x @ p[f"w{name}"]
    if cfg.use_bias:
        y = y + p[f"b{name}"]
    return y


def _repeat_kv(kv: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, Hkv, hd) -> (B, S, H, hd) by GQA group replication."""
    hkv = kv.shape[2]
    if hkv == n_heads:
        return kv
    return jnp.repeat(kv, n_heads // hkv, axis=2)


# ----------------------------------------------------------------------
# chunked flash attention (train / prefill)
# ----------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("causal", "q_chunk", "kv_chunk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_chunk: int = 1024,
                    kv_chunk: int = 1024) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd).  Returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scale = hd ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nkv = -(-sq // q_chunk), -(-skv // kv_chunk)
    pad_q, pad_kv = nq * q_chunk - sq, nkv * kv_chunk - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    qb = q.reshape(b, nq, q_chunk, h, hd)
    kb = k.reshape(b, nkv, kv_chunk, h, hd)
    vb = v.reshape(b, nkv, kv_chunk, h, hd)

    def q_block(args):
        qi, q_base = args                       # (B, cq, H, hd), scalar

        def kv_step(carry, inputs):
            m, lsum, acc = carry
            kj, vj, kv_base = inputs
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            q_pos = q_base + jnp.arange(q_chunk)
            kv_pos = kv_base + jnp.arange(kv_chunk)
            mask = kv_pos[None, :] < skv                       # kv padding
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = lsum * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        kv_bases = jnp.arange(nkv) * kv_chunk
        # remat the body: backward recomputes the (cq, ckv) score tile
        # instead of saving one per scan step (which would materialize the
        # full S^2 matrix as scan residuals — the whole point of flash
        # attention is not to do that)
        (m, lsum, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
             kv_bases),
        )
        out = acc / jnp.maximum(lsum, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3)                  # (B, cq, H, hd)

    q_bases = jnp.arange(nq) * q_chunk
    outs = jax.lax.map(q_block, (qb.transpose(1, 0, 2, 3, 4), q_bases))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, h, hd)
    return out[:, :sq].astype(q.dtype)


# ----------------------------------------------------------------------
# decode attention (single query position, KV cache)
# ----------------------------------------------------------------------
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array) -> jax.Array:
    """q: (B, 1, H, hd); caches: (B, Smax, Hkv, hd); pos: (B,) per-row
    positions (continuous batching: every slot has its own clock).

    Direct einsum: logits (B, H, 1, Smax) are tiny for Sq=1 and contract
    against a sequence-sharded cache without re-chunking.
    """
    b, _, h, hd = q.shape
    smax = k_cache.shape[1]
    # low-precision caches (fp8 KV) are upcast at the compute boundary
    kc = _repeat_kv(k_cache, h).astype(q.dtype)
    vc = _repeat_kv(v_cache, h).astype(q.dtype)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    valid = jnp.arange(smax)[None, None, None, :] <= \
        pos[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vc.astype(jnp.float32))
    return out.astype(q.dtype)


def stream_decode_attention(kvc, q: jax.Array, pos: jax.Array,
                            slot_ids: jax.Array, *, layer: int,
                            oracle: bool = False,
                            interpret: bool = True) -> jax.Array:
    """Decode attention straight off a packed Iris KV stream.

    ``kvc`` is a :class:`repro.kvcache.PackedKVCache`; ``q``:
    ``(B, 1, H, hd)``; ``pos`` / ``slot_ids``: ``(B,)``.  The default
    path runs the stream-direct Pallas kernel (packed pages ->
    registers -> dot, no dense K/V intermediate); ``oracle=True``
    materializes the dequantized dense K/V and reuses
    :func:`decode_attention` — bit-identical by construction, kept as
    the verification path.
    """
    if oracle:
        kf, vf = kvc.dense_kv(layer, slot_ids)
        return decode_attention(q, kf, vf, pos)
    from repro.kvcache.kernels import stream_attention_cache  # lazy

    return stream_attention_cache(kvc, q, pos, slot_ids, layer=layer,
                                  interpret=interpret)


# ----------------------------------------------------------------------
# attention block entry points
# ----------------------------------------------------------------------
def attention_block(cfg: ModelConfig, p: dict, x: jax.Array,
                    positions: jax.Array, inv_freq,
                    causal: bool = True,
                    kv_override: tuple[jax.Array, jax.Array] | None = None
                    ) -> jax.Array:
    """Full-sequence attention (train/prefill or encoder/cross)."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _project(cfg, p, x, "q").reshape(b, s, h, hd)
    if kv_override is None:
        k = _project(cfg, p, x, "k").reshape(b, s, hkv, hd)
        v = _project(cfg, p, x, "v").reshape(b, s, hkv, hd)
        q = apply_rope(q, positions, inv_freq, cfg.mrope_sections)
        k = apply_rope(k, positions, inv_freq, cfg.mrope_sections)
    else:
        k, v = kv_override                       # cross-attention memory
    out = flash_attention(q, k, v, causal=causal)
    return _project(cfg, p, out.reshape(b, s, h * hd), "o")


def attention_decode_block(cfg: ModelConfig, p: dict, x: jax.Array,
                           k_cache: jax.Array, v_cache: jax.Array,
                           pos: jax.Array, inv_freq
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token step; pos: (B,) per-row write positions.
    Returns (out, new_k_cache, new_v_cache)."""
    b, _, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _project(cfg, p, x, "q").reshape(b, 1, h, hd)
    k = _project(cfg, p, x, "k").reshape(b, 1, hkv, hd)
    v = _project(cfg, p, x, "v").reshape(b, 1, hkv, hd)
    pos_b = pos[:, None]                                 # (B, 1)
    q = apply_rope(q, pos_b, inv_freq, cfg.mrope_sections)
    k = apply_rope(k, pos_b, inv_freq, cfg.mrope_sections)
    rows = jnp.arange(b)
    k_cache = k_cache.at[rows, pos].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[rows, pos].set(v[:, 0].astype(v_cache.dtype))
    out = decode_attention(q, k_cache, v_cache, pos)
    y = _project(cfg, p, out.reshape(b, 1, h * hd), "o")
    return y, k_cache, v_cache


def cross_kv(cfg: ModelConfig, p: dict, memory: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
    """Project encoder memory (B, ctx, d) to cross K/V (B, ctx, Hkv, hd)."""
    b, s, _ = memory.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = _project(cfg, p, memory, "k").reshape(b, s, hkv, hd)
    v = _project(cfg, p, memory, "v").reshape(b, s, hkv, hd)
    return k, v


def cross_attention_block(cfg: ModelConfig, p: dict, x: jax.Array,
                          memory: jax.Array | None = None,
                          kv: tuple[jax.Array, jax.Array] | None = None
                          ) -> jax.Array:
    """Decoder cross-attention; pass encoder ``memory`` (train) or
    precomputed ``kv`` (decode)."""
    if kv is None:
        kv = cross_kv(cfg, p, memory)
    return attention_block(cfg, p, x, positions=None, inv_freq=None,
                           causal=False, kv_override=kv)
