"""Shared model building blocks: norms, activations, RoPE/M-RoPE, MLP.

Functional style: params are plain dicts of jnp arrays; every init_* takes
a PRNG key and returns the param subtree, every apply is a pure function.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def truncated_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return truncated_normal(key, (d_in, d_out), scale, dtype)


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------
def init_norm(cfg: ModelConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ----------------------------------------------------------------------
# activations
# ----------------------------------------------------------------------
def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu_squared":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ----------------------------------------------------------------------
# rotary embeddings (RoPE and qwen2-vl's M-RoPE)
# ----------------------------------------------------------------------
def rope_freqs(cfg: ModelConfig) -> jax.Array | None:
    if not cfg.rope_theta:
        return None
    hd = cfg.head_dim
    return cfg.rope_theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32)
                              / hd)                      # (hd/2,)


def apply_rope(x: jax.Array, positions: jax.Array,
               inv_freq: jax.Array | None,
               mrope_sections: tuple[int, int, int] | None = None
               ) -> jax.Array:
    """x: (B, S, H, hd).  positions: (B, S) or (B, S, 3) for M-RoPE.

    M-RoPE (qwen2-vl): the hd/2 frequency channels are split into
    (temporal, height, width) sections, each rotated by its own position
    stream.  For text tokens all three streams are equal, recovering
    standard RoPE.
    """
    if inv_freq is None:
        return x
    if positions.ndim == 2:
        positions = positions[..., None].repeat(3, axis=-1)
    if mrope_sections is None:
        pos = positions[..., 0]                          # (B, S)
        angles = pos[..., None].astype(jnp.float32) * inv_freq  # (B,S,hd/2)
    else:
        t, h, w = mrope_sections
        assert t + h + w == inv_freq.shape[0]
        sec_pos = jnp.concatenate(
            [
                positions[..., 0:1].repeat(t, axis=-1),
                positions[..., 1:2].repeat(h, axis=-1),
                positions[..., 2:3].repeat(w, axis=-1),
            ],
            axis=-1,
        )                                                # (B, S, hd/2)
        angles = sec_pos.astype(jnp.float32) * inv_freq
    cos = jnp.cos(angles)[:, :, None, :]                 # (B, S, 1, hd/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)


def sinusoidal_positions(n_ctx: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal table (n_ctx, d)."""
    inv = 10000 ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = jnp.arange(n_ctx, dtype=jnp.float32)[:, None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------
# gated MLP
# ----------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_gate": dense_init(k1, d, f, dtype),
        "w_up": dense_init(k2, d, f, dtype),
        "w_down": dense_init(k3, f, d, dtype, scale=f ** -0.5),
    }
    if cfg.use_bias:
        p["b_gate"] = jnp.zeros((f,), dtype)
        p["b_up"] = jnp.zeros((f,), dtype)
        p["b_down"] = jnp.zeros((d,), dtype)
    return p


def apply_mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    if cfg.use_bias:
        g = g + p["b_gate"]
        u = u + p["b_up"]
    h = activation(cfg.act, g) * u
    y = h @ p["w_down"]
    if cfg.use_bias:
        y = y + p["b_down"]
    return y
