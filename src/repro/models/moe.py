"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

Top-k routing -> cumulative-sum slot assignment -> scatter into per-expert
buffers (E, C, d) -> batched expert matmuls -> gather-combine.  Compute is
O(T * k * cf) expert FLOPs (not O(T * E)), so the dry-run roofline reflects
the *active* compute of the MoE — the same property the real deployments
rely on.  Experts are sharded over the 'model' mesh axis (EP); tokens stay
sharded over 'data'; XLA inserts the dispatch all-to-alls.

Arctic's dense-residual variant runs a standard MLP in parallel and sums.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import activation, apply_mlp, dense_init, init_mlp
from .shard_utils import dp_spec, maybe_shard


def init_moe(key, cfg: ModelConfig) -> dict:
    moe = cfg.moe
    d, f, e = cfg.d_model, moe.d_expert, moe.n_experts
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {"router": dense_init(ks[0], d, e, jnp.float32)}
    # per-expert weights: (E, d, f) / (E, f, d)
    p["w_gate"] = (jax.random.truncated_normal(
        ks[1], -2, 2, (e, d, f), jnp.float32) * d ** -0.5).astype(dtype)
    p["w_up"] = (jax.random.truncated_normal(
        ks[2], -2, 2, (e, d, f), jnp.float32) * d ** -0.5).astype(dtype)
    p["w_down"] = (jax.random.truncated_normal(
        ks[3], -2, 2, (e, f, d), jnp.float32) * f ** -0.5).astype(dtype)
    if moe.dense_residual_ff:
        p["dense"] = init_mlp(ks[4], cfg, d_ff=moe.dense_residual_ff)
    return p


def moe_capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    moe = cfg.moe
    c = int(tokens_per_group * moe.top_k * moe.capacity_factor
            / moe.n_experts)
    return max(moe.top_k, min(tokens_per_group, c))


def apply_moe(cfg: ModelConfig, p: dict, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (y, aux_loss).

    Dispatch is *per group* (= per batch row): capacity, slot cumsum,
    scatter and gather all carry the leading B dim, so under pjit every
    dispatch tensor stays sharded over the DP axes and expert buffers
    shard over (B x E) — without this, buffers at 1M-token global batch
    are O(100 GiB)/device (measured; see EXPERIMENTS.md §Perf).  Per-group
    capacity is also how real deployments route (per-device buffers).
    """
    moe = cfg.moe
    b, s, d = x.shape
    e, k = moe.n_experts, moe.top_k
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, choice = jax.lax.top_k(probs, k)                  # (B, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style), over all tokens
    density = jnp.mean(jax.nn.one_hot(choice[..., 0], e), axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * router_prob) * e

    cap = moe_capacity(s, cfg)
    onehot = jax.nn.one_hot(choice, e, dtype=jnp.int32)      # (B, S, k, E)
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                       # (B, S*k, E)
    slot = jnp.sum(pos * flat, axis=-1)                      # (B, S*k)
    e_flat = choice.reshape(b, s * k)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap)                      # overflow slot

    xin = jnp.broadcast_to(x[:, :, None], (b, s, k, d)).reshape(b, s * k, d)
    xin = (xin * keep[..., None]).astype(x.dtype)
    # GSPMD does not propagate batch sharding through batched
    # scatter/gather — without explicit constraints these buffers
    # all-gather over 'data' (measured: +100 GiB/dev on arctic train).
    xin = maybe_shard(xin, dp_spec(), None, None)

    def disp(xin_g, e_g, s_g):
        return jnp.zeros((e, cap + 1, d), x.dtype).at[e_g, s_g].add(xin_g)

    buf = jax.vmap(disp)(xin, e_flat, slot_c)[:, :, :cap]    # (B, E, C, d)
    buf = maybe_shard(buf, dp_spec(), "model", None, None)

    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = activation(cfg.act, g) * u
    h = maybe_shard(h, dp_spec(), "model", None, None)
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])   # (B, E, C, d)
    out_buf = jnp.pad(out_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))
    out_buf = maybe_shard(out_buf, dp_spec(), "model", None, None)

    def gather(ob_g, e_g, s_g):
        return ob_g[e_g, s_g]                                # (S*k, d)

    y_flat = jax.vmap(gather)(out_buf, e_flat, slot_c)
    y_flat = maybe_shard(y_flat, dp_spec(), None, None)
    w = (gates.reshape(b, s * k) * keep).astype(x.dtype)
    y = (y_flat * w[..., None]).reshape(b, s, k, d).sum(axis=2)
    if moe.dense_residual_ff:
        y = y + apply_mlp(cfg, p["dense"], x)
    return y, aux


def apply_moe_reference(cfg: ModelConfig, p: dict, x: jax.Array
                        ) -> jax.Array:
    """Dense oracle: every token through its top-k experts exactly (no
    capacity drops).  O(T*E) compute — tests only."""
    moe = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, choice = jax.lax.top_k(probs, moe.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # all-experts compute
    g = jnp.einsum("td,edf->etf", xt, p["w_gate"])
    u = jnp.einsum("td,edf->etf", xt, p["w_up"])
    h = activation(cfg.act, g) * u
    full = jnp.einsum("etf,efd->etd", h, p["w_down"])        # (E, T, d)
    sel = jnp.take_along_axis(
        full.transpose(1, 0, 2), choice[..., None], axis=1)  # (T, k, d)
    y = (sel * gates[..., None].astype(sel.dtype)).sum(axis=1)
    y = y.reshape(b, s, d).astype(x.dtype)
    if moe.dense_residual_ff:
        y = y + apply_mlp(cfg, p["dense"], x)
    return y
