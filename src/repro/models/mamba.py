"""Mamba block in SSD (Mamba-2 state-space-duality) form — for jamba.

Per-head scalar decay a_t = exp(-softplus(dt) * A_h) with data-dependent
dt; B_t/C_t projections play k/q; the recurrence is the shared
``linear_attention`` machinery.  DESIGN.md §Hardware-adaptation records why
the SSD form replaces Mamba-1's per-(channel, state) selective scan: the
per-head scalar decay tiles onto the MXU as plain matmuls, while the
Mamba-1 scan is a CUDA-specific kernel shape with no TPU analogue.

Decode state per layer: S (B, H, d_state, head_dim).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import dense_init
from .linear_attention import recurrent_scan, recurrent_step


def init_mamba(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ssm = cfg.ssm
    di = ssm.expand * d
    h = di // ssm.head_dim
    n = ssm.d_state
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], d, 2 * di, dtype),       # x and gate z
        "w_bc": dense_init(ks[1], d, 2 * h * n, dtype),    # B_t, C_t per head
        "w_dt": dense_init(ks[2], d, h, dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),             # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "w_out": dense_init(ks[3], di, d, dtype, scale=di ** -0.5),
    }


def _ssm_inputs(cfg: ModelConfig, p: dict, x: jax.Array):
    """Common projections.  x: (B, T, d) -> (xh, z, Bk, Cq, log_a)."""
    ssm = cfg.ssm
    b, t, d = x.shape
    di = ssm.expand * d
    h = di // ssm.head_dim
    n = ssm.d_state
    xz = x @ p["w_in"]
    xh, z = jnp.split(xz, 2, axis=-1)                      # (B, T, di)
    bc = x @ p["w_bc"]
    bk, cq = jnp.split(bc, 2, axis=-1)
    bk = bk.reshape(b, t, h, n)
    cq = cq.reshape(b, t, h, n)
    dt = jax.nn.softplus(
        (x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])  # (B, T, H)
    log_a = -dt * jnp.exp(p["a_log"])                       # <= 0
    xh = xh.reshape(b, t, h, ssm.head_dim)
    # discretized input scale: multiply v by dt (ZOH-style)
    v = xh * dt[..., None].astype(xh.dtype)
    return xh, z, bk, cq, v, log_a


def apply_mamba(cfg: ModelConfig, p: dict, x: jax.Array,
                state0: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, d).  Returns (out, final_state)."""
    ssm = cfg.ssm
    b, t, d = x.shape
    di = ssm.expand * d
    xh, z, bk, cq, v, log_a = _ssm_inputs(cfg, p, x)
    # scalar-per-head decay stays (B,T,H,1); the scan broadcasts lazily
    out, state = recurrent_scan(cq, bk, v, log_a[..., None], state0=state0,
                                rwkv_mode=False)            # (B,T,H,hd)
    out = out + xh * p["d_skip"][None, None, :, None].astype(xh.dtype)
    y = (out.reshape(b, t, di) * jax.nn.silu(z)) @ p["w_out"]
    return y, state


def apply_mamba_step(cfg: ModelConfig, p: dict, x: jax.Array,
                     state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Decode step.  x: (B, d); state: (B, H, d_state, head_dim)."""
    b, d = x.shape
    ssm = cfg.ssm
    di = ssm.expand * d
    xh, z, bk, cq, v, log_a = _ssm_inputs(cfg, p, x[:, None])
    out, state = recurrent_step(cq[:, 0], bk[:, 0], v[:, 0],
                                log_a[:, 0, :, None], state,
                                rwkv_mode=False)
    out = out + xh[:, 0] * p["d_skip"][None, :, None].astype(xh.dtype)
    y = (out.reshape(b, di) * jax.nn.silu(z[:, 0])) @ p["w_out"]
    return y, state
