"""Top-level model API: init / forward / loss / decode for every arch.

``Model`` bundles the pure functions the launchers and runtime consume:

* ``init(key)``                 -> params pytree
* ``forward(params, batch)``    -> logits (+ aux, + prefill KV caches)
* ``loss(params, batch)``       -> scalar (CE + MoE aux)
* ``init_decode_state(batch)``  -> KV/SSM caches + pos
* ``decode_step(params, state, tokens)`` -> (logits, new state)
* ``encode(params, frames)``    -> encoder memory (whisper)

Batches are dicts; see ``launch/specs.py`` for the exact per-(arch, shape)
input structures.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn
from . import mamba as mam
from . import rwkv as rwkv_mod
from .layers import (
    apply_norm,
    dense_init,
    init_norm,
    rope_freqs,
    sinusoidal_positions,
)
from .shard_utils import dp_spec, maybe_shard
from .transformer import (
    forward_stack,
    init_stack,
    n_periods,
    period_template,
)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    remat: str = "full"

    # ------------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 6)
        params: dict[str, Any] = {
            # d^-0.5 rows + sqrt(d) lookup scaling keeps tied-unembed
            # logits O(1) (Gemma-style)
            "embed": dense_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
            "blocks": init_stack(ks[1], cfg),
            "final_norm": init_norm(cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(ks[2], cfg.d_model,
                                           cfg.vocab_size, dtype)
        if cfg.encoder is not None:
            enc_cfg = dataclasses.replace(
                cfg, family="dense", n_layers=cfg.encoder.n_layers,
                attn_every=1, moe=None)
            params["encoder"] = {
                "blocks": init_stack(ks[3], enc_cfg),
                "final_norm": init_norm(cfg, cfg.d_model),
            }
        return params

    # ------------------------------------------------------------------
    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x * jnp.asarray(self.cfg.d_model ** 0.5, x.dtype)
        if x.ndim == 3:
            x = maybe_shard(x, dp_spec(), None, None)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(cfg, params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["unembed"]
        # vocab-parallel logits: keep V sharded over 'model' end to end
        if logits.ndim == 3:
            logits = maybe_shard(logits, dp_spec(), None, "model")
        else:
            logits = maybe_shard(logits, dp_spec(), "model")
        return logits

    def encode(self, params, frames: jax.Array) -> jax.Array:
        """Whisper encoder: frames (B, n_ctx, d) stub embeddings -> memory."""
        cfg = self.cfg
        enc_cfg = dataclasses.replace(
            cfg, family="dense", n_layers=cfg.encoder.n_layers,
            attn_every=1, moe=None)
        b, s, _ = frames.shape
        x = frames.astype(jnp.dtype(cfg.dtype))
        x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x, _, _ = forward_stack(enc_cfg, params["encoder"]["blocks"], x,
                                positions, causal=False, remat=self.remat)
        return apply_norm(cfg, params["encoder"]["final_norm"], x)

    # ------------------------------------------------------------------
    def forward(self, params, batch: dict, *, collect_cache: bool = False):
        """Train/prefill forward.  Returns (logits, aux, caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        cross_memory = None
        if cfg.encoder is not None:
            cross_memory = self.encode(params, batch["frames"])
        if cfg.rope_theta == 0.0 and cfg.encoder is not None:
            x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
        x, aux, caches = forward_stack(
            cfg, params["blocks"], x, positions, cross_memory=cross_memory,
            causal=True, collect_cache=collect_cache, remat=self.remat)
        return self._logits(params, x), aux, caches

    def loss(self, params, batch: dict) -> jax.Array:
        logits, aux, _ = self.forward(params, batch)
        labels = batch["labels"]
        # CE without gathering the (possibly vocab-sharded) logits: the
        # label logit comes from a one-hot contraction (psum under GSPMD),
        # never a take_along_axis over the sharded vocab dim.
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(labels, logits.shape[-1],
                                dtype=logits.dtype)
        label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
        ce = lse - label_logit
        mask = batch.get("loss_mask")
        if mask is not None:
            ce = ce * mask
            denom = jnp.maximum(mask.sum(), 1.0)
        else:
            denom = ce.size
        return ce.sum() / denom + 0.01 * aux

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def init_decode_state(self, batch_size: int, max_seq: int,
                          cross_memory: jax.Array | None = None) -> dict:
        cfg = self.cfg
        np_ = n_periods(cfg)
        template = period_template(cfg)
        dtype = jnp.dtype(cfg.dtype)
        # per-row positions: continuous batching gives every slot its own
        # clock (see runtime/serve_loop.py)
        state: dict[str, Any] = {
            "pos": jnp.zeros((batch_size,), jnp.int32)}
        n_attn = sum(1 for t in template if t.mixer == "attn")
        n_mamba = sum(1 for t in template if t.mixer == "mamba")
        n_rwkv = sum(1 for t in template if t.mixer == "rwkv")
        assert n_attn <= 1, "cache layout assumes <= 1 attn sublayer/period"
        if n_attn:
            kv_shape = (np_, batch_size, max_seq, cfg.n_kv_heads,
                        cfg.head_dim)
            kv_dt = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
            state["k_cache"] = jnp.zeros(kv_shape, kv_dt)
            state["v_cache"] = jnp.zeros(kv_shape, kv_dt)
        if n_mamba:
            h = cfg.ssm.expand * cfg.d_model // cfg.ssm.head_dim
            state["ssm"] = jnp.zeros(
                (np_, n_mamba, batch_size, h, cfg.ssm.d_state,
                 cfg.ssm.head_dim), jnp.float32)
        if n_rwkv:
            h = cfg.d_model // cfg.rwkv.head_dim
            state["rwkv"] = jnp.zeros(
                (np_, batch_size, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim),
                jnp.float32)
            state["shift_t"] = jnp.zeros((np_, batch_size, cfg.d_model),
                                         dtype)
            state["shift_c"] = jnp.zeros((np_, batch_size, cfg.d_model),
                                         dtype)
        del cross_memory   # cross K/V handled via precompute_cross_kv
        return state

    def precompute_cross_kv(self, params, memory: jax.Array):
        """(n_periods, B, ctx, Hkv, hd) x2 from encoder memory."""
        cfg = self.cfg
        cross_stacked = params["blocks"][0]["cross"]   # encdec has P=1
        return jax.vmap(
            lambda pp: attn.cross_kv(cfg, pp, memory))(cross_stacked)

    def decode_step(self, params, state: dict, tokens: jax.Array,
                    cross_kv: tuple[jax.Array, jax.Array] | None = None
                    ) -> tuple[jax.Array, dict]:
        """One decode step.  tokens: (B,) int32.  Returns (logits, state)."""
        cfg = self.cfg
        template = period_template(cfg)
        inv_freq = rope_freqs(cfg)
        pos = state["pos"]                             # (B,)
        x = self._embed(params, tokens)[:, None]       # (B, 1, d)
        if cfg.rope_theta == 0.0 and cfg.encoder is not None:
            tab = sinusoidal_positions(cfg.max_seq_len, cfg.d_model)
            x = x + jnp.take(tab, pos, axis=0).astype(x.dtype)[:, None]

        xs: dict[str, Any] = {"blocks": params["blocks"]}
        for key in ("k_cache", "v_cache", "ssm", "rwkv", "shift_t",
                    "shift_c"):
            if key in state:
                xs[key] = state[key]
        if cross_kv is not None:
            xs["cross_kv"] = cross_kv

        def period_fn(carry, inp):
            x = carry
            new = dict(inp)
            for si, spec in enumerate(template):
                p = inp["blocks"][si]
                h = apply_norm(cfg, p["norm1"], x)
                if spec.mixer == "attn":
                    y, k_new, v_new = attn.attention_decode_block(
                        cfg, p["attn"], h, inp["k_cache"], inp["v_cache"],
                        pos, inv_freq)
                    new["k_cache"], new["v_cache"] = k_new, v_new
                    x = x + y
                elif spec.mixer == "mamba":
                    mi = sum(1 for t in template[:si] if t.mixer == "mamba")
                    y, s_new = mam.apply_mamba_step(
                        cfg, p["mamba"], h[:, 0], inp["ssm"][mi])
                    new["ssm"] = new["ssm"].at[mi].set(s_new)
                    x = x + y[:, None].astype(x.dtype)
                elif spec.mixer == "rwkv":
                    y, s_new, sh = rwkv_mod.apply_rwkv_time_mix_step(
                        cfg, p["rwkv_t"], h[:, 0], inp["shift_t"],
                        inp["rwkv"])
                    new["rwkv"], new["shift_t"] = s_new, sh
                    x = x + y[:, None].astype(x.dtype)
                if spec.cross and "cross_kv" in inp:
                    hc = apply_norm(cfg, p["norm_cross"], x)
                    x = x + attn.cross_attention_block(
                        cfg, p["cross"], hc, kv=inp["cross_kv"])
                h2 = apply_norm(cfg, p["norm2"], x)
                if spec.ffn == "mlp":
                    from .layers import apply_mlp
                    x = x + apply_mlp(cfg, p["mlp"], h2)
                elif spec.ffn == "moe":
                    from .moe import apply_moe
                    y, _ = apply_moe(cfg, p["moe"], h2)
                    x = x + y
                elif spec.ffn == "rwkv_channel":
                    y, sh = rwkv_mod.apply_rwkv_channel_mix_step(
                        cfg, p["rwkv_c"], h2[:, 0], inp["shift_c"])
                    new["shift_c"] = sh
                    x = x + y[:, None].astype(x.dtype)
            new.pop("blocks")
            new.pop("cross_kv", None)
            return x, new

        x, new_caches = jax.lax.scan(period_fn, x, xs)
        logits = self._logits(params, x)[:, 0]         # (B, V)
        new_state = dict(state)
        new_state.update(new_caches)
        new_state["pos"] = pos + 1
        return logits, new_state
