"""Quantized decode path: serve with Iris-organized packed weights.

End-to-end instantiation of the paper for LM serving (dense-family archs):

1. ``repro.api.pack_tree`` quantizes every per-layer weight matrix to
   intN (group scales), plans the per-layer Iris stream layout and packs
   both the unified HBM stream buffers and the lane-packed uint32 kernel
   views into one :class:`~repro.tree.PackedTree` pytree;
2. ``packed_decode_step`` consumes the tree's kernel views directly via
   the dequant-on-load Pallas matmul (``kernels.packed_matmul``) — dense
   bf16 weights never exist in memory.

This module owns only the *decode math*; all pack/plan wiring lives
behind ``repro.api.pack_tree``.  ``PackedParams`` and
``quantize_params`` survive as deprecated aliases of the new surface.
Exercised by examples/packed_serving.py and
tests/test_quantized_serving.py, with bytes-moved accounting vs the bf16
and padded-int baselines.
"""
from __future__ import annotations

import warnings
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # pragma: no cover
    from repro.tree import PackedTree

from repro.configs.base import ModelConfig
from repro.kernels.packed_matmul import packed_matmul
from repro.quant.qtypes import QuantSpec

from .layers import activation, apply_norm, rope_freqs
from .transformer import n_periods, period_template


def quantizable(cfg: ModelConfig) -> bool:
    """The packed decode path covers the dense sublayer template."""
    t = period_template(cfg)
    return (len(t) == 1 and t[0].mixer == "attn" and t[0].ffn == "mlp"
            and not t[0].cross)


def quantize_params(cfg: ModelConfig, params: dict, spec: QuantSpec):
    """Deprecated: use :func:`repro.api.pack_tree`.

    Thin wrapper kept for pre-``PackedTree`` callers; returns a
    :class:`~repro.tree.PackedTree` (field-compatible with the old
    ``PackedParams``: ``.packed`` / ``.scales`` / ``.other`` / ``.spec``
    / ``.shapes``), built without stream buffers.
    """
    warnings.warn(
        "quantize_params is deprecated; use repro.api.pack_tree(cfg, "
        "params, spec), which also plans and packs the Iris stream "
        "buffers", DeprecationWarning, stacklevel=2,
    )
    from repro import api

    return api.pack_tree(cfg, params, spec, with_streams=False)


def __getattr__(name: str):
    if name == "PackedParams":
        # deprecated alias of the pytree front door
        from repro.tree import _warn_packed_params

        return _warn_packed_params()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _pmm(x2d, pw, sc, spec, interpret):
    """x2d: (B, K) @ packed (K*bits/32, N) -> (B, N).  Pads B to the MXU
    tile, K blocks to the group size."""
    b, k = x2d.shape
    bm = max(8, 1 << (b - 1).bit_length())
    if bm != b:
        x2d = jnp.pad(x2d, ((0, bm - b), (0, 0)))
    n = pw.shape[1]
    out = packed_matmul(
        x2d, pw, sc, bits=spec.bits, group_size=spec.group_size,
        block_m=bm, block_n=min(128, n), block_k=min(512, k),
        interpret=interpret)
    return out[:b]


def _pmm_direct(x2d, pp, name, layer, interpret, words=None):
    """Stream-direct twin of :func:`_pmm`: same B padding and block
    choices, but the weights are gathered straight from the layer's
    packed Iris stream (``kernels.stream_matmul``) — no lane-packed
    kernel view, no dense intermediate, any element width <= 32.
    ``words`` optionally supplies the layer's stream word view from an
    external stage (see :meth:`~repro.tree.PackedTree.matmul_direct`)."""
    b, k = x2d.shape
    bm = max(8, 1 << (b - 1).bit_length())
    if bm != b:
        x2d = jnp.pad(x2d, ((0, bm - b), (0, 0)))
    n = pp.shapes[name][1]
    out = pp.matmul_direct(
        x2d, name, layer, interpret=interpret, words=words,
        block_m=bm, block_n=min(128, n), block_k=min(512, k))
    return out[:b]


def packed_decode_step(cfg: ModelConfig, pp: "PackedTree", state: dict,
                       tokens: jax.Array, *, interpret: bool = True,
                       weights: str = "auto", slot_ids=None,
                       stream_source=None, kv: str = "dense",
                       kv_attention: str = "stream"
                       ) -> tuple[jax.Array, dict]:
    """One decode token with dequant-on-load weights (dense archs).

    ``pp`` is the :class:`~repro.tree.PackedTree` built by
    ``repro.api.pack_tree``.  Mirrors Model.decode_step but every large
    matmul reads packed codes.

    ``weights`` selects the matmul operand source: ``"packed"`` reads
    the lane-packed kernel views (two-pass legacy path, bits in
    ``SUPPORTED_BITS`` only), ``"stream"`` gathers straight from the
    per-layer Iris stream buffers (stream-direct, any bits <= 32),
    ``"auto"`` uses the kernel views when the tree has them and falls
    back to stream-direct otherwise — which is how int3/int5/int6/int7
    trees serve end-to-end.

    ``slot_ids`` enables ragged-M stepping for the continuous-batching
    engine: an int array of the *active* cache rows, aligned with
    ``tokens`` (shape ``(M,)`` for M active slots, M <= cache batch).
    Only those rows' KV entries and clocks advance; matmul M equals the
    active count (padded to the kernel tile internally), so half-empty
    batches cost half-size matmuls.  ``None`` keeps the legacy
    full-batch semantics (``tokens`` spans every cache row and every
    row's clock ticks).  Because every per-row computation is
    independent, a row's results are bit-identical either way.

    ``stream_source`` (stream path only) maps a layer index to that
    layer's uint32 stream word view — e.g. a
    :class:`~repro.engine.streams.StreamUploader` staging host->device
    uploads ahead of compute.  ``None`` reads the tree's resident
    buffers.

    ``kv`` selects the cache representation: ``"dense"`` keeps the
    legacy bf16 ``k_cache`` / ``v_cache`` tensors; ``"packed"`` streams
    K/V through the Iris-planned :class:`~repro.kvcache.PackedKVCache`
    carried in ``state["packed_kv"]`` — appends write packed token
    pages, and attention consumes them via the stream-direct Pallas
    kernel (``kv_attention="stream"``) or the materialized dequant
    oracle (``kv_attention="dense"``, bit-identical by construction).
    """
    from . import attention as attn

    if weights not in ("auto", "packed", "stream"):
        raise ValueError(
            f"weights must be 'auto', 'packed' or 'stream'; got {weights!r}"
        )
    if kv not in ("dense", "packed"):
        raise ValueError(f"kv must be 'dense' or 'packed'; got {kv!r}")
    if kv_attention not in ("stream", "dense"):
        raise ValueError(
            f"kv_attention must be 'stream' or 'dense'; got {kv_attention!r}"
        )
    kvc = None
    if kv == "packed":
        kvc = state.get("packed_kv")
        if kvc is None:
            raise ValueError(
                "kv='packed' needs a PackedKVCache in state['packed_kv'] "
                "(see repro.kvcache.PackedKVCache.create)"
            )
    use_stream = weights == "stream" or (weights == "auto" and not pp.packed)
    if weights == "packed" and not pp.packed:
        raise ValueError(
            "tree has no lane-packed kernel views (built with "
            "with_kernel_views=False); serve with weights='stream'"
        )
    if use_stream and pp.streams is None and stream_source is None:
        raise ValueError(
            "tree has no stream buffers (built with with_streams=False); "
            "serve with weights='packed' or supply stream_source"
        )
    if stream_source is not None and not use_stream:
        raise ValueError(
            "stream_source only applies to the stream-direct path "
            "(weights='stream', or 'auto' on a kernel-view-free tree)"
        )
    spec = pp.spec
    inv_freq = rope_freqs(cfg)
    b = tokens.shape[0]
    if slot_ids is not None and slot_ids.shape[0] != b:
        raise ValueError(
            f"slot_ids has {slot_ids.shape[0]} rows but tokens has {b}"
        )
    rows = jnp.arange(b) if slot_ids is None else slot_ids
    pos = state["pos"] if slot_ids is None else state["pos"][rows]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = jnp.take(pp.other["embed"], tokens, axis=0) \
        * jnp.asarray(cfg.d_model ** 0.5, pp.other["embed"].dtype)

    def mm(name, period, x2d, words=None):
        if use_stream:
            return _pmm_direct(x2d.astype(jnp.float32), pp, name, period,
                               interpret, words=words)
        return _pmm(x2d.astype(jnp.float32), pp.packed[name][period],
                    pp.scales[name][period], spec, interpret)

    np_ = n_periods(cfg)
    k_cache, v_cache = state["k_cache"], state["v_cache"]
    new_k, new_v = [], []
    for layer in range(np_):
        words = stream_source(layer) if stream_source is not None else None
        hnorm = apply_norm(cfg, jax.tree.map(lambda a: a[layer],
                                             pp.other["norm1"]), x)
        q = mm("attn/wq", layer, hnorm, words).reshape(b, 1, h, hd)
        kk = mm("attn/wk", layer, hnorm, words).reshape(b, 1, hkv, hd)
        vv = mm("attn/wv", layer, hnorm, words).reshape(b, 1, hkv, hd)
        if cfg.use_bias:
            q = q + pp.other["attn/bq"][layer].reshape(1, 1, h, hd)
            kk = kk + pp.other["attn/bk"][layer].reshape(1, 1, hkv, hd)
            vv = vv + pp.other["attn/bv"][layer].reshape(1, 1, hkv, hd)
        pos_b = pos[:, None]
        q = attn.apply_rope(q, pos_b, inv_freq, cfg.mrope_sections)
        kk = attn.apply_rope(kk, pos_b, inv_freq, cfg.mrope_sections)
        if kvc is not None:
            kvc = kvc.append(kk[:, 0], vv[:, 0], pos, rows, layer=layer)
            att = attn.stream_decode_attention(
                kvc, q.astype(jnp.bfloat16), pos, rows, layer=layer,
                oracle=kv_attention == "dense", interpret=interpret)
        else:
            kc = k_cache[layer].at[rows, pos].set(
                kk[:, 0].astype(k_cache.dtype))
            vc = v_cache[layer].at[rows, pos].set(
                vv[:, 0].astype(v_cache.dtype))
            new_k.append(kc)
            new_v.append(vc)
            att = attn.decode_attention(q.astype(jnp.bfloat16), kc[rows],
                                        vc[rows], pos)
        y = mm("attn/wo", layer, att.reshape(b, h * hd), words)
        if cfg.use_bias:
            y = y + pp.other["attn/bo"][layer]
        x = x + y.astype(x.dtype)
        h2 = apply_norm(cfg, jax.tree.map(lambda a: a[layer],
                                          pp.other["norm2"]), x)
        g = mm("mlp/w_gate", layer, h2, words)
        u = mm("mlp/w_up", layer, h2, words)
        if cfg.use_bias:
            g = g + pp.other["mlp/b_gate"][layer]
            u = u + pp.other["mlp/b_up"][layer]
        hh = activation(cfg.act, g) * u
        y2 = mm("mlp/w_down", layer, hh, words)
        if cfg.use_bias:
            y2 = y2 + pp.other["mlp/b_down"][layer]
        x = x + y2.astype(x.dtype)

    x = apply_norm(cfg, pp.other["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ pp.other["embed"].T
    else:
        logits = x @ pp.other["unembed"]
    new_state = dict(state)
    if kvc is not None:
        new_state["packed_kv"] = kvc
    else:
        new_state["k_cache"] = jnp.stack(new_k)
        new_state["v_cache"] = jnp.stack(new_v)
    if slot_ids is None:
        new_state["pos"] = pos + 1
    else:
        new_state["pos"] = state["pos"].at[rows].add(1)
    return logits, new_state


def bytes_per_token_report(cfg: ModelConfig, pp: "PackedTree") -> dict:
    """Weight bytes streamed per decode token: packed vs baselines."""
    n_elems = sum(int(jnp.prod(jnp.array(s)) * n_periods(cfg))
                  for s in pp.shapes.values())
    if pp.packed:
        packed_b = pp.hbm_bytes()
    else:
        # stream-direct tree: the per-layer Iris stream *is* the serving
        # weight storage (scales ride inside it)
        packed_b = pp.stream_bytes + sum(
            int(jnp.size(x)) * x.dtype.itemsize
            for x in jax.tree.leaves(pp.other))
    pad_bits = 8 if pp.spec.bits > 4 else (4 if pp.spec.bits > 2 else 2)
    pad_bits = max(pad_bits, 1 << (pp.spec.bits - 1).bit_length())
    return {
        "packed_MiB": packed_b / 2**20,
        "bf16_MiB": (n_elems * 2
                     + sum(int(x.size) * x.dtype.itemsize
                           for x in jax.tree.leaves(pp.other))) / 2**20,
        "padded_int_MiB": (n_elems * pad_bits / 8) / 2**20,
        "quantized_elems": n_elems,
    }
