"""RWKV-6 ("Finch") time-mix and channel-mix blocks (arXiv:2404.05892).

Attention-free: the time-mix is linear attention with a *data-dependent
per-channel decay* w_t = exp(-exp(w0 + tanh(x A) B)) (the signature Finch
feature) plus the 'bonus' u for the current token.  Token-shift
interpolation and output gating follow the reference implementation; the
decay LoRA rank is configurable.

The recurrence runs through ``linear_attention.recurrent_scan`` (train)
and ``recurrent_step`` (decode).  Decode state per layer:
(shift_x (B, d), shift_c (B, d), S (B, H, dk, dk)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import dense_init
from .linear_attention import recurrent_scan, recurrent_step


def init_rwkv_time_mix(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    h = d // hd
    r = cfg.rwkv.decay_lora
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "w_r": dense_init(ks[0], d, d, dtype),
        "w_k": dense_init(ks[1], d, d, dtype),
        "w_v": dense_init(ks[2], d, d, dtype),
        "w_g": dense_init(ks[3], d, d, dtype),
        "w_o": dense_init(ks[4], d, d, dtype, scale=d ** -0.5),
        # data-dependent decay LoRA: w = w0 + tanh(x A) B
        "decay_a": dense_init(ks[5], d, r, dtype),
        "decay_b": dense_init(ks[6], r, d, dtype, scale=r ** -0.5),
        "decay_w0": jnp.full((d,), -2.0, jnp.float32),
        "bonus_u": jnp.zeros((h, hd), jnp.float32),
        # token-shift mixing coefficients per projection
        "mix": jnp.full((5, d), 0.5, jnp.float32),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x_{t-1} stream: shift right by one token; position 0 sees `prev`."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _decay_log(p: dict, xm: jax.Array) -> jax.Array:
    """log w_t = -exp(w0 + tanh(x A) B) in (-inf, 0) — Finch decay."""
    lora = jnp.tanh(xm @ p["decay_a"]) @ p["decay_b"]
    return -jnp.exp(p["decay_w0"] + lora.astype(jnp.float32))


def apply_rwkv_time_mix(cfg: ModelConfig, p: dict, x: jax.Array,
                        prev_shift: jax.Array | None = None,
                        state0: jax.Array | None = None
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, T, d).  Returns (out, final_state, last_x) for streaming."""
    b, t, d = x.shape
    hd = cfg.rwkv.head_dim
    h = d // hd
    xs = _token_shift(x, prev_shift)
    mixed = [x + p["mix"][i].astype(x.dtype) * (xs - x) for i in range(5)]
    rm, km, vm, gm, wm = mixed
    rr = (rm @ p["w_r"]).reshape(b, t, h, hd)
    kk = (km @ p["w_k"]).reshape(b, t, h, hd)
    vv = (vm @ p["w_v"]).reshape(b, t, h, hd)
    gg = jax.nn.silu(gm @ p["w_g"])
    logw = _decay_log(p, wm).reshape(b, t, h, hd)
    out, state = recurrent_scan(rr, kk, vv, logw, u=p["bonus_u"],
                                state0=state0, rwkv_mode=True)
    y = (out.reshape(b, t, d) * gg) @ p["w_o"]
    return y, state, x[:, -1]


def apply_rwkv_time_mix_step(cfg: ModelConfig, p: dict, x: jax.Array,
                             shift_prev: jax.Array, state: jax.Array
                             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Decode step.  x: (B, d); shift_prev: (B, d); state: (B,H,dk,dk)."""
    b, d = x.shape
    hd = cfg.rwkv.head_dim
    h = d // hd
    mixed = [x + p["mix"][i].astype(x.dtype) * (shift_prev - x)
             for i in range(5)]
    rm, km, vm, gm, wm = mixed
    rr = (rm @ p["w_r"]).reshape(b, h, hd)
    kk = (km @ p["w_k"]).reshape(b, h, hd)
    vv = (vm @ p["w_v"]).reshape(b, h, hd)
    gg = jax.nn.silu(gm @ p["w_g"])
    logw = _decay_log(p, wm).reshape(b, h, hd)
    out, state = recurrent_step(rr, kk, vv, logw, state, u=p["bonus_u"],
                                rwkv_mode=True)
    y = (out.reshape(b, d) * gg) @ p["w_o"]
    return y, state, x


def init_rwkv_channel_mix(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    return {
        "w_k": dense_init(k1, d, f, dtype),
        "w_v": dense_init(k2, f, d, dtype, scale=f ** -0.5),
        "mix": jnp.full((1, d), 0.5, jnp.float32),
    }


def apply_rwkv_channel_mix(cfg: ModelConfig, p: dict, x: jax.Array,
                           prev_shift: jax.Array | None = None
                           ) -> tuple[jax.Array, jax.Array]:
    xs = _token_shift(x, prev_shift)
    km = x + p["mix"][0].astype(x.dtype) * (xs - x)
    h = jnp.square(jax.nn.relu(km @ p["w_k"]))
    return h @ p["w_v"], x[:, -1]


def apply_rwkv_channel_mix_step(cfg: ModelConfig, p: dict, x: jax.Array,
                                shift_prev: jax.Array
                                ) -> tuple[jax.Array, jax.Array]:
    km = x + p["mix"][0].astype(x.dtype) * (shift_prev - x)
    h = jnp.square(jax.nn.relu(km @ p["w_k"]))
    return h @ p["w_v"], x
