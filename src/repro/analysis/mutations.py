"""Mutation harness: seeded corruptions the analyzer must catch.

The analyzer's soundness claim is falsifiable: for each corruption class
below there is a mutator that plants exactly that defect into a copy of
a lowered :class:`~repro.core.exec_plan.ExecProgram` or a packed
checkpoint's (manifest, streams, digest) triple, and a registry entry
naming the rule(s) that must fire as **error** findings.  The test suite
(``tests/test_analysis.py``) runs every class and asserts detection —
if a pass is weakened, the corresponding mutation goes green-on-garbage
and the test fails.

Program-table classes (mutate the lowered tables in place):

=================  ====================================================
``overlap``        two pieces claim the same destination bits
``oob-word``       a destination word index outside the buffer
``wrong-shift``    a shift that pushes a piece past the bus row into
                   the u64-pack row padding (the row-seam defect)
``kernel-width``   a slot-table width field > 32 (funnel-illegal)
``kernel-oob``     a slot-table bit offset past the bus row
``gather-dup``     two gather lanes decoding from the same grid slot
=================  ====================================================

Checkpoint classes (mutate manifest dict / stream bytes / digest):

====================  =================================================
``coverage-gap``      count-intervals drop elements of one array
``signature-tamper``  manifest signature no longer matches its bundle
``truncated-stream``  stream buffer short of manifest byte-lengths
``stream-bit-flip``   one flipped stream bit (content digest mismatch)
``cmax-skew``         manifest c_max disagrees with intervals/streams
``shape-skew``        a tensor shape exceeding its scheduled capacity
====================  =================================================

All mutators return **copies**; the input program/manifest/streams are
never modified (programs are memoized on their layout).
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.exec_plan import _TAB_WIDTH_SHIFT, ExecProgram, KernelTable

#: program-table corruption class -> rule ids, at least one of which
#: must appear as an ERROR finding
PROGRAM_MUTATIONS: dict[str, tuple[str, ...]] = {
    "overlap": ("program/overlap",),
    "oob-word": ("program/oob-word",),
    "wrong-shift": ("program/row-seam",),
    "kernel-width": ("kernel/width",),
    "kernel-oob": ("kernel/oob",),
    "gather-dup": ("kernel/gather-dup",),
}

#: checkpoint corruption class -> rule ids (same contract)
CHECKPOINT_MUTATIONS: dict[str, tuple[str, ...]] = {
    "coverage-gap": ("manifest/intervals",),
    "signature-tamper": ("manifest/signature",),
    "truncated-stream": ("manifest/stream-shape",),
    "stream-bit-flip": ("manifest/stream-digest",),
    "cmax-skew": ("manifest/c-max", "manifest/stream-shape"),
    "shape-skew": ("manifest/shapes",),
}


def _copy_program(prog: ExecProgram) -> ExecProgram:
    """Replace the mutable tables with fresh copies (cheap, targeted)."""
    kt = prog.kernel
    return dataclasses.replace(
        prog,
        word=prog.word.copy(),
        shift=prog.shift.copy(),
        kernel=KernelTable(
            words32=kt.words32, lanes=kt.lanes, tab=kt.tab.copy(),
            gathers=tuple((i, g.copy()) for i, g in kt.gathers)),
        jit_cache={},
    )


def _pick_piece(prog: ExecProgram, *, min_width: int = 1,
                min_depth: int = 1) -> int:
    """Piece index of the widest array meeting the constraints."""
    best, best_w = -1, -1
    for i, ew in enumerate(prog.elem_widths):
        if ew >= min_width and prog.piece_depths[i] >= min_depth \
                and ew > best_w:
            best, best_w = i, ew
    if best < 0:
        raise ValueError(
            f"no array with width >= {min_width} and depth >= {min_depth}"
        )
    return prog.piece_base[best]


def corrupt_program(prog: ExecProgram, kind: str) -> ExecProgram:
    """Return a copy of ``prog`` with corruption class ``kind`` planted."""
    if kind not in PROGRAM_MUTATIONS:
        raise KeyError(
            f"unknown program mutation {kind!r}; "
            f"have {sorted(PROGRAM_MUTATIONS)}"
        )
    mut = _copy_program(prog)
    if kind == "overlap":
        j = _pick_piece(prog, min_depth=2)
        mut.word[j + 1] = mut.word[j]
        mut.shift[j + 1] = mut.shift[j]
    elif kind == "oob-word":
        j = _pick_piece(prog)
        mut.word[j] = prog.c_max * prog.wpr + 3
    elif kind == "wrong-shift":
        # park the piece at the very last bit of its row: bit_in_row
        # becomes wpr*64 - 1 >= m - 1, so width >= 2 crosses the seam
        j = _pick_piece(prog, min_width=2)
        row = int(mut.word[j]) // prog.wpr
        mut.word[j] = row * prog.wpr + (prog.wpr - 1)
        mut.shift[j] = 63
    elif kind == "kernel-width":
        r, c = _first_slot(mut.kernel)
        off = int(mut.kernel.tab[r, c]) & ((1 << _TAB_WIDTH_SHIFT) - 1)
        mut.kernel.tab[r, c] = np.uint32(off | (33 << _TAB_WIDTH_SHIFT))
    elif kind == "kernel-oob":
        r, c = _first_slot(mut.kernel)
        w = int(mut.kernel.tab[r, c]) >> _TAB_WIDTH_SHIFT
        mut.kernel.tab[r, c] = np.uint32(prog.m | (w << _TAB_WIDTH_SHIFT))
    elif kind == "gather-dup":
        for _i, g in mut.kernel.gathers:
            if g.shape[0] >= 2:
                g[1] = g[0]
                break
        else:
            raise ValueError("no gather with >= 2 lanes to duplicate")
    return mut


def _first_slot(kt: KernelTable) -> tuple[int, int]:
    rows, cols = np.nonzero(kt.tab)
    if not rows.size:
        raise ValueError("kernel table has no occupied slots")
    return int(rows[0]), int(cols[0])


def corrupt_checkpoint(manifest_dict: dict, streams: np.ndarray,
                       digest: str, kind: str,
                       ) -> tuple[dict, np.ndarray, str]:
    """Plant checkpoint corruption ``kind``; returns fresh
    ``(manifest_dict, streams, digest)`` (inputs untouched)."""
    if kind not in CHECKPOINT_MUTATIONS:
        raise KeyError(
            f"unknown checkpoint mutation {kind!r}; "
            f"have {sorted(CHECKPOINT_MUTATIONS)}"
        )
    # JSON round-trip: deep copy + normalize tuples to mutable lists
    # (exactly the form a checkpoint stores the manifest in)
    d = json.loads(json.dumps(manifest_dict))
    streams = np.array(streams)
    if kind == "coverage-gap":
        for iv in d["intervals"]:
            counts = iv[1]
            if counts:
                counts[-1] = [counts[-1][0], counts[-1][1] - 1]
                break
    elif kind == "signature-tamper":
        d["signature"] = [d["signature"][0] + 8, *d["signature"][1:]]
    elif kind == "truncated-stream":
        streams = streams[:, :, :-4]
    elif kind == "stream-bit-flip":
        streams.flat[0] ^= np.uint8(1)
    elif kind == "cmax-skew":
        d["c_max"] += 1
    elif kind == "shape-skew":
        name, (k, n) = d["shapes"][0]
        d["shapes"][0] = [name, [k * 2, n]]
    return d, streams, digest
