"""The shared verification problem suite.

One source of truth for the deterministic problems that both the test
suite (``tests/conftest.py`` re-exports these) and the ``analysis-gate``
CI job iterate: the paper's §4 worked example, non-power-of-two
widths/bus, lane-capped arrays, >32-bit host-fallback widths, and
multi-interval many-release schedules.  The gate runs **every registered
strategy** over every problem here and fails on any error finding —
so a scheduler or lowering regression that produces an unsound layout
is caught by the static analyzer before any kernel executes it.
"""
from __future__ import annotations

from repro.core.task import PAPER_EXAMPLE, LayoutProblem, make_problem

#: §4 worked example, non-power-of-two widths/bus, lane-capped, and a
#: multi-interval many-release problem — the equivalence-test axes
#: shared by test_exec_plan.py and the golden-file suite
EXEC_PROBLEMS: list[LayoutProblem] = [
    PAPER_EXAMPLE,
    make_problem(40, [("a", 3, 41, 4), ("b", 5, 33, 9), ("c", 7, 17, 9)]),
    make_problem(72, [("a", 9, 100, 10), ("b", 12, 50, 3),
                      ("c", 33, 20, 20), ("d", 64, 8, 20)]),
    make_problem(256, [("u", 64, 131, 33), ("S", 64, 21, 3),
                       ("D", 64, 131, 36)], max_lanes=2),
    make_problem(128, [("q", 4, 257, 2), ("s", 16, 31, 2), ("b", 32, 9, 5)]),
]

#: mixed-width kernel-decode problems shared with test_kernels.py
DECODE_PROBLEMS: list[LayoutProblem] = [
    make_problem(32, [("a", 3, 40, 4), ("b", 5, 33, 9), ("c", 8, 17, 9)]),
    make_problem(64, [("a", 7, 100, 10), ("b", 12, 50, 3),
                      ("c", 17, 20, 20), ("d", 32, 8, 20)]),
    make_problem(128, [("q", 4, 257, 2), ("s", 16, 31, 2),
                       ("b", 32, 9, 5)]),
]

#: the golden-file canonical problem (small enough to check in its
#: lowered tables verbatim)
GOLDEN_PROBLEM: LayoutProblem = DECODE_PROBLEMS[0]

#: everything the analysis-gate iterates (strategy x problem)
GATE_PROBLEMS: list[LayoutProblem] = [*EXEC_PROBLEMS, *DECODE_PROBLEMS]
