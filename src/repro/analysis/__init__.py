"""repro.analysis — static layout verifier and bandwidth-efficiency lint.

The paper treats a data layout as a provable object: every element's bit
interval is statically known, so unsoundness (overlap, gaps, OOB words,
illegal extractions) and inefficiency (wasted bus bits, padding) are
decidable **without executing anything**.  This package is that checker:
a pass-based analyzer over :class:`~repro.core.layout.Layout`,
:class:`~repro.core.exec_plan.ExecProgram`, stream tables and
:class:`~repro.tree.LayoutManifest`, reporting structured
:class:`Finding` objects instead of asserting.

Entry points (all return a :class:`Report`; none raises unless asked):

* :func:`verify_layout` — schedule-level + lowered-table proof for one
  layout (``Plan.verify()`` routes here).
* :func:`verify_program` — lowered tables only, no re-lowering; what the
  mutation harness drives (a corrupted table must not be "fixed" by
  re-deriving it).
* :func:`verify_manifest` — checkpoint-grade consistency: manifest vs
  bundle vs intervals vs stream byte-lengths vs content digest
  (``restore_packed`` runs this before rebinding).
* :func:`verify_tree` — a whole :class:`~repro.tree.PackedTree`
  (``PackedTree.verify()`` routes here).
* :func:`verify_kvcache` — a :class:`~repro.kvcache.PackedKVCache`:
  layout + tables proof plus the mutable-stream checks (token write-mask
  disjointness/coverage, page geometry and digest, per-page append
  idempotence).  ``PackedKVCache.verify()`` routes here, and
  ``verify_packed``/``restore_kv`` on the checkpoint manager extend the
  gate to KV pages stored on disk.

The package imports numpy only; JAX-side objects (manifests, trees) are
consumed duck-typed so the CLI and CI gate run without a device.
"""
from __future__ import annotations

from typing import Any, Iterable

import numpy as np

from repro.core.exec_plan import ExecProgram, lower_exec
from repro.core.layout import Layout

from .findings import AnalysisError, Finding, Report, Severity
from .passes import (
    DEFAULT_B_EFF_WARN,
    DEFAULT_PAD_WARN,
    PASSES,
    AnalysisContext,
    run_passes,
    stream_sha256,
)

__all__ = [
    "AnalysisContext", "AnalysisError", "Finding", "Report", "Severity",
    "PASSES", "run_passes", "stream_sha256",
    "DEFAULT_B_EFF_WARN", "DEFAULT_PAD_WARN",
    "LAYOUT_ONLY_PASSES", "KVCACHE_PASSES",
    "verify_layout", "verify_layout_fast", "verify_program",
    "verify_manifest", "verify_tree", "verify_kvcache",
]

#: Passes that consume the layout alone — no ExecProgram, no lowering.
LAYOUT_ONLY_PASSES: tuple[str, ...] = ("interval", "bandwidth")

#: Passes a packed KV-cache runs: the weight-tree ``manifest`` pass is
#: replaced by the KV-specific one (a KVManifest has no count-intervals
#: or quant-group shapes to check).
KVCACHE_PASSES: tuple[str, ...] = (
    "interval", "program", "kernel", "stream", "extraction", "bandwidth",
    "kvcache",
)


def verify_layout_fast(layout: Layout, *, subject: str = "",
                       b_eff_warn: float = DEFAULT_B_EFF_WARN) -> Report:
    """Layout-only verification: the interval-legality and bandwidth
    passes, skipping exec lowering entirely.

    Lowering costs seconds on model-scale layouts; this path is
    O(intervals) and is what the persistent
    :class:`~repro.core.iris.LayoutCache` tier runs on every load before
    an entry is trusted (millisecond budget per signature).
    """
    ctx = AnalysisContext(layout=layout, b_eff_warn=b_eff_warn)
    return run_passes(ctx, LAYOUT_ONLY_PASSES, subject=subject or "layout")


def verify_layout(layout: Layout, *,
                  program: ExecProgram | None = None,
                  elem_widths: tuple[int, ...] | None = None,
                  passes: Iterable[str] | None = None,
                  subject: str = "",
                  b_eff_warn: float = DEFAULT_B_EFF_WARN) -> Report:
    """Statically verify one layout and its lowered tables.

    Lowers the layout (memoized on it) unless ``program`` is supplied.
    A layout that cannot even be lowered is itself a finding
    (``program/lowering``), not an exception.
    """
    report = Report(subject=subject or "layout")
    if program is None:
        try:
            program = lower_exec(layout, elem_widths)
        except (ValueError, AssertionError) as e:
            report.findings.append(Finding(
                "program/lowering", Severity.ERROR,
                f"layout does not lower to an ExecProgram: {e}"))
    ctx = AnalysisContext(layout=layout, program=program,
                          b_eff_warn=b_eff_warn)
    sub = run_passes(ctx, passes, subject=report.subject)
    report.findings.extend(sub.findings)
    report.passes = sub.passes
    return report


def verify_program(program: ExecProgram, *,
                   layout: Layout | None = None,
                   passes: Iterable[str] | None = None,
                   subject: str = "") -> Report:
    """Verify lowered tables as-is — no re-lowering, no repair.

    The mutation harness drives this: a corrupted table must be judged
    on its own contents.  ``layout`` (optional) adds array names and the
    interval/coverage/bandwidth checks.
    """
    ctx = AnalysisContext(layout=layout, program=program)
    return run_passes(ctx, passes, subject=subject or "program")


def verify_manifest(manifest: Any, *,
                    streams: np.ndarray | None = None,
                    stream_digest: str | None = None,
                    passes: Iterable[str] | None = None,
                    subject: str = "") -> Report:
    """Checkpoint-grade verification of a :class:`LayoutManifest`.

    Rebuilds the layout from the manifest's recorded count-intervals and
    runs the full pass set over it; a manifest too corrupt to yield a
    layout (bad bundle, bad signature, illegal intervals) degrades to
    manifest-pass findings instead of raising.  ``streams`` /
    ``stream_digest`` extend the proof to the stored bytes.
    """
    subject = subject or f"manifest[{getattr(manifest, 'arch', '?')}]"
    report = Report(subject=subject)
    layout = program = None
    try:
        prob = manifest.problem()
        if prob.canonical_signature() == manifest.signature:
            layout = Layout.from_count_intervals(prob, manifest.intervals)
    except (ValueError, AssertionError, TypeError):
        # the manifest pass reports the specific inconsistency
        layout = None
    if layout is not None:
        try:
            program = lower_exec(layout, manifest.elem_widths())
        except (ValueError, AssertionError) as e:
            report.findings.append(Finding(
                "program/lowering", Severity.ERROR,
                f"manifest layout does not lower: {e}"))
    ctx = AnalysisContext(
        layout=layout, program=program, manifest=manifest,
        streams=None if streams is None else np.asarray(streams),
        stream_digest=stream_digest)
    sub = run_passes(ctx, passes, subject=subject)
    report.findings.extend(sub.findings)
    report.passes = sub.passes
    return report


def verify_kvcache(kvc: Any, *, pages_digest: str | None = None,
                   passes: Iterable[str] | None = None,
                   subject: str = "") -> Report:
    """Verify a :class:`~repro.kvcache.PackedKVCache`: the layout its
    manifest rebinds, the lowered tables, and the mutable-stream facts
    the append path depends on (see the ``kvcache`` pass).

    ``pages_digest``: expected sha256 of the page words (recorded by
    ``CheckpointManager.save_packed(..., kv=...)``); checked when given.
    A manifest that cannot rebind a layout degrades to a finding, and
    the geometry/digest checks still run.
    """
    man = kvc.manifest
    subject = subject or \
        f"PackedKVCache[int{man.bits}/pt{man.page_tokens}]"
    report = Report(subject=subject)
    layout = program = None
    try:
        layout = kvc.layout
        program = kvc.program()
    except (ValueError, AssertionError) as e:
        report.findings.append(Finding(
            "kvcache/rebind", Severity.ERROR,
            f"KV manifest does not rebind a layout: {e}"))
    ctx = AnalysisContext(layout=layout, program=program, kvcache=kvc,
                          stream_digest=pages_digest)
    sub = run_passes(ctx, KVCACHE_PASSES if passes is None else passes,
                     subject=subject)
    report.findings.extend(sub.findings)
    report.passes = sub.passes
    return report


def verify_tree(pt: Any, *, passes: Iterable[str] | None = None) -> Report:
    """Verify a whole :class:`~repro.tree.PackedTree`: its manifest, the
    layout it rebinds, the lowered tables, and (when present) the
    resident stream buffers' byte-lengths."""
    man = pt.manifest
    streams = None if pt.streams is None else np.asarray(pt.streams)
    return verify_manifest(
        man, streams=streams, passes=passes,
        subject=f"PackedTree[{man.arch}]")
