"""The analyzer passes: static proofs over layouts and lowered tables.

Iris's thesis is that a layout is a *provable* object: every element
occupies a known bit interval in a known bus word, so disjointness,
coverage, alignment and bandwidth efficiency are statically decidable
from the :class:`~repro.core.layout.Layout` /
:class:`~repro.core.exec_plan.ExecProgram` alone — a compiler analysis,
not a runtime check.  Each pass here consumes an
:class:`AnalysisContext` and emits :class:`~repro.analysis.findings.Finding`
objects; nothing executes a kernel or touches a device.

Pass catalog (rule ids are ``"<pass>/<check>"``):

``interval``   — interval safety over the layout IR: per-cycle bus
                 overflow, slot bit-range overlap, slots past the bus
                 edge, element coverage per array.
``program``    — interval safety over the lowered piece tables: exact
                 (integer) proof that all packed bit intervals are
                 pairwise disjoint, in-buffer, and inside the bus row —
                 including the u64-pack vs u32-kernel row-padding seam.
``kernel``     — the fused-decode slot table and gathers: widths, slot
                 offsets, gather index range/uniqueness, and conformance
                 of the table against the piece tables.
``stream``     — stream-direct gather safety: global bit offsets stay
                 in-stream, inside their row, and addressable in u32.
``extraction`` — funnel-shift legality: every device-path element spans
                 <= 2 u32 words and <= 32 bits; host-fallback slots are
                 structured findings instead of decode-time warnings.
``manifest``   — a PackedTree/checkpoint manifest agrees with itself and
                 with the stream bytes: signature, intervals, shapes,
                 stream byte-lengths, content digest.
``bandwidth``  — the paper's efficiency metric as lint: B_eff, wasted
                 bits, scheduling-unit padding, staging alignment.
``kvcache``    — a PackedKVCache and its append tables: per-token write
                 masks pairwise disjoint and exactly covering the
                 in-range piece bits (padding never written), page
                 geometry/digest vs the KV manifest, and per-page
                 append idempotence (unpack-then-repack reproduces the
                 page bytes exactly).

All arithmetic is exact: positions are int64 bit indices (stream sizes
are < 2^32 bits by construction, enforced by the ``stream`` pass).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Callable, Iterable

import numpy as np

from repro.core.exec_plan import KERNEL_MAX_WIDTH, _TAB_WIDTH_SHIFT, ExecProgram
from repro.core.layout import Layout

from .findings import Finding, Report, Severity

#: default B_eff below which the bandwidth pass escalates to WARNING
DEFAULT_B_EFF_WARN = 0.5

#: per-array padding fraction above which unit padding is a WARNING
DEFAULT_PAD_WARN = 0.05


@dataclasses.dataclass
class AnalysisContext:
    """Everything a pass may consume.  Any field may be ``None``; passes
    that lack their inputs are skipped (recorded in the report)."""

    layout: Layout | None = None
    program: ExecProgram | None = None
    #: a :class:`repro.tree.LayoutManifest` (typed loosely so the
    #: analyzer stays importable without JAX)
    manifest: Any = None
    #: host stream buffers ``(n_layers, c_max, row_bytes)`` uint8
    streams: np.ndarray | None = None
    #: expected sha256 hexdigest of ``streams`` bytes (checkpoint extra)
    stream_digest: str | None = None
    #: a :class:`repro.kvcache.PackedKVCache` (duck-typed, like manifest)
    kvcache: Any = None
    b_eff_warn: float = DEFAULT_B_EFF_WARN
    pad_warn: float = DEFAULT_PAD_WARN

    def piece_positions(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(row, bit_in_row, width) int64 vectors for every piece."""
        prog = self.program
        assert prog is not None
        word = prog.word.astype(np.int64)
        shift = prog.shift.astype(np.int64)
        row, w_in_row = np.divmod(word, prog.wpr)
        bit_in_row = w_in_row * 64 + shift
        widths = np.empty(prog.n_pieces, dtype=np.int64)
        for i, ew in enumerate(prog.elem_widths):
            widths[prog.piece_base[i]:prog.piece_base[i + 1]] = ew
        return row, bit_in_row, widths

    def piece_array_names(self) -> list[str]:
        """Array name owning each piece (defaults to indices)."""
        prog = self.program
        assert prog is not None
        if self.layout is not None:
            names = [a.name for a in self.layout.problem.arrays]
        else:
            names = [f"array{i}" for i in range(len(prog.piece_depths))]
        out: list[str] = []
        for i, name in enumerate(names):
            out.extend([name] * (prog.piece_base[i + 1] - prog.piece_base[i]))
        return out


PassFn = Callable[[AnalysisContext], Iterable[Finding]]

#: registered passes, in run order
PASSES: dict[str, PassFn] = {}


def register_pass(name: str):
    def _add(fn: PassFn) -> PassFn:
        PASSES[name] = fn
        return fn
    return _add


def _err(rule: str, msg: str, *, array: str = "", locus: str = "",
         hint: str = "") -> Finding:
    return Finding(rule, Severity.ERROR, msg, array=array, locus=locus,
                   fixit_hint=hint)


def _warn(rule: str, msg: str, *, array: str = "", locus: str = "",
          hint: str = "") -> Finding:
    return Finding(rule, Severity.WARNING, msg, array=array, locus=locus,
                   fixit_hint=hint)


def _info(rule: str, msg: str, *, array: str = "", locus: str = "",
          hint: str = "") -> Finding:
    return Finding(rule, Severity.INFO, msg, array=array, locus=locus,
                   fixit_hint=hint)


# ----------------------------------------------------------------------
# interval safety over the layout IR
# ----------------------------------------------------------------------
@register_pass("interval")
def interval_pass(ctx: AnalysisContext) -> Iterable[Finding]:
    """Per-cycle legality of the interval-native layout, reimplemented
    independently of :meth:`Layout.validate` (findings, not asserts).

    A vectorized screen decides the common (legal) case in O(slots)
    numpy; only a layout that fails the screen takes the per-run Python
    walk that localizes the findings.  The persistent
    :class:`~repro.core.iris.LayoutCache` tier runs this pass on every
    disk load, so the legal-case cost is on the planning fast path.
    """
    lay = ctx.layout
    if lay is None:
        return
    if not _interval_screen(lay):
        return
    yield from _interval_walk(lay)


def _interval_screen(lay) -> bool:
    """True if the layout *might* be illegal (run the localizing walk).

    Checks the same facts as the walk, in bulk: slot array indices in
    range, per-run bit usage within the bus, per-array scheduled element
    totals equal to depths.  Slot bit ranges are assigned sequentially
    from offset 0, so overlap is equivalent to bus overflow and needs no
    separate screen.
    """
    prob = lay.problem
    n_arrays = len(prob.arrays)
    run_id, arrs, cnts, taus = lay.flat_counts()
    if not arrs.size:
        return any(a.depth for a in prob.arrays)
    if ((arrs < 0) | (arrs >= n_arrays)).any():
        return True
    widths = np.asarray([a.width for a in prob.arrays], dtype=np.int64)
    used = np.zeros(len(lay.count_intervals), dtype=np.int64)
    np.add.at(used, run_id, cnts * widths[arrs])
    if (used > prob.m).any():
        return True
    scheduled = np.zeros(n_arrays, dtype=np.int64)
    np.add.at(scheduled, arrs, cnts * taus[run_id])
    depths = np.asarray([a.depth for a in prob.arrays], dtype=np.int64)
    return bool((scheduled != depths).any())


def _interval_walk(lay) -> Iterable[Finding]:
    prob = lay.problem
    scheduled = [0] * len(prob.arrays)
    t = 0
    for n_cycles, counts in lay.count_intervals:
        used = 0
        ranges: list[tuple[int, int, int]] = []
        off = 0
        for array, n in counts:
            if not (0 <= array < len(prob.arrays)):
                yield _err("interval/unknown-array",
                           f"slot references array index {array} "
                           f"(problem has {len(prob.arrays)})",
                           locus=f"cycle {t}")
                continue
            spec = prob.arrays[array]
            hi = off + n * spec.width
            ranges.append((off, hi, array))
            used += n * spec.width
            scheduled[array] += n * n_cycles
            off = hi
        if used > prob.m:
            yield _err("interval/bus-overflow",
                       f"{used} bits scheduled on a {prob.m}-bit bus",
                       locus=f"cycle {t}",
                       hint="re-run the scheduler; the layout is not a "
                            "legal transfer plan")
        for lo, hi, array in ranges:
            if hi > prob.m:
                yield _err("interval/slot-oob",
                           f"slot [{lo}, {hi}) exceeds the {prob.m}-bit bus",
                           array=prob.arrays[array].name,
                           locus=f"cycle {t}")
        srt = sorted((lo, hi) for lo, hi, _ in ranges)
        for (a0, a1), (b0, _b1) in zip(srt, srt[1:]):
            if b0 < a1:
                yield _err("interval/overlap",
                           f"slot bit ranges overlap at bit {b0}",
                           locus=f"cycle {t}")
        t += n_cycles
    for i, spec in enumerate(prob.arrays):
        if scheduled[i] != spec.depth:
            yield _err("interval/coverage-gap",
                       f"scheduled {scheduled[i]} of {spec.depth} elements",
                       array=spec.name,
                       hint="the layout does not transfer the array "
                            "exactly once")


# ----------------------------------------------------------------------
# interval safety over the lowered piece tables
# ----------------------------------------------------------------------
@register_pass("program")
def program_pass(ctx: AnalysisContext) -> Iterable[Finding]:
    """Exact-arithmetic proof over ``ExecProgram.word``/``shift``: every
    packed bit interval is in-buffer, inside its bus row (the u64-pack vs
    u32-kernel row-padding seam), and pairwise disjoint."""
    prog = ctx.program
    if prog is None:
        return
    if prog.m % 8:
        yield _err("program/bus-alignment",
                   f"bus width {prog.m} is not byte-aligned")
    names = ctx.piece_array_names()
    row, bit_in_row, widths = ctx.piece_positions()
    n_words = prog.c_max * prog.wpr

    word = prog.word.astype(np.int64)
    bad = np.flatnonzero((word < 0) | (word >= n_words))
    for j in bad[:8]:
        yield _err("program/oob-word",
                   f"destination word {int(word[j])} outside the "
                   f"{n_words}-word buffer",
                   array=names[j], locus=f"piece {int(j)}",
                   hint="lowered table is corrupt; re-lower the layout")
    if bad.size > 8:
        yield _err("program/oob-word",
                   f"... and {bad.size - 8} more out-of-buffer pieces")
    ok = np.flatnonzero((word >= 0) & (word < n_words))

    # the row-padding seam: the u64 pack view pads rows to wpr*8 bytes,
    # the u32 kernel view to words32*4 — bits past m in a row are
    # padding in both, so a piece must end at or before bit m of its row
    seam = ok[bit_in_row[ok] + widths[ok] > prog.m]
    for j in seam[:8]:
        yield _err("program/row-seam",
                   f"piece occupies row bits [{int(bit_in_row[j])}, "
                   f"{int(bit_in_row[j] + widths[j])}) past the "
                   f"{prog.m}-bit bus row",
                   array=names[j], locus=f"piece {int(j)}",
                   hint="shift/width corrupt: the piece would read row "
                        "padding or the next row")
    if seam.size > 8:
        yield _err("program/row-seam",
                   f"... and {seam.size - 8} more pieces past the row edge")

    # pairwise disjointness of all piece intervals, in bus-bit space
    starts = row[ok] * np.int64(prog.m) + bit_in_row[ok]
    ends = starts + widths[ok]
    order = np.argsort(starts, kind="stable")
    s, e = starts[order], ends[order]
    ov = np.flatnonzero(s[1:] < e[:-1])
    for x in ov[:8]:
        ja, jb = int(ok[order[x]]), int(ok[order[x + 1]])
        yield _err("program/overlap",
                   f"pieces {ja} ({names[ja]}) and {jb} ({names[jb]}) "
                   f"overlap at bus bit {int(s[x + 1])}",
                   array=names[jb], locus=f"piece {jb}",
                   hint="two elements claim the same bits; the layout "
                        "or its lowering is corrupt")
    if ov.size > 8:
        yield _err("program/overlap",
                   f"... and {ov.size - 8} more overlapping piece pairs")

    # coverage: piece granularity must tile each element exactly
    if ctx.layout is not None:
        prob = ctx.layout.problem
        for i, (a, ew) in enumerate(zip(prob.arrays, prog.elem_widths)):
            if ew <= 0 or a.width % ew:
                yield _err("program/granularity",
                           f"piece width {ew} does not divide element "
                           f"width {a.width}", array=a.name)
                continue
            subs = a.width // ew
            if prog.piece_depths[i] != a.depth * subs:
                yield _err("program/coverage-gap",
                           f"{prog.piece_depths[i]} pieces cannot cover "
                           f"{a.depth} elements of {subs} pieces each",
                           array=a.name)


# ----------------------------------------------------------------------
# fused-decode kernel table
# ----------------------------------------------------------------------
@register_pass("kernel")
def kernel_pass(ctx: AnalysisContext) -> Iterable[Finding]:
    """The fused kernel's slot table and gathers: every entry decodes a
    real piece, in range, and the gathers are a permutation."""
    prog = ctx.program
    if prog is None:
        return
    kt = prog.kernel
    names = ctx.piece_array_names()
    kernel_arrays = tuple(i for i, ew in enumerate(prog.elem_widths)
                          if ew <= KERNEL_MAX_WIDTH)
    n_kernel = sum(prog.piece_depths[i] for i in kernel_arrays)
    if not n_kernel:
        return
    rows_nz, cols_nz = np.nonzero(kt.tab)
    if rows_nz.size != n_kernel:
        yield _err("kernel/slot-count",
                   f"slot table has {rows_nz.size} entries for "
                   f"{n_kernel} kernel-eligible pieces",
                   hint="table and piece bookkeeping disagree; re-lower")
    entries = kt.tab[rows_nz, cols_nz].astype(np.int64)
    off = entries & ((1 << _TAB_WIDTH_SHIFT) - 1)
    width = entries >> _TAB_WIDTH_SHIFT
    row_bits = kt.words32 * 32
    for idx in np.flatnonzero(width > KERNEL_MAX_WIDTH)[:8]:
        yield _err("kernel/width",
                   f"slot width {int(width[idx])} > {KERNEL_MAX_WIDTH} "
                   "(u32 funnel shifts decode at most 32-bit pieces)",
                   locus=f"tab[{int(rows_nz[idx])}, {int(cols_nz[idx])}]")
    oob = np.flatnonzero((off + width > prog.m) | (off + width > row_bits))
    for idx in oob[:8]:
        yield _err("kernel/oob",
                   f"slot bits [{int(off[idx])}, "
                   f"{int(off[idx] + width[idx])}) exceed the "
                   f"{prog.m}-bit bus row",
                   locus=f"tab[{int(rows_nz[idx])}, {int(cols_nz[idx])}]",
                   hint="the kernel would gather row padding or OOB words")

    # conformance: the (row, bit, width) multiset must equal the piece
    # tables' kernel-eligible positions
    row, bit_in_row, widths = ctx.piece_positions()
    ids = np.concatenate([
        np.arange(prog.piece_base[i], prog.piece_base[i + 1])
        for i in kernel_arrays]) if kernel_arrays else np.empty(0, np.int64)
    want = np.stack([row[ids], bit_in_row[ids], widths[ids]], axis=1)
    got = np.stack([rows_nz.astype(np.int64), off, width], axis=1)
    if want.shape != got.shape or not np.array_equal(
            want[np.lexsort(want.T[::-1])], got[np.lexsort(got.T[::-1])]):
        yield _err("kernel/table-mismatch",
                   "slot table does not encode the same (row, bit, width) "
                   "set as the piece tables",
                   hint="kernel table skewed against pack tables; "
                        "decode would not invert pack")

    # gathers: in-range, duplicate-free, right cardinality per array
    seen = np.zeros(kt.tab.size, dtype=bool)
    for i, g in kt.gathers:
        depth = prog.piece_base[i + 1] - prog.piece_base[i]
        aname = names[prog.piece_base[i]] if depth else f"array{i}"
        if g.shape[0] != depth:
            yield _err("kernel/gather-count",
                       f"gather has {g.shape[0]} indices for {depth} pieces",
                       array=aname)
        bad = np.flatnonzero((g < 0) | (g >= kt.tab.size))
        if bad.size:
            yield _err("kernel/gather-oob",
                       f"{bad.size} gather indices outside the "
                       f"{kt.tab.size}-slot grid (first: "
                       f"{int(g[bad[0]])})", array=aname)
            continue
        # collisions within this gather AND against other arrays' lanes
        uniq, counts = np.unique(g, return_counts=True)
        n_dup = int((counts - 1).sum()) + int(seen[uniq].sum())
        if n_dup:
            first = uniq[(counts > 1) | seen[uniq]][0]
            yield _err("kernel/gather-dup",
                       f"{n_dup} gather indices collide on a grid slot "
                       f"(first: {int(first)})",
                       array=aname,
                       hint="two elements would decode from one lane")
        seen[g] = True


# ----------------------------------------------------------------------
# stream-direct gather safety
# ----------------------------------------------------------------------
@register_pass("stream")
def stream_pass(ctx: AnalysisContext) -> Iterable[Finding]:
    """Global bit offsets consumed by the stream-direct matmul gather:
    in-stream, addressable in u32, and never crossing a row boundary."""
    prog = ctx.program
    if prog is None:
        return
    names = ctx.piece_array_names()
    row_bits = prog.kernel.words32 * 32
    total_bits = prog.c_max * row_bits
    for i, ew in enumerate(prog.elem_widths):
        if ew > KERNEL_MAX_WIDTH:
            continue  # host-path arrays never enter a stream gather
        lo = prog.piece_base[i]
        aname = names[lo] if prog.piece_depths[i] else f"array{i}"
        try:
            gbit = prog.stream_bit_offsets(i).astype(np.int64)
        except ValueError as e:
            yield _err("stream/address-range", str(e), array=aname)
            continue
        oob = np.flatnonzero(gbit + ew > total_bits)
        for j in oob[:8]:
            yield _err("stream/oob",
                       f"gather bits [{int(gbit[j])}, {int(gbit[j]) + ew})"
                       f" exceed the {total_bits}-bit stream",
                       array=aname, locus=f"piece {lo + int(j)}")
        seam = np.flatnonzero((gbit % row_bits) + ew > row_bits)
        for j in seam[:8]:
            yield _err("stream/row-seam",
                       "gather crosses a u32-view row boundary "
                       f"(row bit {int(gbit[j] % row_bits)} + {ew})",
                       array=aname, locus=f"piece {lo + int(j)}")


# ----------------------------------------------------------------------
# extraction legality
# ----------------------------------------------------------------------
@register_pass("extraction")
def extraction_pass(ctx: AnalysisContext) -> Iterable[Finding]:
    """Funnel-shift legality per array: device paths need width <= 32 and
    a <= 2-u32-word span; wider slots are structured host-fallback
    findings (instead of warnings at decode time)."""
    prog = ctx.program
    if prog is None:
        return
    names = ctx.piece_array_names()
    row, bit_in_row, widths = ctx.piece_positions()
    for i, ew in enumerate(prog.elem_widths):
        lo, hi = prog.piece_base[i], prog.piece_base[i + 1]
        aname = names[lo] if hi > lo else f"array{i}"
        if ew > 64:
            yield _err("extraction/width",
                       f"piece width {ew} > 64: not unpackable on any path",
                       array=aname,
                       hint="lower at a finer granularity (elem_widths)")
            continue
        if ew > KERNEL_MAX_WIDTH:
            yield _warn("extraction/host-fallback",
                        f"piece width {ew} > {KERNEL_MAX_WIDTH}: decoded "
                        "by the numpy host path, not the Pallas kernel",
                        array=aname,
                        hint="lower at element granularity (elem_widths) "
                             "to keep the decode on-device")
            continue
        # device path: (gbit & 31) + width <= 64 <=> spans <= 2 u32 words
        span = (bit_in_row[lo:hi] & 31) + ew
        bad = np.flatnonzero(span > 64)
        for j in bad[:8]:
            yield _err("extraction/funnel-span",
                       f"element spans {int(span[j])} bits from its u32 "
                       "word base (> 2 words): funnel shift cannot "
                       "extract it",
                       array=aname, locus=f"piece {lo + int(j)}")


# ----------------------------------------------------------------------
# manifest consistency
# ----------------------------------------------------------------------
def stream_sha256(streams: np.ndarray) -> str:
    """Content digest of the packed stream bytes (checkpoint integrity)."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(streams).view(np.uint8).tobytes())
    return h.hexdigest()


@register_pass("manifest")
def manifest_pass(ctx: AnalysisContext) -> Iterable[Finding]:
    """A manifest, its layout, and the stream bytes must mutually agree:
    signature, intervals, geometry, per-tensor shapes, byte-lengths and
    (when recorded) the content digest."""
    man = ctx.manifest
    if man is None:
        return
    try:
        prob = man.problem()
    except Exception as e:  # corrupt bundle spec
        yield _err("manifest/bundle",
                   f"bundle spec does not build a problem: {e}")
        return
    if prob.canonical_signature() != man.signature:
        yield _err("manifest/signature",
                   "manifest signature does not match its bundle problem",
                   hint="manifest is corrupt or from an incompatible "
                        "version; do not rebind")
    if man.m % 8 or man.row_bytes != man.m // 8:
        yield _err("manifest/row-bytes",
                   f"row_bytes {man.row_bytes} inconsistent with bus "
                   f"width {man.m}")
    lay = ctx.layout
    if lay is None:
        try:
            lay = Layout.from_count_intervals(prob, man.intervals)
            lay.validate()
        except (ValueError, AssertionError) as e:
            yield _err("manifest/intervals",
                       f"count-intervals do not rebuild a legal layout: {e}",
                       hint="checkpoint corrupt: elements would be "
                            "dropped or duplicated on restore")
            lay = None
    if lay is not None and lay.c_max != man.c_max:
        yield _err("manifest/c-max",
                   f"intervals span {lay.c_max} cycles, manifest says "
                   f"{man.c_max}")
    # per-tensor shapes vs the scheduled capacity
    by_name = {b.name: b for b in man.bundle}
    g = man.spec.group_size
    for key, (kk, nn) in dict(man.shapes).items():
        bname = key.split("/", 1)[1] if "/" in key else key
        w = by_name.get(bname)
        s = by_name.get(f"{bname}_scales")
        if w is None or s is None:
            yield _err("manifest/shapes",
                       f"{key}: bundle lacks tensor {bname!r} or its scales",
                       array=bname)
            continue
        if kk * nn > w.n_elems:
            yield _err("manifest/shapes",
                       f"{key}: shape ({kk}, {nn}) needs {kk * nn} "
                       f"elements, bundle holds {w.n_elems}",
                       array=bname)
        if kk % g:
            yield _err("manifest/shapes",
                       f"{key}: K={kk} not divisible by group_size {g}",
                       array=bname)
        elif (kk // g) * nn > s.n_elems:
            yield _err("manifest/shapes",
                       f"{key}: needs {(kk // g) * nn} scales, bundle "
                       f"holds {s.n_elems}", array=f"{bname}_scales")
    # stream byte-lengths
    if ctx.streams is not None:
        st = np.asarray(ctx.streams)
        want = (man.n_layers, man.c_max, man.row_bytes)
        if st.dtype != np.uint8:
            yield _err("manifest/stream-dtype",
                       f"stream buffer dtype {st.dtype} != uint8")
        if tuple(st.shape) != want:
            yield _err("manifest/stream-shape",
                       f"stream buffer shape {tuple(st.shape)} != "
                       f"{want} (n_layers, c_max, row_bytes)",
                       hint="stream bytes truncated or from a different "
                            "layout; refusing would-be garbage gathers")
        elif ctx.stream_digest is not None:
            got = stream_sha256(st)
            if got != ctx.stream_digest:
                yield _err("manifest/stream-digest",
                           f"stream content digest {got[:16]}... does not "
                           f"match recorded {ctx.stream_digest[:16]}...",
                           hint="stream words were corrupted in storage "
                                "or transit")


# ----------------------------------------------------------------------
# bandwidth audit
# ----------------------------------------------------------------------
@register_pass("bandwidth")
def bandwidth_pass(ctx: AnalysisContext) -> Iterable[Finding]:
    """The paper's efficiency metric (Eq. 1) as lint: wasted bus bits,
    per-tensor scheduling-unit padding, and staging alignment."""
    lay = ctx.layout
    if lay is None:
        return
    prob = lay.problem
    c_max = lay.c_max
    total = c_max * prob.m
    b_eff = prob.p_tot / total if total else 0.0
    wasted = total - prob.p_tot
    mk = _warn if b_eff < ctx.b_eff_warn else _info
    yield mk("bandwidth/efficiency",
             f"B_eff = {b_eff:.4f} ({wasted} of {total} bus bits idle "
             f"over {c_max} cycles)",
             hint="" if b_eff >= ctx.b_eff_warn else
             "layout wastes more than "
             f"{(1 - ctx.b_eff_warn) * 100:.0f}% of bus bandwidth; "
             "check lane caps / due dates or try another strategy")
    prog = ctx.program
    if prog is not None:
        # staging alignment: bits per row added by the u32 kernel view
        pad = prog.kernel.words32 * 32 - prob.m
        if pad:
            yield _info("bandwidth/row-alignment",
                        f"u32 staging pads each row by {pad} bits "
                        f"({prob.m} -> {prog.kernel.words32 * 32})",
                        hint="host-staging only; DMA moves row_bytes")
        # scheduling-unit padding per tensor (manifest knows true counts)
        if ctx.manifest is not None:
            by_name = {b.name: b for b in ctx.manifest.bundle}
            for i, a in enumerate(prob.arrays):
                b = by_name.get(a.name)
                if b is None:
                    continue
                cap_bits = prog.piece_depths[i] * prog.elem_widths[i]
                used_bits = b.n_elems * b.width_bits
                pad_bits = cap_bits - used_bits
                if pad_bits < 0:
                    yield _err("bandwidth/unit-padding",
                               f"{b.n_elems} elements exceed the "
                               f"scheduled capacity "
                               f"{prog.piece_depths[i]} pieces",
                               array=a.name)
                elif pad_bits:
                    frac = pad_bits / cap_bits
                    mk2 = _warn if frac > ctx.pad_warn else _info
                    yield mk2("bandwidth/unit-padding",
                              f"{pad_bits} pad bits "
                              f"({frac * 100:.2f}% of the tensor's "
                              "stream share) from unit rounding",
                              array=a.name,
                              hint="" if frac <= ctx.pad_warn else
                              "shrink the scheduling unit (lanes_target) "
                              "or repack the tensor")


# ----------------------------------------------------------------------
# packed KV-cache: mutable-stream safety
# ----------------------------------------------------------------------
def _popcount32(x: np.ndarray) -> np.ndarray:
    """Per-element popcount of a uint32 array (SWAR, wrap-on-overflow)."""
    x = x.astype(np.uint32)
    x = x - ((x >> np.uint32(1)) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> np.uint32(2))
                                       & np.uint32(0x33333333))
    x = (x + (x >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> np.uint32(24)).astype(np.int64)


def _expected_write_mask(ctx: AnalysisContext, logical) -> np.ndarray:
    """(c_max, words32) u32 bits every *in-range* piece occupies, derived
    from the piece tables directly (independent of the append tables'
    pack-table inversion)."""
    prog = ctx.program
    row, bit_in_row, widths = ctx.piece_positions()
    n_arr = len(prog.piece_depths)
    base = np.asarray(prog.piece_base, dtype=np.int64)
    arr_id = np.repeat(np.arange(n_arr), np.diff(base))
    local = np.arange(prog.n_pieces) - base[arr_id]
    in_range = local < np.asarray(logical, dtype=np.int64)[arr_id]
    w32 = prog.kernel.words32
    r, b, w = row[in_range], bit_in_row[in_range], widths[in_range]
    q, sh = np.divmod(b, 32)
    m64 = ((np.uint64(1) << w.astype(np.uint64)) - np.uint64(1)) \
        << sh.astype(np.uint64)
    exp = np.zeros((prog.c_max, w32), np.uint64)
    np.bitwise_or.at(exp, (r, q), m64 & np.uint64(0xFFFFFFFF))
    hi = m64 >> np.uint64(32)
    has_hi = (hi != 0) & (q + 1 < w32)   # row-seam pieces already flagged
    np.bitwise_or.at(exp, (r[has_hi], q[has_hi] + 1), hi[has_hi])
    return exp.astype(np.uint32)


@register_pass("kvcache")
def kvcache_pass(ctx: AnalysisContext) -> Iterable[Finding]:
    """Mutable-stream safety for a packed KV-cache: the masked-RMW append
    path is only sound if (a) per-token write masks are pairwise
    disjoint, (b) their union is exactly the in-range piece bits (so
    padding is never written and every payload bit has exactly one
    owner), and (c) a page's bytes are a fixed point of
    unpack-then-repack (appends compose with the static pack tables).
    Geometry and content digest are checked against the KV manifest."""
    kvc = ctx.kvcache
    if kvc is None:
        return
    man = kvc.manifest
    try:
        prob = man.problem()
    except Exception as e:  # corrupt bundle spec
        yield _err("kvcache/bundle",
                   f"KV bundle spec does not build a problem: {e}")
        return
    # KV signatures are stored JSON-canonical (strings survive the
    # checkpoint-extra round trip; tuples would come back as lists)
    if json.dumps(prob.canonical_signature()) != man.signature:
        yield _err("kvcache/signature",
                   "KV manifest signature does not match its bundle "
                   "problem",
                   hint="manifest is corrupt or from an incompatible "
                        "version; do not rebind")
    pages = np.asarray(kvc.host_pages())
    want = (man.n_layers, man.n_slots, man.n_pages, man.c_max, man.words32)
    if pages.dtype != np.uint32 or tuple(pages.shape) != want:
        yield _err("kvcache/pages-shape",
                   f"page buffer {pages.dtype}{tuple(pages.shape)} != "
                   f"uint32{want} (n_layers, n_slots, n_pages, c_max, "
                   "words32)",
                   hint="pages truncated or from a different KV layout")
        return
    if ctx.stream_digest is not None:
        got = stream_sha256(pages)
        if got != ctx.stream_digest:
            yield _err("kvcache/pages-digest",
                       f"KV page content digest {got[:16]}... does not "
                       f"match recorded {ctx.stream_digest[:16]}...",
                       hint="page words were corrupted in storage or "
                            "transit")
    prog = ctx.program
    if prog is None:
        return

    from repro.kvcache.layout import append_tables  # numpy-only module

    try:
        tabs = append_tables(prog, page_tokens=man.page_tokens,
                             logical=man.logical())
    except (ValueError, AssertionError) as e:
        yield _err("kvcache/append-tables",
                   f"append tables do not derive from the program: {e}")
        return
    mk = tabs.maskbits                       # (c_max, words32, K) u32
    union = np.zeros(mk.shape[:2], np.uint32)
    popsum = np.zeros(mk.shape[:2], np.int64)
    for kk in range(tabs.K):
        union |= mk[:, :, kk]
        popsum += _popcount32(mk[:, :, kk])
    clash = popsum != _popcount32(union)
    if clash.any():
        r, q = np.argwhere(clash)[0]
        yield _err("kvcache/mask-overlap",
                   f"{int(clash.sum())} destination words have "
                   "overlapping token write masks (first: row "
                   f"{int(r)}, word {int(q)})",
                   hint="two appends would clobber each other's bits; "
                        "the RMW append path is unsound")
    pad_write = (tabs.tok < 0) & (mk != 0)
    if pad_write.any():
        yield _err("kvcache/padding-write",
                   f"{int(pad_write.sum())} contributions write bits "
                   "owned by residual padding (token id -1)",
                   hint="appends would dirty pad bits, breaking the "
                        "zero-page idempotence invariant")
    exp = _expected_write_mask(ctx, man.logical())
    if (union != exp).any():
        bad = union != exp
        r, q = np.argwhere(bad)[0]
        yield _err("kvcache/mask-coverage",
                   f"token mask union differs from the in-range piece "
                   f"bits in {int(bad.sum())} words (first: row "
                   f"{int(r)}, word {int(q)})",
                   hint="append tables and piece tables disagree on "
                        "which bits are payload")

    # pages start zeroed and appends are masked, so every bit outside
    # the in-range payload mask must still be zero — this catches writes
    # into residual-fill pieces and bus slack alike, which the pack
    # tables would happily reproduce (so idempotence alone cannot)
    stray = pages & ~exp
    if stray.any():
        n_bad = int(_popcount32(stray).sum())
        first = tuple(int(x) for x in np.argwhere(stray)[0])
        yield _err("kvcache/stray-bits",
                   f"{n_bad} page bits set outside the in-range payload "
                   f"mask (first word: {first})",
                   hint="an append escaped its token mask or the pages "
                        "were corrupted; reads would see garbage after "
                        "the next overwrite")

    # append idempotence over sampled pages: unpack -> repack must be a
    # byte fixed point (pages start zeroed and appends are masked, so
    # every non-payload bit is 0 and pack_indexed reproduces the page)
    nl, ns, npg = pages.shape[:3]
    coords = [(layer, s, p) for layer in range(nl) for s in range(ns)
              for p in range(npg)]
    coords.sort(key=lambda t: not pages[t].any())   # nonzero pages first
    n_elem = len(prog.elem_widths)
    for t in coords[:6]:
        u8 = np.ascontiguousarray(pages[t]).view(np.uint8) \
            .reshape(man.c_max, man.words32 * 4)
        tail = u8[:, man.row_bytes:]
        if tail.any():
            yield _err("kvcache/row-padding",
                       f"page {t}: u32-view row padding bytes are "
                       "nonzero",
                       hint="writes escaped the bus row; the pack view "
                            "and the DMA view disagree")
            continue
        buf = np.ascontiguousarray(u8[:, :man.row_bytes])
        flat = prog.buffer_words64(buf)
        streams = [prog.unpack_array(flat, i) for i in range(n_elem)]
        back = prog.pack_indexed(streams)
        if not np.array_equal(np.asarray(back, np.uint8), buf):
            yield _err("kvcache/idempotence",
                       f"page {t}: pack(unpack(page)) differs from the "
                       "page bytes",
                       hint="append left bits the static pack tables "
                            "cannot reproduce; the page is corrupt")


# ----------------------------------------------------------------------
# driver
# ----------------------------------------------------------------------
def run_passes(ctx: AnalysisContext,
               passes: Iterable[str] | None = None, *,
               subject: str = "") -> Report:
    """Run ``passes`` (default: all registered) over ``ctx``.

    Unknown pass names raise ``KeyError``; passes whose inputs are absent
    from the context simply contribute no findings.
    """
    names = list(PASSES) if passes is None else list(passes)
    report = Report(subject=subject)
    for name in names:
        try:
            fn = PASSES[name]
        except KeyError:
            known = ", ".join(PASSES)
            raise KeyError(
                f"unknown analysis pass {name!r}; registered: {known}"
            ) from None
        report.findings.extend(fn(ctx))
        report.passes.append(name)
    return report
