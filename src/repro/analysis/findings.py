"""Findings model for the static layout analyzer.

A pass reports :class:`Finding` objects instead of raising: every rule
violation carries a stable ``rule_id`` (``"pass/check"``), a severity, the
array it concerns, a *locus* (where in the layout/tables the violation
sits — cycle, piece index, table cell) and a machine-checkable message.
A :class:`Report` aggregates findings per analysis run, serializes to
JSON (the CI gate artifact), and converts to a structured
:class:`AnalysisError` when a caller wants errors to be fatal —
``restore_packed`` rejecting a corrupted checkpoint, ``Plan.verify()``
gating a serving launch.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Iterable, Iterator


class Severity(enum.IntEnum):
    """Ordered severity: errors are unsound layouts, warnings are
    inefficiencies or surprising-but-correct configurations."""

    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:
        return self.name.lower()


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or informational diagnostic).

    ``rule_id`` is ``"<pass>/<check>"`` (e.g. ``"interval/overlap"``);
    ``array`` names the affected array (empty for whole-layout findings);
    ``locus`` localizes the violation (``"cycle 12"``, ``"piece 3041"``,
    ``"kernel tab[4, 7]"``); ``fixit_hint`` suggests the remediation.
    """

    rule_id: str
    severity: Severity
    message: str
    array: str = ""
    locus: str = ""
    fixit_hint: str = ""

    def to_json_dict(self) -> dict[str, object]:
        return {
            "rule_id": self.rule_id,
            "severity": str(self.severity),
            "array": self.array,
            "locus": self.locus,
            "message": self.message,
            "fixit_hint": self.fixit_hint,
        }

    def render(self) -> str:
        loc = f" @ {self.locus}" if self.locus else ""
        arr = f" [{self.array}]" if self.array else ""
        hint = f"  (fix: {self.fixit_hint})" if self.fixit_hint else ""
        return f"{self.severity}: {self.rule_id}{arr}{loc}: " \
               f"{self.message}{hint}"


@dataclasses.dataclass
class Report:
    """All findings of one analysis run, plus which passes produced them."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    passes: list[str] = dataclasses.field(default_factory=list)
    subject: str = ""

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def __iter__(self) -> Iterator[Finding]:
        return iter(self.findings)

    def __len__(self) -> int:
        return len(self.findings)

    def by_severity(self, severity: Severity) -> list[Finding]:
        return [f for f in self.findings if f.severity == severity]

    @property
    def errors(self) -> list[Finding]:
        return self.by_severity(Severity.ERROR)

    @property
    def warnings(self) -> list[Finding]:
        return self.by_severity(Severity.WARNING)

    @property
    def ok(self) -> bool:
        """True when no error-severity finding was reported."""
        return not self.errors

    def rule_ids(self) -> set[str]:
        return {f.rule_id for f in self.findings}

    def raise_if_errors(self) -> "Report":
        """Raise :class:`AnalysisError` when any error finding exists;
        chainable otherwise."""
        if not self.ok:
            raise AnalysisError(self)
        return self

    # -- serialization (the CI gate artifact) ---------------------------
    def to_json_dict(self) -> dict[str, object]:
        return {
            "subject": self.subject,
            "passes": list(self.passes),
            "ok": self.ok,
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "findings": [f.to_json_dict() for f in self.findings],
        }

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_json_dict(), indent=indent)

    def render(self, min_severity: Severity = Severity.INFO) -> str:
        lines = [f.render() for f in self.findings
                 if f.severity >= min_severity]
        status = "OK" if self.ok else f"FAIL ({len(self.errors)} error(s))"
        head = f"analysis[{self.subject or 'layout'}]: {status}, " \
               f"{len(self.findings)} finding(s)"
        return "\n".join([head, *lines])


class AnalysisError(ValueError):
    """A verification run found error-severity findings.

    Carries the full :class:`Report` on :attr:`report`; ``str()`` renders
    the errors so a rejected checkpoint names exactly which rule failed
    where, instead of surfacing as a shape error or silent garbage.
    """

    def __init__(self, report: Report):
        self.report = report
        errs = "; ".join(f.render() for f in report.errors) or "(none)"
        super().__init__(
            f"layout verification failed with {len(report.errors)} "
            f"error(s): {errs}"
        )
