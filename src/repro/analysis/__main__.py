"""``python -m repro.analysis`` — the layout verifier CLI.

Three subcommands:

* ``gate`` — every registered strategy x the shared problem suite
  (:mod:`repro.analysis.suite`); the CI ``analysis-gate`` job runs this
  and uploads the JSON report as an artifact.  Exit 1 on any error
  finding.
* ``config ARCH`` — verify the per-layer stream layout a model config
  plans (e.g. ``python -m repro.analysis config smollm-135m --bits 4``).
* ``ckpt ROOT`` — verify a packed checkpoint on disk (manifest vs
  intervals vs stream bytes vs content digest) **without** restoring it.

All subcommands print a findings report (``--min-severity`` filters)
and support ``--json PATH`` for the machine-readable artifact.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from . import Report, Severity, verify_layout
from .suite import GATE_PROBLEMS


def _severity(name: str) -> Severity:
    try:
        return Severity[name.upper()]
    except KeyError:
        raise argparse.ArgumentTypeError(
            f"unknown severity {name!r}; use info|warning|error"
        ) from None


def _emit(reports: list[Report], json_path: str | None,
          min_severity: Severity) -> int:
    ok = all(r.ok for r in reports)
    for r in reports:
        print(r.render(min_severity))
    n_err = sum(len(r.errors) for r in reports)
    n_warn = sum(len(r.warnings) for r in reports)
    print(f"== {len(reports)} report(s): "
          f"{'OK' if ok else 'FAIL'} ({n_err} error(s), "
          f"{n_warn} warning(s))")
    if json_path:
        payload = {
            "ok": ok,
            "n_reports": len(reports),
            "n_errors": n_err,
            "n_warnings": n_warn,
            "reports": [r.to_json_dict() for r in reports],
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=2))
        print(f"wrote {json_path}")
    return 0 if ok else 1


def _cmd_gate(args: argparse.Namespace) -> int:
    from repro.api import STRATEGIES, plan

    names = args.strategies or STRATEGIES.names()
    reports = []
    for prob in GATE_PROBLEMS:
        tag = "/".join(a.name for a in prob.arrays) + f"@m={prob.m}"
        for strategy in names:
            lay = plan(prob, strategy, cache=None).layout
            reports.append(verify_layout(
                lay, subject=f"{strategy}:{tag}"))
    return _emit(reports, args.json, args.min_severity)


def _cmd_config(args: argparse.Namespace) -> int:
    from repro.api import plan_layer_stack
    from repro.configs import get_config
    from repro.quant import QuantSpec

    cfg = get_config(args.arch)
    spec = QuantSpec(bits=args.bits, group_size=args.group_size)
    stack = plan_layer_stack(cfg, spec, m=args.m, strategy=args.strategy,
                             n_layers=args.layers, cache=None)
    report = verify_layout(
        stack.plans[0].layout, program=stack.exec_program(),
        subject=f"{args.arch}:int{args.bits}/g{args.group_size}"
                f":{args.strategy}")
    return _emit([report], args.json, args.min_severity)


def _cmd_ckpt(args: argparse.Namespace) -> int:
    from repro.checkpoint.checkpoint import CheckpointManager  # needs JAX

    mgr = CheckpointManager(args.root)
    report = mgr.verify_packed(args.step)
    return _emit([report], args.json, args.min_severity)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static layout verifier and bandwidth lint")
    ap.add_argument("--json", help="write the JSON report artifact here")
    ap.add_argument("--min-severity", type=_severity,
                    default=Severity.WARNING,
                    help="lowest severity to print (info|warning|error)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    g = sub.add_parser("gate", help="every strategy x the problem suite")
    g.add_argument("--strategies", nargs="*", default=None,
                   help="strategy names (default: whole registry)")
    g.set_defaults(fn=_cmd_gate)

    c = sub.add_parser("config", help="verify a model config's layout")
    c.add_argument("arch", help="config name, e.g. smollm-135m")
    c.add_argument("--bits", type=int, default=4)
    c.add_argument("--group-size", type=int, default=64)
    c.add_argument("--m", type=int, default=4096)
    c.add_argument("--layers", type=int, default=None)
    c.add_argument("--strategy", default="iris")
    c.set_defaults(fn=_cmd_config)

    k = sub.add_parser("ckpt", help="verify a packed checkpoint on disk")
    k.add_argument("root", help="checkpoint root directory")
    k.add_argument("--step", type=int, default=None)
    k.set_defaults(fn=_cmd_ckpt)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
