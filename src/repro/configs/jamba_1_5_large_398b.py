"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887.

72L, d_model=8192, 64H (GQA kv=8), d_ff=24576, vocab=65536; Mamba+attention
1:7 interleave (1 attention layer per 8), MoE 16 experts top-2 on every
second layer.  Sub-quadratic: runs long_500k (sequence-sharded KV on the 9
attention layers).
"""
from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    attn_every=8,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, every=2),
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64),
    subquadratic=True,
    max_seq_len=1 << 20,
)
