"""qwen2-vl-2b [vlm] — arXiv:2409.12191.

28L, d_model=1536, 12H (GQA kv=2), d_ff=8960, vocab=151936; M-RoPE
(temporal/height/width sections).  The vision frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed patch embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    frontend="vision",
    use_bias=True,
    tie_embeddings=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
)
