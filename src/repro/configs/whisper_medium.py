"""whisper-medium [audio enc-dec] — arXiv:2212.04356.

24L decoder (+24L encoder), d_model=1024, 16H (GQA kv=16), d_ff=4096,
vocab=51865.  The conv audio frontend is a STUB per the assignment:
``input_specs()`` provides precomputed (B, 1500, d_model) frame embeddings.
"""
from .base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    encoder=EncoderConfig(n_layers=24, n_ctx=1500),
    frontend="audio",
    act="gelu",
    norm="layernorm",
    use_bias=True,
    rope_theta=0.0,            # whisper uses learned/sinusoidal positions
    max_seq_len=32_768,        # stress config per assignment shapes
)
