"""The paper's own accelerator inputs (Table 5), as layout-problem configs."""
from repro.core.task import INV_HELMHOLTZ, PAPER_EXAMPLE, matmul_problem  # noqa: F401
