"""stablelm-3b [dense] — hf:stabilityai/stablelm-2 family.

32L, d_model=2560, 32H (MHA: kv=32), d_ff=6912, vocab=50304.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    norm="layernorm",
    use_bias=True,
    max_seq_len=32_768,
)
