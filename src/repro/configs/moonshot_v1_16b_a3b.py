"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B.

48L, d_model=2048, 16H (kv=16), d_ff=1408, vocab=163840; MoE 64 experts
top-6 (~3B active params per token).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408),
    max_seq_len=131_072,
)
