"""Architecture registry: ``get_config("<arch-id>")``.

Every assigned architecture (10) plus the paper's own accelerator inputs
(``paper_accels``).  IDs match the assignment exactly.
"""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401

_REGISTRY: dict[str, str] = {
    "whisper-medium": "whisper_medium",
    "command-r-plus-104b": "command_r_plus_104b",
    "mistral-large-123b": "mistral_large_123b",
    "stablelm-3b": "stablelm_3b",
    "smollm-135m": "smollm_135m",
    "arctic-480b": "arctic_480b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "rwkv6-3b": "rwkv6_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen2-vl-2b": "qwen2_vl_2b",
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.CONFIG


def shape_cells(arch: str) -> list[ShapeConfig]:
    """The runnable shape cells for an arch (long_500k needs sub-quadratic
    attention; skips are recorded in DESIGN.md §Arch-applicability)."""
    cfg = get_config(arch)
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.subquadratic:
        cells.append(SHAPES["long_500k"])
    return cells
