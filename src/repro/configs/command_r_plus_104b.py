"""command-r-plus-104b [dense] — hf:CohereForAI/c4ai-command-r-plus.

64L, d_model=12288, 96H (GQA kv=8), d_ff=33792, vocab=256000, no biases.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    norm="layernorm",
    use_bias=False,
    rope_theta=75_000_000.0,
    max_seq_len=131_072,
)
