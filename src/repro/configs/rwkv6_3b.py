"""rwkv6-3b [ssm] — Finch, arXiv:2404.05892 (attention-free).

32L, d_model=2560, d_ff=8960, vocab=65536; RWKV-6 time-mix with
data-dependent per-channel decay.  Sub-quadratic: runs long_500k.
"""
from .base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,              # time-mix heads, head_dim 64
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
    act="relu_squared",      # rwkv channel-mix uses squared relu
    subquadratic=True,
    max_seq_len=1 << 20,
)
