"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
full configs live in one module per architecture (``repro.configs.<id>``)
and reduced smoke variants are derived with :meth:`ModelConfig.reduced`.
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    capacity_factor: float = 1.25
    # Arctic: dense residual MLP in parallel with the experts
    dense_residual_ff: int | None = None
    # apply MoE every `every` layers (jamba: alternate dense/MoE)
    every: int = 1


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-family SSM block, in SSD (scalar-decay head) form.

    DESIGN.md §Hardware-adaptation: Mamba1's per-(channel, state) decay has
    no TPU-friendly tiling without bespoke kernels; we use the Mamba-2 SSD
    parameterization (per-head scalar decay), which has the same state size
    and asymptotics and maps onto MXU matmuls.
    """

    d_state: int = 64             # state per head (dk = dv = d_state)
    expand: int = 2               # d_inner = expand * d_model
    head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64          # low-rank size of the data-dependent decay


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper)."""

    n_layers: int
    n_ctx: int                    # encoder positions (audio frames)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                       # 0 -> d_model // n_heads
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    encoder: EncoderConfig | None = None
    attn_every: int = 1                     # jamba: 1 attn per N layers
    frontend: Literal[None, "audio", "vision"] = None
    act: str = "silu"
    norm: str = "rmsnorm"
    use_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] | None = None   # qwen2-vl M-RoPE
    max_seq_len: int = 1 << 19
    dtype: str = "bfloat16"
    kv_cache_dtype: str = ""                # "" = model dtype;
                                            # "float8_e5m2" halves KV bytes
    subquadratic: bool = False              # eligible for long_500k

    def __post_init__(self) -> None:
        if self.n_heads > 0:
            hd = self.head_dim or self.d_model // self.n_heads
            object.__setattr__(self, "head_dim", hd)
            if self.n_heads % max(1, self.n_kv_heads):
                raise ValueError("n_heads must be a multiple of n_kv_heads")

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_is_attn(self, layer_idx: int) -> bool:
        """Hybrid interleave: layer i uses attention iff this returns True."""
        if self.attention_free:
            return False
        if self.attn_every <= 1:
            return True
        # jamba: one attention layer per `attn_every`, at the end of a period
        return layer_idx % self.attn_every == self.attn_every - 1

    def layer_is_moe(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return layer_idx % self.moe.every == self.moe.every - 1

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + per-layer blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        total = v * d                      # embeddings
        if not self.tie_embeddings:
            total += v * d                 # unembed
        for i in range(self.n_layers):
            if self.layer_is_attn(i):
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * hd * d
            elif self.family in ("ssm",) and self.rwkv is not None:
                total += 5 * d * d + 2 * d * self.rwkv.decay_lora
            elif self.ssm is not None:
                di = self.ssm.expand * d
                total += 2 * d * di + di * d + di
            if self.layer_is_moe(i):
                moe = self.moe
                total += d * moe.n_experts
                total += moe.n_experts * 3 * d * moe.d_expert
                if moe.dense_residual_ff:
                    total += 3 * d * moe.dense_residual_ff
            else:
                total += 3 * d * f
            total += 2 * d                 # norms
        if self.encoder is not None:
            for _ in range(self.encoder.n_layers):
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * hd * d + 3 * d * f + 2 * d
            # decoder cross-attention
            total += self.n_layers * (d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        moe = self.moe
        inactive_frac = 1 - moe.top_k / moe.n_experts
        expert_params = sum(
            moe.n_experts * 3 * self.d_model * moe.d_expert
            for i in range(self.n_layers)
            if self.layer_is_moe(i)
        )
        return self.param_count() - int(expert_params * inactive_frac)

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """A small same-family config for CPU smoke tests."""
        changes: dict = dict(
            n_layers=min(self.n_layers, 2 if self.attn_every <= 1
                         else 2 * self.attn_every),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            max_seq_len=256,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_expert=64,
                dense_residual_ff=(64 if self.moe.dense_residual_ff else None),
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32)
        if self.rwkv is not None:
            changes["rwkv"] = dataclasses.replace(
                self.rwkv, head_dim=32, decay_lora=16)
        if self.encoder is not None:
            changes["encoder"] = dataclasses.replace(
                self.encoder, n_layers=2, n_ctx=32)
        if self.mrope_sections is not None:
            # rescale sections to the reduced head_dim (channels = hd/2)
            hd = changes["head_dim"]
            total = sum(self.mrope_sections)
            t = self.mrope_sections[0] * (hd // 2) // total
            h = self.mrope_sections[1] * (hd // 2) // total
            changes["mrope_sections"] = (t, h, hd // 2 - t - h)
        changes.update(overrides)
        return dataclasses.replace(self, **changes)

    def with_tp(self, tp: int) -> "ModelConfig":
        """Adjust for tensor parallelism:

        * replicate KV heads to a multiple of the model axis when
          n_kv_heads doesn't divide it (standard GQA TP practice);
        * pad the vocab to a multiple of the axis (Megatron-style) so
          the logits/CE path shards — an unshardable vocab replicates
          O(B*S*V) f32 tensors per device (measured: whisper train
          +12.7 GiB/dev per tensor; EXPERIMENTS §Perf cell E).

        The model function is unchanged (padded logit rows simply learn
        to be improbable; labels never reference them)."""
        out = self
        pad = (-out.vocab_size) % tp
        if pad:
            out = dataclasses.replace(out,
                                      vocab_size=out.vocab_size + pad)
        if out.n_kv_heads == 0 or out.n_kv_heads % tp == 0:
            return out
        reps = -(-tp // out.n_kv_heads)        # ceil
        new_kv = out.n_kv_heads * reps
        if new_kv % tp and tp % new_kv:
            # fall back: replicate to lcm so the axis divides or is unused
            import math
            new_kv = out.n_kv_heads * tp // math.gcd(out.n_kv_heads, tp)
        if out.n_heads % new_kv:
            return out                         # keep GQA grouping legal
        return dataclasses.replace(out, n_kv_heads=new_kv)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
