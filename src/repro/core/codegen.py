"""Code generation from a :class:`Layout` (paper §5).

Three artifacts, mirroring the paper's pipeline:

* **Host-side organization** (paper Listing 1): :func:`pack_arrays` packs the
  input arrays into the unified layout buffer.  Vectorized per
  (interval, slot) with numpy — the analogue of the generated C `pack()`
  (one statement per slot, a ``for`` loop per multi-cycle interval).
  :func:`emit_c_pack` additionally emits the literal C function for
  inspection/tests.
* **Accelerator-side decoding** (paper Listing 2): :func:`decode_plan`
  produces the static per-interval slot tables the Pallas kernel
  (``repro.kernels.layout_decode``) is gridded over, and
  :func:`unpack_arrays` is the pure-numpy oracle of that kernel.
* **FIFO/staging report**: sizes the decode module's per-array staging
  (paper: shift-register write ports), from ``Layout.fifo_depths``.

Bit conventions: bus cycle = one row of ``m`` bits; element LSB at
``bit_offset``; rows stored little-endian in bytes (bit *b* of a row lives
in byte ``b >> 3`` at in-byte position ``b & 7``) — matching the shifts an
``ap_uint<m>.range(hi, lo)`` performs in the paper's HLS module.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .layout import Layout
from .task import LayoutProblem


# ----------------------------------------------------------------------
# packing (host side)
# ----------------------------------------------------------------------
def pack_arrays(layout: Layout, arrays: dict[str, np.ndarray]) -> np.ndarray:
    """Pack per-array element codes into the unified layout buffer.

    ``arrays[name]`` holds ``depth`` unsigned element codes (any integer
    dtype; values must fit in the array's declared bitwidth).  Returns a
    ``(c_max, m // 8)`` uint8 buffer.  Requires ``m % 8 == 0`` and
    element widths <= 64.
    """
    prob = layout.problem
    if prob.m % 8 != 0:
        raise ValueError(f"bus width {prob.m} is not byte-aligned")
    row_bytes = prob.m // 8
    # 8 spare bytes so 64-bit scatter windows never clip at the row edge
    buf = np.zeros((layout.c_max, row_bytes + 9), dtype=np.uint8)

    data: list[np.ndarray] = []
    for i, spec in enumerate(prob.arrays):
        if spec.name not in arrays:
            raise KeyError(f"missing array {spec.name!r}")
        a = np.asarray(arrays[spec.name]).reshape(-1).astype(np.uint64)
        if a.shape[0] != spec.depth:
            raise ValueError(
                f"{spec.name}: expected {spec.depth} elements, got {a.shape[0]}"
            )
        if spec.width > 64:
            raise ValueError(f"{spec.name}: width {spec.width} > 64 unsupported")
        if spec.width < 64 and (a >> np.uint64(spec.width)).any():
            raise ValueError(f"{spec.name}: codes overflow {spec.width} bits")
        data.append(a)

    for iv in layout.intervals():
        rows = slice(iv.start_cycle, iv.start_cycle + iv.n_cycles)
        for (array, off, n), base in zip(iv.slots, iv.elem_base):
            w = prob.arrays[array].width
            elems = data[array][base:base + n * iv.n_cycles]
            elems = elems.reshape(iv.n_cycles, n)
            for k in range(n):
                _scatter_bits(buf[rows], elems[:, k], off + k * w, w)
    return buf[:, :row_bytes]


def _scatter_bits(rows: np.ndarray, vals: np.ndarray, bit_off: int,
                  width: int) -> None:
    """OR ``width``-bit values into byte rows at ``bit_off`` (LSB-first)."""
    byte_lo = bit_off >> 3
    shift = bit_off & 7
    lo = (vals << np.uint64(shift)).astype(np.uint64)
    if shift:
        hi = (vals >> np.uint64(64 - shift)).astype(np.uint64)
    else:
        hi = np.zeros_like(vals)
    lo_bytes = lo.view(np.uint8).reshape(vals.shape[0], 8)
    if lo_bytes.base is not None and not lo.flags.c_contiguous:  # pragma: no cover
        lo_bytes = np.ascontiguousarray(lo).view(np.uint8).reshape(-1, 8)
    rows[:, byte_lo:byte_lo + 8] |= lo_bytes
    rows[:, byte_lo + 8] |= hi.astype(np.uint8)


def unpack_arrays(layout: Layout, buf: np.ndarray) -> dict[str, np.ndarray]:
    """Inverse of :func:`pack_arrays` — the oracle for the decode kernel."""
    prob = layout.problem
    row_bytes = prob.m // 8
    if buf.shape != (layout.c_max, row_bytes):
        raise ValueError(
            f"buffer shape {buf.shape} != ({layout.c_max}, {row_bytes})"
        )
    padded = np.zeros((layout.c_max, row_bytes + 9), dtype=np.uint8)
    padded[:, :row_bytes] = buf
    out = {
        a.name: np.zeros(a.depth, dtype=np.uint64) for a in prob.arrays
    }
    for iv in layout.intervals():
        rows = padded[iv.start_cycle:iv.start_cycle + iv.n_cycles]
        for (array, off, n), base in zip(iv.slots, iv.elem_base):
            spec = prob.arrays[array]
            w = spec.width
            vals = np.empty((iv.n_cycles, n), dtype=np.uint64)
            for k in range(n):
                vals[:, k] = _gather_bits(rows, off + k * w, w)
            out[spec.name][base:base + n * iv.n_cycles] = vals.reshape(-1)
    return out


def _gather_bits(rows: np.ndarray, bit_off: int, width: int) -> np.ndarray:
    byte_lo = bit_off >> 3
    shift = bit_off & 7
    window = np.ascontiguousarray(rows[:, byte_lo:byte_lo + 8])
    lo = window.view(np.uint64).reshape(-1) >> np.uint64(shift)
    if shift:
        hi = rows[:, byte_lo + 8].astype(np.uint64) << np.uint64(64 - shift)
        lo = lo | hi
    if width < 64:
        lo = lo & np.uint64((1 << width) - 1)
    return lo


# ----------------------------------------------------------------------
# decode plan (accelerator side)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SlotPlan:
    """One (interval, slot) decode unit — fully static, kernel-ready."""

    array: int          # index into problem.arrays
    name: str
    width: int          # element bits
    start_cycle: int    # first bus cycle of the interval
    n_cycles: int       # cycles in the interval
    bit_offset: int     # LSB offset of lane 0 within the bus row
    lanes: int          # elements per cycle
    elem_base: int      # index of the first element decoded by this unit


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """Static decode program: the paper's Listing 2 as a table.

    ``slots`` are ordered by start_cycle (stream order).  ``fifo_depths``
    and ``write_ports`` size the decode module's staging memories.
    """

    m: int
    c_max: int
    slots: tuple[SlotPlan, ...]
    fifo_depths: dict[str, int]
    write_ports: dict[str, int]

    @property
    def n_units(self) -> int:
        return len(self.slots)


def decode_plan(layout: Layout) -> DecodePlan:
    prob = layout.problem
    slots: list[SlotPlan] = []
    for iv in layout.intervals():
        for (array, off, n), base in zip(iv.slots, iv.elem_base):
            spec = prob.arrays[array]
            slots.append(
                SlotPlan(
                    array=array,
                    name=spec.name,
                    width=spec.width,
                    start_cycle=iv.start_cycle,
                    n_cycles=iv.n_cycles,
                    bit_offset=off,
                    lanes=n,
                    elem_base=base,
                )
            )
    fifo = {a.name: d for a, d in zip(prob.arrays, layout.fifo_depths())}
    ports = {
        a.name: p for a, p in zip(prob.arrays, layout.max_concurrent_elems())
    }
    return DecodePlan(
        m=prob.m,
        c_max=layout.c_max,
        slots=tuple(sorted(slots, key=lambda s: (s.start_cycle, s.bit_offset))),
        fifo_depths=fifo,
        write_ports=ports,
    )


# ----------------------------------------------------------------------
# literal C emission (paper Listing 1 / Listing 2 artifacts)
# ----------------------------------------------------------------------
def emit_c_pack(layout: Layout, word_bits: int = 64) -> str:
    """Emit the host-side C pack() function in the style of Listing 1."""
    prob = layout.problem
    args = ", ".join(f"const uint64_t* {a.name}" for a in prob.arrays)
    lines = [
        f"// auto-generated by Iris: m={prob.m}, C_max={layout.c_max}",
        f"void pack({args}, uint8_t* out) {{",
    ]
    for a in prob.arrays:
        lines.append(
            f"  // {a.name}: W={a.width}, D={a.depth}, d={a.due}"
        )
    for iv in layout.intervals():
        who = ", ".join(
            f"{prob.arrays[s[0]].name}x{s[2]}" for s in iv.slots
        )
        hdr = (
            f"  // cycles {iv.start_cycle}..{iv.start_cycle + iv.n_cycles - 1}"
            f" : {who}"
        )
        lines.append(hdr)
        body = []
        for (array, off, n), _base in zip(iv.slots, iv.elem_base):
            spec = prob.arrays[array]
            for k in range(n):
                bit = off + k * spec.width
                body.append(
                    f"    put_bits(out, t*{prob.m} + {bit}, "
                    f"(*{spec.name}++) & {_mask_lit(spec.width)}, {spec.width});"
                )
        if iv.n_cycles > 1:
            lines.append(
                f"  for (unsigned t = {iv.start_cycle}; "
                f"t < {iv.start_cycle + iv.n_cycles}; t++) {{"
            )
            lines.extend(body)
            lines.append("  }")
        else:
            lines.append(f"  {{ unsigned t = {iv.start_cycle};")
            lines.extend(body)
            lines.append("  }")
    lines.append("}")
    return "\n".join(lines)


def emit_c_decode(layout: Layout) -> str:
    """Emit the accelerator-side read module in the style of Listing 2."""
    prob = layout.problem
    plan = decode_plan(layout)
    streams = ", ".join(
        f"hls::stream<ap_uint<{a.width}>>& data{a.name}" for a in prob.arrays
    )
    lines = [
        f"#define BUSWIDTH {prob.m}",
    ]
    for name, depth in plan.fifo_depths.items():
        lines.append(f"#define {name}_FIFO_DEPTH {max(1, depth)}")
    lines += [
        f"void read_data(ap_uint<BUSWIDTH>* in_buf, {streams}) {{",
        f"  ap_uint<BUSWIDTH> elem;",
        f"  for (unsigned t = 0; t < {plan.c_max}; t++) {{",
        "#pragma HLS pipeline II=1",
        "    elem = in_buf[t];",
    ]
    first = True
    for iv in layout.intervals():
        lo, hi = iv.start_cycle, iv.start_cycle + iv.n_cycles - 1
        cond = f"t == {lo}" if lo == hi else f"t >= {lo} && t <= {hi}"
        kw = "if" if first else "} else if"
        first = False
        lines.append(f"    {kw} ({cond}) {{")
        for (array, off, n), _base in zip(iv.slots, iv.elem_base):
            spec = prob.arrays[array]
            for k in range(n):
                b0 = off + k * spec.width
                lines.append(
                    f"      data{spec.name} << elem.range("
                    f"{b0 + spec.width - 1}, {b0});"
                )
        lines.append("    ")
    lines += ["    }", "  }", "}"]
    return "\n".join(lines)


def _mask_lit(width: int) -> str:
    return hex((1 << width) - 1)


def random_codes(problem: LayoutProblem, seed: int = 0) -> dict[str, np.ndarray]:
    """Random element codes respecting each array's bitwidth (test helper)."""
    rng = np.random.default_rng(seed)
    out = {}
    for a in problem.arrays:
        if a.width == 64:
            vals = rng.integers(0, 1 << 63, size=a.depth, dtype=np.uint64)
        else:
            vals = rng.integers(0, 1 << a.width, size=a.depth,
                                dtype=np.uint64)
        out[a.name] = vals
    return out
