"""Name -> object registries backing the :mod:`repro.api` façade.

The façade dispatches by *name* over two registries — layout strategies
("iris" plus the paper's baselines) and execution backends ("numpy",
"pallas", "c") — so sweeps, benchmarks and comparisons iterate one table
instead of importing one function per layout family.  The registry is
deliberately tiny: insertion-ordered, no priorities, no entry points;
third-party strategies register by calling :meth:`Registry.register` at
import time.
"""
from __future__ import annotations

from typing import Generic, Iterator, TypeVar

T = TypeVar("T")


class Registry(Generic[T]):
    """Insertion-ordered name -> object table with helpful lookup errors.

    A failed :meth:`get` raises ``KeyError`` naming the registry kind and
    listing every registered name, so a typo'd ``strategy="irsi"`` is a
    one-glance fix rather than a stack-trace hunt.
    """

    def __init__(self, kind: str) -> None:
        self.kind = kind
        self._entries: dict[str, T] = {}

    def register(self, name: str, obj: T | None = None, *,
                 overwrite: bool = False):
        """Register ``obj`` under ``name``; decorator form when obj omitted.

        Re-registering an existing name raises unless ``overwrite=True``
        (guards against two plugins silently shadowing each other).
        """

        def _add(o: T) -> T:
            if not overwrite and name in self._entries:
                raise KeyError(
                    f"{self.kind} {name!r} is already registered; "
                    "pass overwrite=True to replace it"
                )
            self._entries[name] = o
            return o

        if obj is None:
            return _add
        return _add(obj)

    def get(self, name: str) -> T:
        try:
            return self._entries[name]
        except KeyError:
            known = ", ".join(repr(n) for n in self._entries) or "(none)"
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered: {known}"
            ) from None

    def names(self) -> list[str]:
        return list(self._entries)

    def __contains__(self, name: object) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {self.names()})"
