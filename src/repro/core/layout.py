"""Layout IR: the output of the Iris scheduler and its metrics.

A :class:`Layout` assigns every element of every array to a (cycle, bit
offset) position on the bus.  Layouts are produced forward in *release-time*
space by the scheduler and reversed into *due-date* space (paper §4: "the
final layout must be reversed to target L_max").

The ground-truth representation is **interval-native**: a list of
(n_cycles, counts) runs where ``counts`` is the constant per-cycle slot
structure ``(array, elems_per_cycle)`` in lane order.  This is what the
paper's Listing 1 exploits with ``for`` loops, what our Pallas decode kernel
is gridded over, and what keeps billion-element model-packing problems
tractable (metrics and validation are O(intervals), not O(cycles)).

Per-cycle :class:`Segment` views are materialized lazily for small layouts
(rendering, oracle cross-checks).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .task import LayoutProblem

# A per-cycle slot structure: ((array_index, elems_per_cycle), ...) lane order.
Counts = tuple[tuple[int, int], ...]


@dataclasses.dataclass(frozen=True)
class Segment:
    """``n_elems`` consecutive elements of one array in one bus cycle."""

    array: int       # index into problem.arrays
    elem_start: int  # index of the first element transferred
    n_elems: int
    bit_offset: int  # offset of the first element's LSB within the bus word

    def bits(self, problem: LayoutProblem) -> int:
        return self.n_elems * problem.arrays[self.array].width


@dataclasses.dataclass(frozen=True)
class Interval:
    """A run of ``n_cycles`` cycles sharing one per-cycle segment structure.

    ``slots`` holds (array, bit_offset, elems_per_cycle); element indices for
    cycle ``c`` within the interval are ``elem_base[i] + c * elems_per_cycle``.
    """

    start_cycle: int
    n_cycles: int
    slots: tuple[tuple[int, int, int], ...]   # (array, bit_offset, n_elems)
    elem_base: tuple[int, ...]                # first element idx per slot


@dataclasses.dataclass
class LayoutMetrics:
    """Paper metrics: Eq. 1 efficiency, lateness, FIFO depths."""

    c_max: int
    efficiency: float                  # B_eff = p_tot / (C_max * m)
    lateness: dict[str, int]           # L_j per array
    l_max: int
    completion: dict[str, int]         # C_j per array (1-based cycle count)
    fifo_depth: dict[str, int]         # decode-module buffering per array
    wasted_bits: int                   # C_max*m - p_tot

    def row(self) -> dict[str, object]:
        return {
            "C_max": self.c_max,
            "B_eff": round(self.efficiency, 4),
            "L_max": self.l_max,
            "FIFO": dict(self.fifo_depth),
            "wasted_bits": self.wasted_bits,
        }


_MATERIALIZE_LIMIT = 1 << 18  # refuse to expand >256k cycles unless forced


class Layout:
    """A complete bus layout in due-date space, interval-native."""

    def __init__(self, problem: LayoutProblem,
                 count_intervals: Sequence[tuple[int, Counts]], *,
                 _normalized: bool = False) -> None:
        """``count_intervals`` are (n_cycles, counts) runs in final cycle order.

        Element indices are assigned sequentially per array in cycle order;
        bit offsets are packed LSB-first in slot order.

        ``_normalized=True`` asserts the runs are already in canonical
        form — int-valued (n, ((a, e), ...)) tuples with n > 0 and every
        e > 0 — and skips the per-entry rebuild.  Only the scheduler and
        cache paths, whose runs are canonical by construction, set it;
        ``_build_intervals`` still bounds- and coverage-checks either way.
        """
        self.problem = problem
        # immutable so layouts can be shared safely (e.g. cache hits
        # handing out the same object to many callers)
        if _normalized:
            self.count_intervals = tuple(count_intervals)
        else:
            self.count_intervals = tuple(
                (int(n), tuple((int(a), int(e)) for a, e in counts if e > 0))
                for n, counts in count_intervals
                if n > 0
            )
        self._intervals: list[Interval] | None = None
        self._cycles: list[list[Segment]] | None = None
        # lowered execution programs (repro.core.exec_plan), keyed by
        # piece-width tuple; shared across rebinds (programs are
        # name-free), so a LayoutCache hit never re-lowers
        self._exec_cache: dict[tuple, object] = {}
        # vectorized replay tables for warm-started re-planning
        # (repro.core.iris._schedule_warm); name-free like the exec
        # programs, so rebinds share them too
        self._replay_cache: dict[str, object] = {}
        self._flat: tuple | None = None
        # legality (bus overflow, per-array coverage, array-index bounds)
        # is proven vectorized at construction; the Python Interval list
        # is materialized lazily on first intervals() access, so paths
        # that never enumerate slots (cache loads, metrics) skip it
        self._check_intervals_fast()

    # ------------------------------------------------------------------
    # construction helpers
    # ------------------------------------------------------------------
    @staticmethod
    def from_counts(problem: LayoutProblem,
                    count_cycles: Sequence[Counts],
                    reverse: bool = False) -> "Layout":
        """Build from per-cycle (array, n_elems) counts, merging runs.

        ``reverse=True`` flips the cycle order first (release-time space ->
        due-date space).
        """
        seq = list(reversed(count_cycles)) if reverse else list(count_cycles)
        runs: list[tuple[int, Counts]] = []
        for counts in seq:
            counts = tuple((a, e) for a, e in counts if e > 0)
            if runs and runs[-1][1] == counts:
                runs[-1] = (runs[-1][0] + 1, counts)
            else:
                runs.append((1, counts))
        return Layout(problem, runs)

    @staticmethod
    def from_count_intervals(problem: LayoutProblem,
                             intervals: Sequence[tuple[int, Counts]],
                             reverse: bool = False, *,
                             _normalized: bool = False) -> "Layout":
        seq = list(reversed(intervals)) if reverse else list(intervals)
        return Layout(problem, seq, _normalized=_normalized)

    def rebind(self, problem: LayoutProblem) -> "Layout":
        """Re-attach this layout to ``problem`` without re-scheduling.

        ``problem`` must pose the same scheduling instance (same
        ``canonical_signature``) — typically it differs only in array
        names.  O(intervals): the count runs are reused verbatim; this is
        what makes a :class:`repro.core.iris.LayoutCache` hit cheap.
        """
        if problem == self.problem:
            return self
        if problem.canonical_signature() != self.problem.canonical_signature():
            raise ValueError(
                "rebind target is a different scheduling instance"
            )
        lay = Layout(problem, self.count_intervals, _normalized=True)
        lay._exec_cache = self._exec_cache
        lay._replay_cache = self._replay_cache
        # intervals and flat views are name-free — share them too
        lay._intervals = self._intervals
        lay._flat = self._flat
        return lay

    def flat_counts(self) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
        """``(run_id, array_id, count, taus)`` int64 views of the count
        runs, one entry per (run, slot).  Memoized — shared by the
        constructor legality check and the analysis interval screen so
        the Python flatten happens once per layout."""
        if self._flat is None:
            run_id: list[int] = []
            arrs: list[int] = []
            cnts: list[int] = []
            for r, (_n, counts) in enumerate(self.count_intervals):
                for a, e in counts:
                    run_id.append(r)
                    arrs.append(a)
                    cnts.append(e)
            self._flat = (
                np.asarray(run_id, dtype=np.int64),
                np.asarray(arrs, dtype=np.int64),
                np.asarray(cnts, dtype=np.int64),
                np.asarray([n for n, _c in self.count_intervals],
                           dtype=np.int64),
            )
        return self._flat

    def _check_intervals_fast(self) -> None:
        """Vectorized legality proof: every run fits the bus and every
        array is scheduled to exactly its depth.  Same error classes as
        the slot-by-slot build (IndexError on out-of-range array ids,
        ValueError on overflow / coverage), at numpy cost."""
        prob = self.problem
        run_np, arr_np, cnt_np, taus = self.flat_counts()
        n_arrays = len(prob.arrays)
        depths = np.asarray([a.depth for a in prob.arrays], dtype=np.int64)
        if not arr_np.size:
            bad = int(np.argmax(depths != 0)) if (depths != 0).any() else -1
            if bad >= 0:
                raise ValueError(
                    f"array {prob.arrays[bad].name}: scheduled 0 of "
                    f"{prob.arrays[bad].depth} elements"
                )
            return
        if ((arr_np >= n_arrays) | (arr_np < -n_arrays)).any():
            raise IndexError("array index out of range")
        widths = np.asarray([a.width for a in prob.arrays], dtype=np.int64)
        used = np.zeros(len(self.count_intervals), dtype=np.int64)
        np.add.at(used, run_np, cnt_np * widths[arr_np])
        if (used > prob.m).any():
            r = int(np.argmax(used > prob.m))
            t = sum(n for n, _c in self.count_intervals[:r])
            raise ValueError(
                f"interval at cycle {t} overflows the bus: "
                f"{int(used[r])} > {prob.m} bits"
            )
        scheduled = np.zeros(n_arrays, dtype=np.int64)
        np.add.at(scheduled, arr_np, cnt_np * taus[run_np])
        if (scheduled != depths).any():
            i = int(np.argmax(scheduled != depths))
            raise ValueError(
                f"array {prob.arrays[i].name}: scheduled {int(scheduled[i])} "
                f"of {prob.arrays[i].depth} elements"
            )

    def _build_intervals(self) -> None:
        prob = self.problem
        next_elem = [0] * len(prob.arrays)
        out: list[Interval] = []
        t = 0
        for n_cycles, counts in self.count_intervals:
            offset = 0
            slots: list[tuple[int, int, int]] = []
            base: list[int] = []
            for array, n in counts:
                spec = prob.arrays[array]
                slots.append((array, offset, n))
                base.append(next_elem[array])
                next_elem[array] += n * n_cycles
                offset += n * spec.width
            out.append(Interval(t, n_cycles, tuple(slots), tuple(base)))
            t += n_cycles
        self._intervals = out

    # ------------------------------------------------------------------
    # validation (O(intervals * slots))
    # ------------------------------------------------------------------
    def validate(self) -> None:
        """Check the layout is a legal, complete transfer plan."""
        prob = self.problem
        ranges: list[list[tuple[int, int]]] = [[] for _ in prob.arrays]
        for iv in self.intervals():
            used = 0
            bit_ranges: list[tuple[int, int]] = []
            for (array, off, n), base in zip(iv.slots, iv.elem_base):
                spec = prob.arrays[array]
                if n <= 0:
                    raise AssertionError("empty slot in interval")
                hi = off + n * spec.width
                if hi > prob.m:
                    raise AssertionError(
                        f"cycle {iv.start_cycle}: slot exceeds bus width"
                    )
                bit_ranges.append((off, hi))
                used += n * spec.width
                # slot covers elements [base, base + n * n_cycles)
                ranges[array].append((base, base + n * iv.n_cycles))
            if used > prob.m:
                raise AssertionError(
                    f"cycle {iv.start_cycle}: {used} bits > bus {prob.m}"
                )
            bit_ranges.sort()
            for (a0, a1), (b0, b1) in zip(bit_ranges, bit_ranges[1:]):
                if b0 < a1:
                    raise AssertionError(
                        f"cycle {iv.start_cycle}: overlapping bit ranges"
                    )
        for i, spec in enumerate(prob.arrays):
            rs = sorted(ranges[i])
            pos = 0
            for lo, hi in rs:
                if lo != pos:
                    raise AssertionError(
                        f"array {spec.name}: elements "
                        f"[{min(lo, pos)},{max(lo, pos)}) duplicated or missing"
                    )
                pos = hi
            if pos != spec.depth:
                raise AssertionError(
                    f"array {spec.name}: {spec.depth - pos} elements "
                    "never transferred"
                )

    # ------------------------------------------------------------------
    # derived views
    # ------------------------------------------------------------------
    @property
    def c_max(self) -> int:
        return sum(n for n, _ in self.count_intervals)

    def intervals(self) -> list[Interval]:
        if self._intervals is None:
            self._build_intervals()
        assert self._intervals is not None
        return self._intervals

    @property
    def cycles(self) -> list[list[Segment]]:
        """Per-cycle segment lists (materialized; small layouts only)."""
        if self._cycles is None:
            if self.c_max > _MATERIALIZE_LIMIT:
                raise RuntimeError(
                    f"refusing to materialize {self.c_max} cycles; "
                    "use intervals() instead"
                )
            out: list[list[Segment]] = []
            for iv in self.intervals():
                for c in range(iv.n_cycles):
                    segs = [
                        Segment(array, base + c * n, n, off)
                        for (array, off, n), base in zip(iv.slots, iv.elem_base)
                    ]
                    out.append(segs)
            self._cycles = out
        return self._cycles

    def element_positions(self, array: int) -> list[tuple[int, int]]:
        """(cycle, bit_offset) per element, in element order."""
        spec = self.problem.arrays[array]
        pos: list[tuple[int, int] | None] = [None] * spec.depth
        for iv in self.intervals():
            for (arr, off, n), base in zip(iv.slots, iv.elem_base):
                if arr != array:
                    continue
                for c in range(iv.n_cycles):
                    for k in range(n):
                        pos[base + c * n + k] = (
                            iv.start_cycle + c,
                            off + k * spec.width,
                        )
        assert all(p is not None for p in pos)
        return pos  # type: ignore[return-value]

    # ------------------------------------------------------------------
    # metrics (paper §4, §6) — interval-native, O(intervals)
    # ------------------------------------------------------------------
    def metrics(self) -> LayoutMetrics:
        prob = self.problem
        last = [0] * len(prob.arrays)
        for iv in self.intervals():
            for (array, _off, _n) in iv.slots:
                last[array] = max(last[array], iv.start_cycle + iv.n_cycles)
        completion = {a.name: last[i] for i, a in enumerate(prob.arrays)}
        lateness = {a.name: last[i] - a.due for i, a in enumerate(prob.arrays)}
        fifo = {a.name: d for a, d in zip(prob.arrays, self.fifo_depths())}
        c_max = self.c_max
        return LayoutMetrics(
            c_max=c_max,
            efficiency=prob.p_tot / (c_max * prob.m),
            lateness=lateness,
            l_max=max(lateness.values()),
            completion=completion,
            fifo_depth=fifo,
            wasted_bits=c_max * prob.m - prob.p_tot,
        )

    def fifo_depths(self) -> list[int]:
        """Decode-side buffering per array (paper §5 running sum).

        The read module forwards one element per array per cycle to its
        stream; the surplus ``e_c - 1`` elements in a cycle must be staged.
        Depth = max backlog over the schedule, computed analytically per
        interval (arrival rate is constant within an interval).
        Reproduces the paper's reported depths exactly (Helmholtz naive
        u -> 998, MM (64,64) naive -> 468 / Iris -> 312).
        """
        n = len(self.problem.arrays)
        backlog = [0] * n
        depth = [0] * n
        for iv in self.intervals():
            arrived = [0] * n
            for (array, _off, cnt) in iv.slots:
                arrived[array] += cnt
            for i in range(n):
                e = arrived[i]
                tau = iv.n_cycles
                if e == 0:
                    backlog[i] = max(0, backlog[i] - tau)
                elif e == 1:
                    pass  # steady state: one in, one out
                else:
                    backlog[i] += (e - 1) * tau
                    depth[i] = max(depth[i], backlog[i])
        return depth

    def max_concurrent_elems(self) -> list[int]:
        """Max elements of each array in any single cycle (write-port count)."""
        n = len(self.problem.arrays)
        peak = [0] * n
        for iv in self.intervals():
            arrived = [0] * n
            for (array, _off, cnt) in iv.slots:
                arrived[array] += cnt
            for i in range(n):
                peak[i] = max(peak[i], arrived[i])
        return peak

    # ------------------------------------------------------------------
    def render(self, max_cycles: int = 64) -> str:
        """ASCII rendering in the style of the paper's Figs. 3-5."""
        prob = self.problem
        lines = []
        shown = 0
        for iv in self.intervals():
            for c in range(iv.n_cycles):
                if shown >= max_cycles:
                    lines.append(f"  ... ({self.c_max - shown} more cycles)")
                    return "\n".join(lines)
                row = ["."] * prob.m
                for (array, off, n), _base in zip(iv.slots, iv.elem_base):
                    spec = prob.arrays[array]
                    for k in range(n):
                        lo = off + k * spec.width
                        for b in range(spec.width):
                            row[lo + b] = spec.name[0]
                lines.append(f"{iv.start_cycle + c:4d} |{''.join(row)}|")
                shown += 1
        return "\n".join(lines)
