"""The paper's primary contribution: the Iris bus-layout system.

Curated public surface of the core package: the *types* (problem spec,
layout IR, program tables, registries) import eagerly and warning-free.
The pre-façade *workflow entry points* (``schedule``, ``pack_arrays``,
baseline constructors, ...) are kept alive for compatibility but emit a
``DeprecationWarning`` naming the :mod:`repro.api` replacement — the
façade is the front door for the end-to-end pipeline.  Deeper module
paths (``repro.core.iris.schedule`` etc.) remain stable, warning-free
import targets.
"""
from __future__ import annotations

import importlib
import warnings

from .codegen import DecodePlan, SlotPlan
from .exec_plan import ExecProgram, KernelTable
from .iris import LayoutCache
from .layout import Counts, Interval, Layout, LayoutMetrics, Segment
from .registry import Registry
from .task import ArraySpec, LayoutProblem

#: deprecated workflow entry points: name -> (defining module, replacement)
_DEPRECATED = {
    # problem constructors / fixtures
    "make_problem": ("repro.core.task", "repro.api.make_problem"),
    "matmul_problem": ("repro.core.task", "repro.api.matmul_problem"),
    "PAPER_EXAMPLE": ("repro.core.task", "repro.api.PAPER_EXAMPLE"),
    "INV_HELMHOLTZ": ("repro.core.task", "repro.api.INV_HELMHOLTZ"),
    # scheduler + cache singleton
    "schedule": ("repro.core.iris", "repro.api.plan(problem).layout"),
    "schedule_many": ("repro.core.iris", "repro.api.plan_many"),
    "DEFAULT_CACHE": ("repro.core.iris", "repro.core.iris.DEFAULT_CACHE"),
    # baselines
    "naive_layout": ("repro.core.baselines",
                     "repro.api.plan(problem, strategy='naive')"),
    "homogeneous_layout": ("repro.core.baselines",
                           "repro.api.plan(problem, "
                           "strategy='homogeneous')"),
    "hls_padded_layout": ("repro.core.baselines",
                          "repro.api.plan(problem, "
                          "strategy='hls_padded')"),
    "ALL_BASELINES": ("repro.core.baselines", "repro.api.STRATEGIES"),
    # codegen / execution
    "decode_plan": ("repro.core.codegen", "repro.api.Plan.decode_plan"),
    "pack_arrays": ("repro.core.codegen", "repro.api.Plan.pack"),
    "unpack_arrays": ("repro.core.codegen",
                      "repro.api.Plan.decode(buf, backend='numpy')"),
    "emit_c_pack": ("repro.core.codegen",
                    "repro.api.Plan.emit(target='c', artifact='pack')"),
    "emit_c_decode": ("repro.core.codegen",
                      "repro.api.Plan.emit(target='c')"),
    "random_codes": ("repro.core.codegen", "repro.api.random_codes"),
    "lower_exec": ("repro.core.exec_plan", "repro.api.Plan.exec_program"),
    "pack_compiled": ("repro.core.exec_plan",
                      "repro.api.Plan.pack(compiled=True)"),
    "unpack_compiled": ("repro.core.exec_plan",
                        "repro.api.Plan.decode(buf, backend='numpy')"),
}


def __getattr__(name: str):
    """Serve (and deprecate) the pre-façade workflow aliases lazily."""
    try:
        mod_path, repl = _DEPRECATED[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}"
        ) from None
    warnings.warn(
        f"repro.core.{name} is deprecated; use {repl}",
        DeprecationWarning, stacklevel=2,
    )
    return getattr(importlib.import_module(mod_path), name)


__all__ = [
    # problem spec & layout IR (stable types)
    "ArraySpec", "LayoutProblem",
    "Layout", "LayoutMetrics", "Interval", "Segment", "Counts",
    "LayoutCache",
    # program tables & registries (stable types)
    "DecodePlan", "SlotPlan", "ExecProgram", "KernelTable", "Registry",
    # deprecated workflow entry points (DeprecationWarning on access)
    *sorted(_DEPRECATED),
]
