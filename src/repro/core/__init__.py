"""The paper's primary contribution: the Iris bus-layout system.

Curated public surface of the core package — problem spec, scheduler
engine + layout cache, layout IR & metrics, the baseline layouts, and
decode codegen.  Deeper module paths (``repro.core.iris`` etc.) remain
stable import targets; prefer the :mod:`repro.api` façade for the
end-to-end pipeline.
"""
from .baselines import (
    ALL_BASELINES,
    hls_padded_layout,
    homogeneous_layout,
    naive_layout,
)
from .codegen import (
    DecodePlan,
    SlotPlan,
    decode_plan,
    emit_c_decode,
    emit_c_pack,
    pack_arrays,
    random_codes,
    unpack_arrays,
)
from .exec_plan import (
    ExecProgram,
    KernelTable,
    lower_exec,
    pack_compiled,
    unpack_compiled,
)
from .iris import DEFAULT_CACHE, LayoutCache, schedule, schedule_many
from .layout import Counts, Interval, Layout, LayoutMetrics, Segment
from .registry import Registry
from .task import (
    INV_HELMHOLTZ,
    PAPER_EXAMPLE,
    ArraySpec,
    LayoutProblem,
    make_problem,
    matmul_problem,
)

__all__ = [
    # problem spec
    "ArraySpec", "LayoutProblem", "make_problem",
    "PAPER_EXAMPLE", "INV_HELMHOLTZ", "matmul_problem",
    # scheduler + cache
    "schedule", "schedule_many", "LayoutCache", "DEFAULT_CACHE",
    # layout IR & metrics
    "Layout", "LayoutMetrics", "Interval", "Segment", "Counts",
    # baselines
    "naive_layout", "homogeneous_layout", "hls_padded_layout",
    "ALL_BASELINES",
    # codegen
    "DecodePlan", "SlotPlan", "decode_plan", "pack_arrays",
    "unpack_arrays", "emit_c_pack", "emit_c_decode", "random_codes",
    # compiled execution plans
    "ExecProgram", "KernelTable", "lower_exec", "pack_compiled",
    "unpack_compiled",
    # registries
    "Registry",
]
