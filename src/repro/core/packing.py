"""Model-level Iris integration: parameter bundles -> layouts -> buffers.

The serving-side instantiation of the paper: a transformer layer's
parameters are a bundle of *heterogeneous-width* arrays — int4/int3 weight
codes, 8/16-bit scales, bf16 norm vectors, fp32 biases — consumed at
different points of the layer dataflow.  We treat each bundle as an Iris
problem:

* bus width ``m`` = one HBM burst line (default 4096 bits = 512 B);
* array widths = the custom-precision element widths;
* due dates = the consuming op's position in the layer dataflow
  (attn-norm -> QKV -> O -> mlp-norm -> gate/up -> down), scaled to
  cycle units — the paper's "due dates derived from the dataflow graph";

and emit one unified stream buffer per layer.  Streaming that buffer
moves ``p_tot`` useful bits at ``B_eff`` bus efficiency; the comparison
against per-tensor padded storage (HLS-style lane padding) is exactly the
paper's Table 7 experiment at LM scale, reported by
``benchmarks/bench_packing.py``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.quant.qtypes import QuantSpec

from .codegen import decode_plan
from .exec_plan import ExecProgram, lower_exec, pack_compiled
from .iris import DEFAULT_CACHE, LayoutCache
from .layout import Layout
from .task import ArraySpec, LayoutProblem
from .util import pad_bundle_elements  # noqa: F401  (compat re-export)

#: dataflow order of a standard decoder layer: (tensor role -> stage)
LAYER_STAGES = (
    ("attn_norm", 0),
    ("wq", 1), ("wk", 1), ("wv", 1),
    ("wo", 2),
    ("mlp_norm", 3),
    ("w_gate", 4), ("w_up", 4),
    ("w_down", 5),
)


@dataclasses.dataclass(frozen=True)
class BundleTensor:
    """One member of a layer bundle."""

    name: str
    width_bits: int
    n_elems: int
    stage: int             # dataflow stage (0 = needed first)


@dataclasses.dataclass
class PackedBundle:
    problem: LayoutProblem
    layout: Layout
    buffer: np.ndarray | None       # (c_max, m//8) uint8, None if plan-only
    metrics_iris: dict
    metrics_homogeneous: dict
    metrics_padded: dict
    #: compiled execution plan at bundle-element granularity (piece width
    #: = each tensor's width_bits); shared via the layout's exec cache
    exec_program: ExecProgram | None = None

    @property
    def stream_bytes(self) -> int:
        return self.layout.c_max * self.problem.m // 8

    def decode_plan(self):
        return decode_plan(self.layout)

    def unpack(self, buf: np.ndarray | None = None) -> dict[str, np.ndarray]:
        """Element-granularity codes from a packed buffer (vectorized).

        Tensors are padded up to whole scheduling units; trailing pad
        elements decode as zeros.
        """
        buf = self.buffer if buf is None else buf
        if buf is None:
            raise ValueError("bundle was planned without data")
        out = self.exec_program.unpack_indexed(np.asarray(buf))
        names = [a.name for a in self.problem.arrays]
        return {names[i]: v for i, v in out.items()}


def layer_bundle_spec(d_model: int, d_ff: int, n_heads: int,
                      n_kv_heads: int, head_dim: int,
                      qspec: QuantSpec) -> list[BundleTensor]:
    """The bundle for one dense decoder layer under weight quantization."""
    g = qspec.group_size
    out: list[BundleTensor] = []

    def w(name, d_in, d_out, stage):
        out.append(BundleTensor(name, qspec.bits, d_in * d_out, stage))
        out.append(BundleTensor(f"{name}_scales", 16,
                                (d_in // g) * d_out, stage))

    out.append(BundleTensor("attn_norm", 16, d_model, 0))
    w("wq", d_model, n_heads * head_dim, 1)
    w("wk", d_model, n_kv_heads * head_dim, 1)
    w("wv", d_model, n_kv_heads * head_dim, 1)
    w("wo", n_heads * head_dim, d_model, 2)
    out.append(BundleTensor("mlp_norm", 16, d_model, 3))
    w("w_gate", d_model, d_ff, 4)
    w("w_up", d_model, d_ff, 4)
    w("w_down", d_ff, d_model, 5)
    return out


def bundle_problem(bundle: list[BundleTensor], m: int = 4096,
                   lanes_target: int = 16) -> LayoutProblem:
    """Build the Iris problem for a bundle.

    Arrays are scheduled in *units* of consecutive elements — sized per
    tensor so ~``lanes_target`` units fit one bus line — keeping depths in
    the 10^3..10^5 range where the scheduler is fast while preserving the
    lane-level freedom Iris needs to interleave tensors (a unit as wide as
    the bus degenerates to the homogeneous layout).  The layout tiles back
    to element granularity because units are width-homogeneous.  Due
    dates: proportional allocation of the ideal stream time by cumulative
    stage work (the paper's dataflow-derived due dates).
    """
    arrays = []
    # total stream cycles at 100% efficiency
    p_tot_bits = sum(b.width_bits * b.n_elems for b in bundle)
    total_cycles = max(1, p_tot_bits // m)
    # cumulative work per stage defines the due date of that stage
    stage_bits: dict[int, int] = {}
    for b in bundle:
        stage_bits[b.stage] = stage_bits.get(b.stage, 0) \
            + b.width_bits * b.n_elems
    cum = 0
    stage_due: dict[int, int] = {}
    for s in sorted(stage_bits):
        cum += stage_bits[s]
        stage_due[s] = max(1, int(total_cycles * cum / p_tot_bits))
    for b in bundle:
        unit = max(1, m // (lanes_target * b.width_bits))
        depth = -(-b.n_elems // unit)
        width = b.width_bits * unit
        arrays.append(ArraySpec(
            name=b.name, width=width, depth=depth, due=stage_due[b.stage]))
    return LayoutProblem(m=m, arrays=tuple(arrays))




def pack_bundle(bundle: list[BundleTensor], m: int = 4096,
                data: dict[str, np.ndarray] | None = None,
                mode: str = "auto",
                cache: LayoutCache | None = DEFAULT_CACHE) -> PackedBundle:
    """Schedule (and optionally pack) one layer bundle.

    Layer bundles of uniform decoder stacks are identical scheduling
    instances, so the shared ``cache`` makes every layer after the first
    (and every repeated serving request) a cache hit — the scheduler
    never re-runs.
    """
    # deferred façade import: core stays importable without repro.api,
    # mirroring api.plan_layer_stack's deferred import of this module
    from repro import api

    prob = bundle_problem(bundle, m=m)
    pl = api.plan(prob, "iris", mode=mode, cache=cache).validate()
    lay = pl.layout
    # compiled execution plan at element granularity: the program's piece
    # width is each tensor's width_bits, so element data packs directly —
    # no per-unit merge loop, and >64-bit scheduling units pack fine
    ew = tuple(b.width_bits for b in bundle)
    prog = lower_exec(lay, elem_widths=ew)
    buf = None
    if data is not None:
        buf = pack_compiled(lay, pad_bundle_elements(prob, prog, data),
                            program=prog)
    baselines = api.compare(prob, strategies=("homogeneous", "hls_padded"))
    return PackedBundle(
        problem=prob,
        layout=lay,
        buffer=buf,
        metrics_iris=pl.metrics.row(),
        metrics_homogeneous=baselines["homogeneous"].row(),
        metrics_padded=baselines["hls_padded"].row(),
        exec_program=prog,
    )


def _next_pow2(w: int) -> int:
    return 1 << (w - 1).bit_length()


def _per_tensor_cycles(width: int, n_elems: int, m: int) -> int:
    """Bus lines for one tensor stored alone (line-aligned buffer)."""
    lanes = max(1, m // width)
    return -(-n_elems // lanes)


def serving_stream_report(cfg, qspec: QuantSpec, m: int = 4096,
                          cache: LayoutCache | None = DEFAULT_CACHE) -> dict:
    """Bytes-per-layer comparison for decode-step weight streaming.

    Baselines are computed at *element* granularity, matching real
    deployments:

    * ``bf16``      — unquantized weights (2 B/elem);
    * ``padded``    — custom-width codes stored in the next power-of-two
      container (3b->4b, 5b/6b->8b: what frameworks do when a width has no
      native packed type), one line-aligned buffer per tensor;
    * ``homogeneous`` — dense bit-packing per tensor (paper Fig. 4), one
      line-aligned buffer per tensor, no cross-tensor interleaving;
    * ``iris``      — the unified Iris stream (this work): dense packing
      *plus* dataflow-ordered interleaving, which additionally minimizes
      arrival lateness (L_max) and decode staging (FIFO depth).
    """
    from repro import api

    stack = api.plan_layer_stack(cfg, qspec, m=m, n_layers=1, cache=cache)
    bundle = stack.bundle
    pl = stack.plans[0]
    unit_metrics = api.compare(stack.problem, strategies=("homogeneous",))
    p_tot_bits = sum(b.width_bits * b.n_elems for b in bundle)
    n_elems = sum(b.n_elems for b in bundle)
    hom_cycles = sum(
        _per_tensor_cycles(b.width_bits, b.n_elems, m) for b in bundle)
    pad_cycles = sum(
        _per_tensor_cycles(_next_pow2(b.width_bits), b.n_elems, m)
        for b in bundle)
    line_b = m / 8
    iris_row = pl.metrics.row()
    hom_row = unit_metrics["homogeneous"].row()
    return {
        "arch": cfg.name,
        "bits": qspec.bits,
        "useful_MiB_per_layer": p_tot_bits / 8 / 2**20,
        "iris_MiB_per_layer": stack.stream_bytes_per_layer / 2**20,
        "homogeneous_MiB_per_layer": hom_cycles * line_b / 2**20,
        "padded_MiB_per_layer": pad_cycles * line_b / 2**20,
        "bf16_MiB_per_layer": n_elems * 2 / 2**20,
        "iris_efficiency": iris_row["B_eff"],
        "homogeneous_efficiency": p_tot_bits / (hom_cycles * m),
        "padded_efficiency": p_tot_bits / (pad_cycles * m),
        "iris_L_max": iris_row["L_max"],
        "homogeneous_unit_L_max": hom_row["L_max"],
        "iris_unit_fifo": sum(iris_row["FIFO"].values()),
        "homogeneous_unit_fifo": sum(hom_row["FIFO"].values()),
        "n_decode_units": pl.decode_plan.n_units,
    }
