"""Iris layout scheduler (paper Algorithms 1.1, 1.2, 1.3).

The bus-layout problem is solved as preemptive multiprocessor scheduling of
linear-speedup tasks (Drozdowski 1996): the m-bit bus is m identical
processors, array j is a task with processing time ``p_j = W_j * D_j``,
maximum parallelism ``delta_j = floor(m/W_j)*W_j``, and release time
``r_j = d_max - d_j``.  The schedule is computed forward in release-time
space and reversed into due-date space to optimize ``L_max``.

One event-driven engine serves both execution modes:

* ``interval`` — the paper's event-driven form (Alg 1.1 lines 8-13) made
  *exact*: a heap-ordered event queue advances time over releases,
  completions and height-equalizations, and every jump ``tau`` is bounded
  so that FIND_CAPABILITIES is provably constant across the whole run
  (``_exact_tau``).  O(events) instead of O(C_max); required for
  model-packing problems with millions of cycles.
* ``cycle``    — the same engine with ``tau`` pinned to 1: a trivially-
  verifiable per-cycle replay.  Used for paper-scale problems and as the
  ground truth in property tests.

Because the jump bounds account for element indivisibility exactly (the
``delta_eff`` tail correction in ``_exact_tau`` steps cycle-by-cycle once a
task's remaining elements drop below its lane count), both modes emit
**bit-identical** layouts — there are no "O(1)-cycle transient differences"
to tolerate, and mode is therefore not part of the layout-cache key.

Repeated identical problems are served by :class:`LayoutCache`, a
content-addressed LRU keyed on ``LayoutProblem.canonical_signature()``
with an optional persistent on-disk tier (``cache_dir``, or the
``REPRO_CACHE_DIR`` environment variable for the process-wide default);
:func:`schedule_many` batches and dedupes whole problem lists through it,
fanning unique instances over a process pool when one is available.

Near-miss problems — one array added, removed or re-specified against a
cached neighbour — are *warm-started*: the engine resumes from the
cached schedule's state at the first cycle where the two problems can
diverge (``_schedule_warm``), which is bit-identical to a cold run by
construction and verified by the layout's own coverage check.

Deviations from the paper's pseudocode are deliberate and documented in
DESIGN.md §2 (the pseudocode has typos; our resolution reproduces every
worked number in the paper).
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import json
import multiprocessing
import os
import pathlib
import warnings
from collections import OrderedDict
from typing import Sequence

import numpy as np

from .layout import Counts, Layout
from .task import LayoutProblem


@dataclasses.dataclass
class _Task:
    idx: int          # index into problem.arrays
    width: int
    release: int
    delta: int        # max bits/cycle (already max_lanes-clamped)
    rem: int          # remaining elements

    @property
    def delta_eff(self) -> int:
        """Usable width right now: never claim lanes beyond remaining work."""
        return min(self.delta, self.rem * self.width)

    @property
    def lanes_eff(self) -> int:
        return self.delta_eff // self.width

    @property
    def height(self) -> int:
        """h(j) = ceil(rem / lanes) — remaining cycles at max parallelism."""
        return -(-self.rem // self.lanes_eff)


def _lrm_allocation(group: list[_Task], avail: int) -> dict[int, int]:
    """Largest-remainder (Hamilton) apportionment in element-width seats.

    Paper Alg 1.3, with the §4 modification: allocations are whole
    multiples of each element's bitwidth (elements are indivisible).
    Returns {task_idx: beta_bits}; beta is a multiple of W and <= delta_eff.
    """
    total = sum(t.delta_eff for t in group)
    assert total > avail > 0
    beta: dict[int, int] = {}
    rem_frac: list[tuple[float, int, _Task]] = []
    for order, t in enumerate(group):
        v = t.delta_eff * avail / total          # fair fractional share
        b = min((int(v) // t.width) * t.width, t.delta_eff)
        beta[t.idx] = b
        rem_frac.append((v - b, order, t))
    spent = sum(beta.values())
    left = avail - spent
    # hand out remaining seats (one element = W_j bits) by largest remainder
    rem_frac.sort(key=lambda x: (-x[0], x[1]))
    progressed = True
    while left > 0 and progressed:
        progressed = False
        for _, _, t in rem_frac:
            if left >= t.width and beta[t.idx] + t.width <= t.delta_eff:
                beta[t.idx] += t.width
                left -= t.width
                progressed = True
                if left == 0:
                    break
    return beta


def _find_capabilities(ready: list[_Task], m: int,
                       fill_residual: bool) -> list[tuple[_Task, int]]:
    """Paper Alg 1.2: allocate bus bits to the highest tasks first.

    Returns [(task, beta_bits)] in allocation (lane) order, beta > 0.
    ``fill_residual=False`` is the paper-faithful behaviour (avail := 0
    after an LRM round, line 27); ``True`` keeps offering leftover bits to
    lower groups — a beyond-paper refinement measured in EXPERIMENTS.md
    §fill_residual.
    """
    avail = m
    out: list[tuple[_Task, int]] = []
    # group by equal height, tallest first; stable within a group
    # (delta_eff is precomputed per task — this is the hot loop)
    by_height: dict[int, list[tuple[_Task, int]]] = {}
    for t in ready:
        de = t.delta
        rw = t.rem * t.width
        if rw < de:
            de = rw
        h = -(-t.rem // (de // t.width))
        by_height.setdefault(h, []).append((t, de))
    for h in sorted(by_height, reverse=True):
        if avail <= 0:
            break
        group = by_height[h]
        total = sum(de for _, de in group)
        if total <= avail:
            for t, de in group:
                out.append((t, de))
            avail -= total
        else:
            beta = _lrm_allocation([t for t, _ in group], avail)
            spent = 0
            for t, _ in group:
                b = beta.get(t.idx, 0)
                if b > 0:
                    out.append((t, b))
                    spent += b
            avail -= spent
            if not fill_residual:
                break          # paper line 27: avail := 0
    return out


# ----------------------------------------------------------------------
# exact event horizon
# ----------------------------------------------------------------------
# FIND_CAPABILITIES is a pure function of, per ready task, the pair
# (height, delta_eff) — heights only through the ordered partition of
# tasks into equal-height groups — plus the stable ready order, which the
# engine never perturbs between events.  A jump of tau cycles replays the
# same allocation bit-exactly iff all of these are invariant for
# k = 0..tau-1.  ``_exact_tau`` returns the largest tau it can *prove*
# safe; any conservatism costs events, never correctness.

_PAIR_EVENT_CAP = 64      # height-drop events examined per task pair
_FAR = 1 << 62


def _next_drop(rem: int, n: int, le: int, h_cur: int, after: int) -> int:
    """Smallest k > after with h(k) < h_cur.

    Heights drop by at most one per cycle (n <= le), so h at that k is
    exactly h_cur - 1.
    """
    return max(after + 1, -(-(rem - le * (h_cur - 1)) // n))


def _pair_bound(ra: int, la: int, ha: int, na: int,
                rb: int, lb: int, hb: int, nb: int, cap: int) -> int:
    """Largest tau <= cap keeping the height relation of the pair fixed.

    Arguments are (rem, lanes_eff, height, alloc_lanes) per task.  The
    relation (>, =, <) of the two integral heights determines whether
    the pair shares a FIND_CAPABILITIES group and in which order the
    groups rank; any change is a height-equalization (or separation)
    event that ends the jump.  Never exceeds the true first-change time;
    the event walk is capped, falling back to the last verified event.
    """
    if na == la and nb == lb:
        # both full-rate: h(k) = h(0) - k exactly for each, so the
        # difference — and the relation — is constant for any k
        return cap
    if nb == 0:
        if ha < hb:
            return cap                   # gap below a static task only grows
        if ha == hb:
            return min(cap, _next_drop(ra, na, la, ha, 0))
        # ha > hb: first k with h_a(k) <= hb
        return min(cap, -(-(ra - la * hb) // na))
    if na == 0:
        if hb < ha:
            return cap
        if hb == ha:
            return min(cap, _next_drop(rb, nb, lb, hb, 0))
        return min(cap, -(-(rb - lb * ha) // nb))
    # both moving at different normalized rates: walk the merged
    # height-drop events (the only cycles where the relation can change);
    # the drop/height arithmetic is inlined — this loop is the engine's
    # hottest path on LRM-contended problems
    rel0 = (ha > hb) - (ha < hb)
    k = 0
    for _ in range(_PAIR_EVENT_CAP):
        ka = -(-(ra - la * (ha - 1)) // na)
        kb = -(-(rb - lb * (hb - 1)) // nb)
        nxt = ka if ka < kb else kb
        k = nxt if nxt > k else k + 1
        if k >= cap:
            return cap
        ha = -(-(ra - k * na) // la)
        hb = -(-(rb - k * nb) // lb)
        rel = (ha > hb) - (ha < hb)
        if rel != rel0:
            return k                     # invariant on [0, k)
    return min(cap, k + 1)               # verified through event k


def _exact_tau(ready: list[_Task], alloc: list[tuple[_Task, int]],
               next_release: int | None, t_now: int) -> int:
    """Event horizon: largest jump with a provably constant allocation.

    Bounds, in order:

    * next release (heap head) — the ready set grows there;
    * element-indivisibility / completion: a task whose remaining
      elements have fallen below its lane count has ``delta_eff = rem*W``
      shrinking every cycle, so the engine steps it per-cycle (this tail
      correction is what makes interval mode bit-identical to cycle
      mode); in the bulk regime ``delta_eff`` is constant until rem
      crosses the lane count;
    * height-equalization: pairwise first time any two ready tasks'
      integral heights merge, split or cross (``_pair_bound``).

    """
    lanes = {task.idx: beta // task.width for task, beta in alloc}
    cap = _FAR if next_release is None else next_release - t_now
    for task, beta in alloc:
        dl = task.delta // task.width
        if task.rem < dl:
            return 1                     # indivisibility tail: exact replay
        cap = min(cap, (task.rem - dl) // lanes[task.idx] + 1)
        if cap <= 1:
            return 1
    # (rem, lanes_eff, height, alloc_lanes) per ready task, computed once
    state = []
    for t in ready:
        le = t.lanes_eff
        state.append((t.rem, le, -(-t.rem // le), lanes.get(t.idx, 0)))
    for i, (ra, la, ha, na) in enumerate(state):
        for (rb, lb, hb, nb) in state[i + 1:]:
            if na == 0 and nb == 0:
                continue                 # both static: nothing moves
            cap = _pair_bound(ra, la, ha, na, rb, lb, hb, nb, cap)
            if cap <= 1:
                return 1
    return cap


# ----------------------------------------------------------------------
# periodic steady-state fast-forward
# ----------------------------------------------------------------------
# While every ready task is in the bulk regime, the per-cycle allocation
# is a pure function of a *relative* fingerprint: ready order, height
# differences, and each task's phase within its current height level
# (rem - lanes*(height-1)).  When the fingerprint recurs with no release
# in between, the cycle-by-cycle count sequence between the two
# occurrences repeats verbatim — the LRM tie-group "wobble" is periodic.
# The engine then replays whole periods at O(runs) emission cost with no
# allocation or event-horizon work, which is what keeps LRM-contended
# million-cycle problems tractable *without* giving up bit-exactness.
# (Because runs are merged to maximal length, replay fidelity only needs
# the per-cycle counts to repeat — how the original events happened to
# split the period into jumps is irrelevant.)
#
# Safety guards: every moving task must stay in the bulk regime across
# the replay (rem - n_rep*work >= dl — in the tail, delta_eff starts
# shrinking and the fingerprint argument breaks), and the replay must
# stop at the next release (the ready set changes there).

_FP_MAP_LIMIT = 4096


def _bulk_fingerprint(ready: list[_Task]) -> tuple | None:
    """Relative state fingerprint, or None if any task is in its tail."""
    ids = []
    rel_h = []
    phases = []
    h_min = _FAR
    for t in ready:
        dl = t.delta // t.width
        if t.rem < dl:
            return None
        h = -(-t.rem // dl)
        ids.append(t.idx)
        rel_h.append(h)
        phases.append(t.rem - dl * (h - 1))
        if h < h_min:
            h_min = h
    return (tuple(ids), tuple(h - h_min for h in rel_h), tuple(phases))


def _append_run(forward: list[tuple[int, Counts]], tau: int,
                counts: Counts) -> None:
    if forward and forward[-1][1] == counts:
        forward[-1] = (forward[-1][0] + tau, counts)
    else:
        forward.append((tau, counts))


def _fast_forward(ready: list[_Task], forward: list[tuple[int, Counts]],
                  t_now: int, next_release: int | None,
                  entry: tuple) -> int:
    """Replay the detected period as many times as provably safe.

    ``entry`` is (t_prev, {idx: rem}, n_runs, last_tau) recorded when the
    same fingerprint was last seen (with no release in between).  Returns
    the cycles advanced (0 if no safe replay exists); mutates ``forward``
    and the tasks' ``rem``.
    """
    t_prev, rem_prev, n_runs, last_tau = entry
    t_period = t_now - t_prev
    if t_period <= 0:
        return 0
    work = {t.idx: rem_prev[t.idx] - t.rem for t in ready}
    n_rep = _FAR
    if next_release is not None:
        n_rep = (next_release - t_now) // t_period
    for t in ready:
        w = work[t.idx]
        if w <= 0:
            continue
        dl = t.delta // t.width
        n_safe = (t.rem - dl) // w
        if n_safe < n_rep:
            n_rep = n_safe
    if n_rep >= _FAR or n_rep < 1:
        return 0
    period: list[tuple[int, Counts]] = []
    if n_runs > 0 and forward[n_runs - 1][0] > last_tau:
        # the period's first run merged into the run open at record time
        period.append((forward[n_runs - 1][0] - last_tau,
                       forward[n_runs - 1][1]))
    period.extend(forward[n_runs:])
    assert sum(tau for tau, _ in period) == t_period
    for _ in range(n_rep):
        for tau, counts in period:
            _append_run(forward, tau, counts)
    for t in ready:
        t.rem -= n_rep * work[t.idx]
    return n_rep * t_period


# ----------------------------------------------------------------------
# the unified engine
# ----------------------------------------------------------------------
def _run_engine(tasks: list[_Task], m: int, fill_residual: bool,
                per_cycle: bool, *,
                heap: list[tuple[int, int]] | None = None,
                ready: list[_Task] | None = None,
                forward: list[tuple[int, Counts]] | None = None,
                t_now: int = 0) -> list[tuple[int, Counts]]:
    """Event loop shared by both modes; ``per_cycle`` pins tau to 1.

    Releases live in a heap; completions and height-equalizations are
    folded into the jump bound; recurring bulk-regime fingerprints
    trigger the periodic fast-forward.  Consecutive identical allocations
    merge, so both modes emit maximal runs — hence bit-identical layouts.

    The keyword-only state arguments let a warm start resume the loop
    mid-schedule: ``heap`` holds the not-yet-released tasks, ``ready``
    the released ones in (release, idx) order, ``forward`` the runs
    already emitted, and ``t_now`` the resume time.  Defaults reproduce
    a cold start from cycle 0.
    """
    if heap is None:
        heap = [(t.release, i) for i, t in enumerate(tasks)]
    heapq.heapify(heap)
    if forward is None:
        forward = []
    if ready is None:
        ready = []
    # fingerprint -> (t_at, {idx: rem}, n_runs, last_tau); cleared on
    # every release so a period never spans one
    fp_map: dict[tuple, tuple] = {}
    while heap or ready:
        released = False
        while heap and heap[0][0] <= t_now:
            _, i = heapq.heappop(heap)
            ready.append(tasks[i])
            released = True
        if released:
            fp_map.clear()
        ready = [t for t in ready if t.rem > 0]
        if not ready:
            if not heap:
                break
            # idle until the next release; idle cycles are *not* emitted —
            # dropping them in due-date space only reduces lateness
            t_now = heap[0][0]
            continue
        next_release = heap[0][0] if heap else None
        if not per_cycle:
            fp = _bulk_fingerprint(ready)
            if fp is not None:
                ent = fp_map.get(fp)
                if ent is not None:
                    advanced = _fast_forward(ready, forward, t_now,
                                             next_release, ent)
                    if advanced:
                        t_now += advanced
                        fp_map.clear()
                        continue
                if len(fp_map) >= _FP_MAP_LIMIT:
                    fp_map.clear()
                fp_map[fp] = (t_now, {t.idx: t.rem for t in ready},
                              len(forward),
                              forward[-1][0] if forward else 0)
        alloc = _find_capabilities(ready, m, fill_residual)
        assert alloc, "FIND_CAPABILITIES must allocate at least one task"
        tau = 1 if per_cycle else _exact_tau(ready, alloc, next_release,
                                             t_now)
        counts: Counts = tuple(
            (task.idx, beta // task.width) for task, beta in alloc
        )
        _append_run(forward, tau, counts)
        for task, beta in alloc:
            task.rem -= tau * (beta // task.width)
            assert task.rem >= 0
        t_now += tau
    return forward


# ----------------------------------------------------------------------
# incremental re-planning (warm start from a cached near-miss neighbour)
# ----------------------------------------------------------------------
# The engine's state at any release time R is fully determined by the
# per-task remaining elements, the ready order (ascending (release,
# idx)), and t_now = R — the fingerprint map is cleared on every release
# and only accelerates, never alters, the emitted counts.  A cached
# layout therefore lets us *jump* to R: replay its forward trace
# vectorized (one matmul over the run/count matrix) to recover the
# remaining-element vector, copy the prefix runs verbatim, and resume
# the event loop.  This is bit-identical to a cold run provided
#
# * the two problems share m, fill_residual and d_max, and agree on
#   every array except one (substitution, insertion or deletion) — then
#   every common task has the same release and the same tie order, so
#   the cold engine's decisions on [0, R) match the neighbour's, where
#   R is the earliest release at which the problems can diverge;
# * no idle gap was compressed out of the prefix — the cached trace
#   omits idle cycles, so a gap makes trace time lag engine time.  A gap
#   always surfaces as a prefix run scheduling a task before its
#   release (post-gap runs start at a release), which we detect and
#   reject, falling back to a cold run.
#
# Layout construction re-validates full coverage afterwards, so a warm
# start can never silently produce a wrong layout — at worst it falls
# back to the cold path.

def _align_signatures(old: tuple, new: tuple
                      ) -> tuple[str, int] | None:
    """Align two canonical array tuples differing in at most one slot.

    Returns ``(kind, pos)`` with kind in {'sub', 'ins', 'del'} and pos
    the differing index (in the new tuple for 'ins', the old tuple for
    'del'), or None if the tuples are not near-miss neighbours.
    """
    if len(old) == len(new):
        diffs = [i for i, (a, b) in enumerate(zip(old, new)) if a != b]
        if len(diffs) == 1:
            return ("sub", diffs[0])
        return None
    if len(new) == len(old) + 1:
        i = 0
        while i < len(old) and old[i] == new[i]:
            i += 1
        if tuple(old[i:]) == tuple(new[i + 1:]):
            return ("ins", i)
        return None
    if len(new) == len(old) - 1:
        i = 0
        while i < len(new) and old[i] == new[i]:
            i += 1
        if tuple(old[i + 1:]) == tuple(new[i:]):
            return ("del", i)
        return None
    return None


def _replay_tables(layout: Layout) -> tuple:
    """Vectorized replay view of a layout's forward trace (memoized).

    Returns (fwd_runs, tau, cmat, start, rel) where ``cmat[r, j]`` is
    array j's per-cycle element count in forward run r, ``start[r]`` the
    run's first cycle in trace time, and ``rel[j]`` the task release.
    Shared across rebinds via ``Layout._replay_cache``.
    """
    cached = layout._replay_cache.get("replay")
    if cached is None:
        fwd = tuple(reversed(layout.count_intervals))
        n = len(layout.problem.arrays)
        tau = np.fromiter((t for t, _ in fwd), dtype=np.int64,
                          count=len(fwd))
        cmat = np.zeros((len(fwd), n), dtype=np.int64)
        for r, (_tau, counts) in enumerate(fwd):
            for a, e in counts:
                cmat[r, a] += e
        start = np.zeros(len(fwd) + 1, dtype=np.int64)
        np.cumsum(tau, out=start[1:])
        d_max = layout.problem.d_max
        rel = np.fromiter((d_max - a.due for a in layout.problem.arrays),
                          dtype=np.int64, count=n)
        cached = (fwd, tau, cmat, start, rel)
        layout._replay_cache["replay"] = cached
    return cached


def _schedule_warm(prob: LayoutProblem, tasks: list[_Task],
                   per_cycle: bool, fill_residual: bool,
                   neighbor: tuple
                   ) -> tuple[list[tuple[int, Counts]], tuple] | None:
    """Resume the engine from a cached neighbour's state at cycle R.

    ``neighbor`` is (layout, kind, pos, R) from
    :meth:`LayoutCache.find_neighbor`.  Returns ``(forward, replay)`` —
    the complete forward trace for ``prob`` plus ready-made replay
    tables for the *new* layout (derived from the neighbour's by a
    column edit, so chained warm starts never rescan the prefix in
    Python) — or None when the prefix is unusable (idle gap,
    inconsistent remaining work) and the caller must run cold.  Mutates
    ``tasks`` (remaining elements); callers must rebuild them on None.
    """
    lay_old, kind, pos, r_split = neighbor
    fwd, tau, cmat, start, rel_old = _replay_tables(lay_old)
    n_old = cmat.shape[1]
    total = int(start[-1])
    if r_split >= total:
        idx, tau1 = len(fwd), 0
    else:
        idx = int(np.searchsorted(start, r_split, side="right")) - 1
        tau1 = r_split - int(start[idx])
    win = idx + (1 if tau1 > 0 else 0)
    if win > 0:
        # a prefix run scheduling a task before its release ⇒ an idle
        # gap was compressed out of the trace: bail to the cold path
        active = cmat[:win] > 0
        if bool(np.any(active & (rel_old[None, :] > start[:win, None]))):
            return None
    if kind == "del" and win > 0 and bool(np.any(cmat[:win, pos] > 0)):
        return None          # deleted array must not appear in the prefix
    consumed = tau[:idx] @ cmat[:idx]
    if tau1 > 0:
        consumed = consumed + tau1 * cmat[idx]
    if kind == "sub":
        remap = list(range(n_old))
    elif kind == "ins":
        remap = [j if j < pos else j + 1 for j in range(n_old)]
    else:
        remap = [j if j < pos else j - 1 for j in range(n_old)]
        remap[pos] = -1
    for j_old in range(n_old):
        j_new = remap[j_old]
        c = int(consumed[j_old])
        if j_new < 0:
            if c:
                return None
            continue
        tasks[j_new].rem -= c
        if tasks[j_new].rem < 0:
            return None
    if kind == "sub":
        # identity remap: share the neighbour's run tuples verbatim
        forward: list[tuple[int, Counts]] = list(fwd[:idx])
        if tau1 > 0:
            _append_run(forward, tau1, fwd[idx][1])
    else:
        forward = [(int(tau[r]),
                    tuple((remap[a], e) for a, e in fwd[r][1]))
                   for r in range(idx)]
        if tau1 > 0:
            _append_run(forward, tau1,
                        tuple((remap[a], e) for a, e in fwd[idx][1]))
    order = sorted(range(len(tasks)),
                   key=lambda i: (tasks[i].release, i))
    ready = [tasks[i] for i in order if tasks[i].release < r_split]
    heap = [(tasks[i].release, i) for i in order
            if tasks[i].release >= r_split]
    _run_engine(tasks, prob.m, fill_residual, per_cycle,
                heap=heap, ready=ready, forward=forward, t_now=r_split)
    # replay tables for the new layout: prefix rows come from the
    # neighbour's count matrix via a column edit (a seam merge only
    # alters a run's tau, never its counts, so row r < idx still
    # describes forward[r]); only the continuation tail is scanned
    n_new = len(tasks)
    if kind == "sub":
        pre = cmat[:idx]
    elif kind == "ins":
        pre = np.insert(cmat[:idx], pos, 0, axis=1)
    else:
        pre = np.delete(cmat[:idx], pos, axis=1)
    tail = np.zeros((len(forward) - idx, n_new), dtype=np.int64)
    for r in range(idx, len(forward)):
        for a, e in forward[r][1]:
            tail[r - idx, a] += e
    cmat_new = np.vstack([pre, tail])
    tau_new = np.fromiter((t for t, _ in forward), dtype=np.int64,
                          count=len(forward))
    start_new = np.zeros(len(forward) + 1, dtype=np.int64)
    np.cumsum(tau_new, out=start_new[1:])
    rel_new = np.fromiter((t.release for t in tasks), dtype=np.int64,
                          count=n_new)
    replay = (tuple(forward), tau_new, cmat_new, start_new, rel_new)
    return forward, replay


def schedule(problem: LayoutProblem, *, mode: str = "auto",
             fill_residual: bool = False,
             cache: "LayoutCache | None" = None,
             warm_start: bool = True,
             _cycle_limit: int = 1 << 16) -> Layout:
    """Run Iris on ``problem`` and return the due-date-space :class:`Layout`.

    mode: 'cycle' (per-cycle replay, O(C_max)), 'interval' (event-driven,
    O(events)), or 'auto' (cycle below ``_cycle_limit`` estimated cycles).
    Both modes produce bit-identical layouts; they differ only in cost.

    ``cache``: an optional :class:`LayoutCache`; on a hit the scheduler
    does not run at all.  On a miss with ``warm_start=True`` (the
    default), a cached near-miss neighbour — same bus and d_max, one
    array substituted, added or removed — seeds the engine mid-schedule
    (:func:`_schedule_warm`); the result is bit-identical to a cold run,
    and any unusable prefix silently falls back to one.
    """
    if mode not in ("auto", "cycle", "interval"):
        raise ValueError(f"unknown mode {mode!r}")
    if cache is not None:
        hit = cache.lookup(problem, fill_residual)
        if hit is not None:
            return hit
    prob = problem
    d_max = prob.d_max

    def _build_tasks() -> list[_Task]:
        return [
            _Task(
                idx=i,
                width=a.width,
                release=d_max - a.due,
                delta=a.delta(prob.m),
                rem=a.depth,
            )
            for i, a in enumerate(prob.arrays)
        ]

    tasks = _build_tasks()
    if mode == "auto":
        est = sum(t.rem * t.width for t in tasks) / prob.m + d_max
        mode = "cycle" if est <= _cycle_limit else "interval"
    per_cycle = mode == "cycle"

    lay: Layout | None = None
    if warm_start and cache is not None:
        neighbor = cache.find_neighbor(problem, fill_residual)
        if neighbor is not None:
            try:
                res = _schedule_warm(prob, tasks, per_cycle,
                                     fill_residual, neighbor)
                if res is not None:
                    forward, replay = res
                    lay = Layout.from_count_intervals(
                        prob, forward, reverse=True, _normalized=True)
                    lay._replay_cache["replay"] = replay
            except (ValueError, AssertionError):
                lay = None
            if lay is None:
                tasks = _build_tasks()     # warm path mutated the rems
            else:
                cache.warm_starts += 1
    if lay is None:
        forward = _run_engine(tasks, prob.m, fill_residual,
                              per_cycle=per_cycle)
        lay = Layout.from_count_intervals(prob, forward, reverse=True,
                                          _normalized=True)
    if cache is not None:
        cache.insert(problem, fill_residual, lay)
    return lay


# ----------------------------------------------------------------------
# layout cache + batch API
# ----------------------------------------------------------------------
_DISK_CACHE_VERSION = 1


class LayoutCache:
    """Content-addressed LRU cache of solved layout problems.

    Keyed on ``LayoutProblem.canonical_signature()`` (name-independent)
    plus the ``fill_residual`` flag.  Mode is deliberately *not* part of
    the key: the unified engine emits bit-identical layouts in both
    modes, so a layout solved in either mode answers both.  A hit whose
    cached problem differs only in array names is rebound via
    :meth:`Layout.rebind` — O(intervals), no scheduling.

    ``cache_dir`` enables a persistent on-disk tier: inserts write
    through to content-addressed JSON entries (atomic rename), and an
    in-memory miss consults the disk before scheduling.  Loaded entries
    are trusted only after re-verification — payload digest, signature
    match, the Layout constructor's own full-coverage check, and the
    layout-only analysis passes (mirroring the gate
    ``checkpoint.restore_packed`` runs before rebinding streams).  A
    tampered or truncated entry is unlinked and counted in
    ``disk_rejects``; the lookup then proceeds as an ordinary miss.
    """

    def __init__(self, maxsize: int = 256,
                 cache_dir: "str | os.PathLike | None" = None) -> None:
        if maxsize <= 0:
            raise ValueError(f"maxsize must be positive, got {maxsize}")
        self.maxsize = maxsize
        self._store: OrderedDict[tuple, Layout] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.warm_starts = 0
        self.disk_hits = 0
        self.disk_rejects = 0
        self.cache_dir = pathlib.Path(cache_dir) if cache_dir else None
        if self.cache_dir is not None:
            self.cache_dir.mkdir(parents=True, exist_ok=True)

    def __len__(self) -> int:
        return len(self._store)

    @staticmethod
    def _key(problem: LayoutProblem, fill_residual: bool) -> tuple:
        return (problem.canonical_signature(), bool(fill_residual))

    # -- persistent tier ------------------------------------------------
    @staticmethod
    def _entry_name(key: tuple) -> str:
        blob = json.dumps(key, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:40] + ".json"

    @staticmethod
    def _payload_digest(payload: dict) -> str:
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()

    def _disk_store(self, fill_residual: bool, layout: Layout,
                    key: tuple) -> None:
        payload = {
            "problem": json.loads(layout.problem.to_json()),
            "fill_residual": bool(fill_residual),
            "intervals": [[int(n), [[int(a), int(e)] for a, e in counts]]
                          for n, counts in layout.count_intervals],
        }
        obj = {"version": _DISK_CACHE_VERSION,
               "sha256": self._payload_digest(payload),
               "payload": payload}
        path = self.cache_dir / self._entry_name(key)
        tmp = path.with_suffix(".tmp")
        try:
            tmp.write_text(json.dumps(obj))
            os.replace(tmp, path)
        except OSError as e:  # disk full / permissions: cache stays warm-only
            warnings.warn(f"layout cache: cannot persist {path.name}: {e}",
                          RuntimeWarning, stacklevel=3)

    def _disk_load(self, problem: LayoutProblem, key: tuple) -> Layout | None:
        path = self.cache_dir / self._entry_name(key)
        if not path.exists():
            return None
        try:
            obj = json.loads(path.read_text())
            if obj.get("version") != _DISK_CACHE_VERSION:
                raise ValueError(f"version {obj.get('version')!r}")
            payload = obj["payload"]
            if self._payload_digest(payload) != obj.get("sha256"):
                raise ValueError("payload digest mismatch")
            stored = LayoutProblem.from_json(json.dumps(payload["problem"]))
            if stored.canonical_signature() != problem.canonical_signature():
                raise ValueError("canonical signature mismatch")
            raw = payload["intervals"]
            # enforce the canonical-form contract here so the trusted
            # constructor path is sound on disk data: a malformed run
            # (non-positive or non-integer cycle counts / element
            # counts) is a rejection, not something normalization
            # silently repairs.  Vectorized: dtype kind 'i' proves every
            # value is a plain integer, ragged rows fail np.array.
            taus = np.array([n for n, _c in raw] or [1])
            pairs = [p for _n, counts in raw for p in counts]
            pair_np = (np.array(pairs) if pairs
                       else np.empty((0, 2), dtype=np.int64))
            if (taus.dtype.kind != "i" or bool((taus <= 0).any())
                    or pair_np.dtype.kind != "i" or pair_np.ndim != 2
                    or pair_np.shape[1] != 2
                    or bool((pair_np[:, 1] <= 0).any())):
                raise ValueError("non-canonical count run")
            runs = tuple((n, tuple(map(tuple, counts))) for n, counts in raw)
            # the constructor bounds- and coverage-checks; the analysis
            # gate below re-proves legality independently (validate()
            # would be a third, redundant derivation of the same facts)
            lay = Layout.from_count_intervals(stored, runs,
                                              _normalized=True)
            from ..analysis import verify_layout_fast
            verify_layout_fast(lay, subject=path.name).raise_if_errors()
        except Exception as e:
            self.disk_rejects += 1
            warnings.warn(
                f"layout cache: rejecting persisted entry {path.name}: {e}",
                RuntimeWarning, stacklevel=3)
            try:
                path.unlink()
            except OSError:
                pass
            return None
        return lay

    # -- in-memory tier -------------------------------------------------
    def lookup(self, problem: LayoutProblem,
               fill_residual: bool = False) -> Layout | None:
        key = self._key(problem, fill_residual)
        lay = self._store.get(key)
        if lay is None and self.cache_dir is not None:
            lay = self._disk_load(problem, key)
            if lay is not None:
                self.disk_hits += 1
                self._store[key] = lay
                while len(self._store) > self.maxsize:
                    self._store.popitem(last=False)
        if lay is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return lay.rebind(problem)

    def insert(self, problem: LayoutProblem, fill_residual: bool,
               layout: Layout) -> None:
        key = self._key(problem, fill_residual)
        self._store[key] = layout
        self._store.move_to_end(key)
        while len(self._store) > self.maxsize:
            self._store.popitem(last=False)
        if self.cache_dir is not None:
            self._disk_store(fill_residual, layout, key)

    def find_neighbor(self, problem: LayoutProblem,
                      fill_residual: bool = False) -> tuple | None:
        """Most-recently-used near-miss neighbour of ``problem``.

        A neighbour shares the bus width, fill_residual and d_max, and
        differs in exactly one array (substituted, inserted or removed).
        Returns ``(layout, kind, pos, R)`` where R is the first cycle at
        which the two schedules can diverge, or None.  Problems with a
        different bus width share no engine state (every task's
        parallelism changes), so they are never neighbours.
        """
        new_sig = problem.canonical_signature()
        m, new_arr = new_sig
        if not new_arr:
            return None
        d_max = max(a[2] for a in new_arr)
        for (sig, fr), lay in reversed(self._store.items()):
            if fr != bool(fill_residual) or sig[0] != m or sig == new_sig:
                continue
            old_arr = sig[1]
            if not old_arr or max(a[2] for a in old_arr) != d_max:
                continue
            align = _align_signatures(old_arr, new_arr)
            if align is None:
                continue
            kind, pos = align
            if kind == "sub":
                r_split = d_max - max(old_arr[pos][2], new_arr[pos][2])
            elif kind == "ins":
                r_split = d_max - new_arr[pos][2]
            else:
                r_split = d_max - old_arr[pos][2]
            if r_split <= 0:
                continue
            return (lay, kind, pos, r_split)
        return None

    def clear(self) -> None:
        self._store.clear()
        self.hits = 0
        self.misses = 0
        self.warm_starts = 0
        self.disk_hits = 0
        self.disk_rejects = 0

    @property
    def stats(self) -> dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "size": len(self._store),
            "maxsize": self.maxsize,
            "warm_starts": self.warm_starts,
            "disk_hits": self.disk_hits,
            "disk_rejects": self.disk_rejects,
        }


def _env_default_cache() -> LayoutCache:
    """Build the process-wide cache from the environment.

    ``REPRO_CACHE_SIZE`` sizes the in-memory LRU (default 512);
    ``REPRO_CACHE_DIR``, when set, enables the persistent on-disk tier
    under that directory.  Malformed values fall back to the defaults.
    """
    raw = os.environ.get("REPRO_CACHE_SIZE", "")
    try:
        size = int(raw) if raw else 512
    except ValueError:
        size = 512
    if size <= 0:
        size = 512
    return LayoutCache(maxsize=size,
                       cache_dir=os.environ.get("REPRO_CACHE_DIR") or None)


#: Process-wide cache used by the DSE sweeps, model packing and serving.
DEFAULT_CACHE = _env_default_cache()


# ----------------------------------------------------------------------
# batch API: dedupe + process-pool fan-out
# ----------------------------------------------------------------------
def _schedule_worker(payload: tuple) -> list[tuple]:
    """Pool worker: JSON problems in, due-date-space run traces out.

    Problems within a chunk share a local cache, so contiguous near-miss
    neighbours warm-start each other inside the worker exactly as they
    would serially.  Only plain tuples cross the process boundary.
    """
    texts, mode, fill_residual = payload
    local = LayoutCache(maxsize=max(1, len(texts)))
    out = []
    for text in texts:
        prob = LayoutProblem.from_json(text)
        lay = schedule(prob, mode=mode, fill_residual=fill_residual,
                       cache=local)
        out.append(lay.count_intervals)
    return out


def _effective_workers(workers: int | None, n_unique: int) -> int:
    cores = os.cpu_count() or 1
    if workers is None:
        workers = cores
    return max(1, min(workers, cores, n_unique))


def _pool_schedule(probs: list[LayoutProblem], mode: str,
                   fill_residual: bool, workers: int
                   ) -> list[tuple[LayoutProblem, tuple]] | None:
    """Schedule ``probs`` over a process pool; None if no pool works.

    Chunks are contiguous so each worker's local cache can warm-start
    chain neighbouring problems, and results merge in input order —
    the outcome is deterministic regardless of completion order.
    """
    per = -(-len(probs) // workers)
    chunks = [probs[i:i + per] for i in range(0, len(probs), per)]
    payloads = [([p.to_json() for p in ch], mode, fill_residual)
                for ch in chunks]
    methods = multiprocessing.get_all_start_methods()
    method = "fork" if "fork" in methods else "spawn"
    try:
        ctx = multiprocessing.get_context(method)
        with ctx.Pool(processes=min(workers, len(chunks))) as pool:
            results = pool.map(_schedule_worker, payloads)
    except Exception as e:  # sandboxed / fork-less hosts: run serially
        warnings.warn(f"schedule_many: process pool unavailable ({e}); "
                      "falling back to serial scheduling",
                      RuntimeWarning, stacklevel=3)
        return None
    out: list[tuple[LayoutProblem, tuple]] = []
    for ch, runs_list in zip(chunks, results):
        out.extend(zip(ch, runs_list))
    return out


def schedule_many(problems: Sequence[LayoutProblem], *, mode: str = "auto",
                  fill_residual: bool = False,
                  cache: LayoutCache | None = DEFAULT_CACHE,
                  workers: int | None = None) -> list[Layout]:
    """Batch API: one scheduler run per *unique* scheduling instance.

    Problems sharing a canonical signature (e.g. every layer of a uniform
    decoder) are scheduled once and rebound; results are returned in
    input order.  ``cache=None`` still dedupes within the batch via an
    ephemeral cache.

    Unique uncached instances fan out over a process pool of
    ``workers`` processes (default: the machine's core count, always
    clamped to it).  Pool results merge into the cache in input order,
    so the cache state — like the returned layouts — is deterministic
    and identical to a serial run's.  With one effective worker, or
    when no pool can be spawned, scheduling is serial; near-miss
    batches still chain warm starts through the shared cache either
    way, and the counters in ``cache.stats`` advance identically in
    every path (one miss per unique instance, one hit per duplicate).
    """
    problems = list(problems)
    local = cache if cache is not None \
        else LayoutCache(maxsize=max(1, len(problems)))
    fresh: "OrderedDict[tuple, LayoutProblem]" = OrderedDict()
    for p in problems:
        key = LayoutCache._key(p, fill_residual)
        if key not in local._store and key not in fresh:
            fresh[key] = p
    eff = _effective_workers(workers, len(fresh))
    pooled: dict[tuple, Layout] = {}
    if eff > 1:
        solved = _pool_schedule(list(fresh.values()), mode, fill_residual,
                                eff)
        if solved is not None:
            for p, runs in solved:
                lay = Layout.from_count_intervals(p, runs, _normalized=True)
                key = LayoutCache._key(p, fill_residual)
                local.insert(p, fill_residual, lay)
                local.misses += 1   # counter parity with the serial path
                pooled[key] = lay
    out: list[Layout] = []
    claimed: set[tuple] = set()
    for p in problems:
        key = LayoutCache._key(p, fill_residual)
        if key in pooled and key not in claimed:
            claimed.add(key)        # first occurrence: no lookup, like serial
            out.append(pooled[key].rebind(p))
        else:
            out.append(schedule(p, mode=mode, fill_residual=fill_residual,
                                cache=local))
    return out
