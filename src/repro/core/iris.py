"""Iris layout scheduler (paper Algorithms 1.1, 1.2, 1.3).

The bus-layout problem is solved as preemptive multiprocessor scheduling of
linear-speedup tasks (Drozdowski 1996): the m-bit bus is m identical
processors, array j is a task with processing time ``p_j = W_j * D_j``,
maximum parallelism ``delta_j = floor(m/W_j)*W_j``, and release time
``r_j = d_max - d_j``.  The schedule is computed forward in release-time
space and reversed into due-date space to optimize ``L_max``.

One event-driven engine serves both execution modes:

* ``interval`` — the paper's event-driven form (Alg 1.1 lines 8-13) made
  *exact*: a heap-ordered event queue advances time over releases,
  completions and height-equalizations, and every jump ``tau`` is bounded
  so that FIND_CAPABILITIES is provably constant across the whole run
  (``_exact_tau``).  O(events) instead of O(C_max); required for
  model-packing problems with millions of cycles.
* ``cycle``    — the same engine with ``tau`` pinned to 1: a trivially-
  verifiable per-cycle replay.  Used for paper-scale problems and as the
  ground truth in property tests.

Because the jump bounds account for element indivisibility exactly (the
``delta_eff`` tail correction in ``_exact_tau`` steps cycle-by-cycle once a
task's remaining elements drop below its lane count), both modes emit
**bit-identical** layouts — there are no "O(1)-cycle transient differences"
to tolerate, and mode is therefore not part of the layout-cache key.

Repeated identical problems are served by :class:`LayoutCache`, a
content-addressed LRU keyed on ``LayoutProblem.canonical_signature()``;
:func:`schedule_many` batches and dedupes whole problem lists through it.

Deviations from the paper's pseudocode are deliberate and documented in
DESIGN.md §2 (the pseudocode has typos; our resolution reproduces every
worked number in the paper).
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import OrderedDict
from typing import Sequence

from .layout import Counts, Layout
from .task import LayoutProblem


@dataclasses.dataclass
class _Task:
    idx: int          # index into problem.arrays
    width: int
    release: int
    delta: int        # max bits/cycle (already max_lanes-clamped)
    rem: int          # remaining elements

    @property
    def delta_eff(self) -> int:
        """Usable width right now: never claim lanes beyond remaining work."""
        return min(self.delta, self.rem * self.width)

    @property
    def lanes_eff(self) -> int:
        return self.delta_eff // self.width

    @property
    def height(self) -> int:
        """h(j) = ceil(rem / lanes) — remaining cycles at max parallelism."""
        return -(-self.rem // self.lanes_eff)


def _lrm_allocation(group: list[_Task], avail: int) -> dict[int, int]:
    """Largest-remainder (Hamilton) apportionment in element-width seats.

    Paper Alg 1.3, with the §4 modification: allocations are whole
    multiples of each element's bitwidth (elements are indivisible).
    Returns {task_idx: beta_bits}; beta is a multiple of W and <= delta_eff.
    """
    total = sum(t.delta_eff for t in group)
    assert total > avail > 0
    beta: dict[int, int] = {}
    rem_frac: list[tuple[float, int, _Task]] = []
    for order, t in enumerate(group):
        v = t.delta_eff * avail / total          # fair fractional share
        b = min((int(v) // t.width) * t.width, t.delta_eff)
        beta[t.idx] = b
        rem_frac.append((v - b, order, t))
    spent = sum(beta.values())
    left = avail - spent
    # hand out remaining seats (one element = W_j bits) by largest remainder
    rem_frac.sort(key=lambda x: (-x[0], x[1]))
    progressed = True
    while left > 0 and progressed:
        progressed = False
        for _, _, t in rem_frac:
            if left >= t.width and beta[t.idx] + t.width <= t.delta_eff:
                beta[t.idx] += t.width
                left -= t.width
                progressed = True
                if left == 0:
                    break
    return beta


def _find_capabilities(ready: list[_Task], m: int,
                       fill_residual: bool) -> list[tuple[_Task, int]]:
    """Paper Alg 1.2: allocate bus bits to the highest tasks first.

    Returns [(task, beta_bits)] in allocation (lane) order, beta > 0.
    ``fill_residual=False`` is the paper-faithful behaviour (avail := 0
    after an LRM round, line 27); ``True`` keeps offering leftover bits to
    lower groups — a beyond-paper refinement measured in EXPERIMENTS.md
    §fill_residual.
    """
    avail = m
    out: list[tuple[_Task, int]] = []
    # group by equal height, tallest first; stable within a group
    # (delta_eff is precomputed per task — this is the hot loop)
    by_height: dict[int, list[tuple[_Task, int]]] = {}
    for t in ready:
        de = t.delta
        rw = t.rem * t.width
        if rw < de:
            de = rw
        h = -(-t.rem // (de // t.width))
        by_height.setdefault(h, []).append((t, de))
    for h in sorted(by_height, reverse=True):
        if avail <= 0:
            break
        group = by_height[h]
        total = sum(de for _, de in group)
        if total <= avail:
            for t, de in group:
                out.append((t, de))
            avail -= total
        else:
            beta = _lrm_allocation([t for t, _ in group], avail)
            spent = 0
            for t, _ in group:
                b = beta.get(t.idx, 0)
                if b > 0:
                    out.append((t, b))
                    spent += b
            avail -= spent
            if not fill_residual:
                break          # paper line 27: avail := 0
    return out


# ----------------------------------------------------------------------
# exact event horizon
# ----------------------------------------------------------------------
# FIND_CAPABILITIES is a pure function of, per ready task, the pair
# (height, delta_eff) — heights only through the ordered partition of
# tasks into equal-height groups — plus the stable ready order, which the
# engine never perturbs between events.  A jump of tau cycles replays the
# same allocation bit-exactly iff all of these are invariant for
# k = 0..tau-1.  ``_exact_tau`` returns the largest tau it can *prove*
# safe; any conservatism costs events, never correctness.

_PAIR_EVENT_CAP = 64      # height-drop events examined per task pair
_FAR = 1 << 62


def _next_drop(rem: int, n: int, le: int, h_cur: int, after: int) -> int:
    """Smallest k > after with h(k) < h_cur.

    Heights drop by at most one per cycle (n <= le), so h at that k is
    exactly h_cur - 1.
    """
    return max(after + 1, -(-(rem - le * (h_cur - 1)) // n))


def _pair_bound(ra: int, la: int, ha: int, na: int,
                rb: int, lb: int, hb: int, nb: int, cap: int) -> int:
    """Largest tau <= cap keeping the height relation of the pair fixed.

    Arguments are (rem, lanes_eff, height, alloc_lanes) per task.  The
    relation (>, =, <) of the two integral heights determines whether
    the pair shares a FIND_CAPABILITIES group and in which order the
    groups rank; any change is a height-equalization (or separation)
    event that ends the jump.  Never exceeds the true first-change time;
    the event walk is capped, falling back to the last verified event.
    """
    if na == la and nb == lb:
        # both full-rate: h(k) = h(0) - k exactly for each, so the
        # difference — and the relation — is constant for any k
        return cap
    if nb == 0:
        if ha < hb:
            return cap                   # gap below a static task only grows
        if ha == hb:
            return min(cap, _next_drop(ra, na, la, ha, 0))
        # ha > hb: first k with h_a(k) <= hb
        return min(cap, -(-(ra - la * hb) // na))
    if na == 0:
        if hb < ha:
            return cap
        if hb == ha:
            return min(cap, _next_drop(rb, nb, lb, hb, 0))
        return min(cap, -(-(rb - lb * ha) // nb))
    # both moving at different normalized rates: walk the merged
    # height-drop events (the only cycles where the relation can change);
    # the drop/height arithmetic is inlined — this loop is the engine's
    # hottest path on LRM-contended problems
    rel0 = (ha > hb) - (ha < hb)
    k = 0
    for _ in range(_PAIR_EVENT_CAP):
        ka = -(-(ra - la * (ha - 1)) // na)
        kb = -(-(rb - lb * (hb - 1)) // nb)
        nxt = ka if ka < kb else kb
        k = nxt if nxt > k else k + 1
        if k >= cap:
            return cap
        ha = -(-(ra - k * na) // la)
        hb = -(-(rb - k * nb) // lb)
        rel = (ha > hb) - (ha < hb)
        if rel != rel0:
            return k                     # invariant on [0, k)
    return min(cap, k + 1)               # verified through event k


def _exact_tau(ready: list[_Task], alloc: list[tuple[_Task, int]],
               next_release: int | None, t_now: int) -> int:
    """Event horizon: largest jump with a provably constant allocation.

    Bounds, in order:

    * next release (heap head) — the ready set grows there;
    * element-indivisibility / completion: a task whose remaining
      elements have fallen below its lane count has ``delta_eff = rem*W``
      shrinking every cycle, so the engine steps it per-cycle (this tail
      correction is what makes interval mode bit-identical to cycle
      mode); in the bulk regime ``delta_eff`` is constant until rem
      crosses the lane count;
    * height-equalization: pairwise first time any two ready tasks'
      integral heights merge, split or cross (``_pair_bound``).

    """
    lanes = {task.idx: beta // task.width for task, beta in alloc}
    cap = _FAR if next_release is None else next_release - t_now
    for task, beta in alloc:
        dl = task.delta // task.width
        if task.rem < dl:
            return 1                     # indivisibility tail: exact replay
        cap = min(cap, (task.rem - dl) // lanes[task.idx] + 1)
        if cap <= 1:
            return 1
    # (rem, lanes_eff, height, alloc_lanes) per ready task, computed once
    state = []
    for t in ready:
        le = t.lanes_eff
        state.append((t.rem, le, -(-t.rem // le), lanes.get(t.idx, 0)))
    for i, (ra, la, ha, na) in enumerate(state):
        for (rb, lb, hb, nb) in state[i + 1:]:
            if na == 0 and nb == 0:
                continue                 # both static: nothing moves
            cap = _pair_bound(ra, la, ha, na, rb, lb, hb, nb, cap)
            if cap <= 1:
                return 1
    return cap


# ----------------------------------------------------------------------
# periodic steady-state fast-forward
# ----------------------------------------------------------------------
# While every ready task is in the bulk regime, the per-cycle allocation
# is a pure function of a *relative* fingerprint: ready order, height
# differences, and each task's phase within its current height level
# (rem - lanes*(height-1)).  When the fingerprint recurs with no release
# in between, the cycle-by-cycle count sequence between the two
# occurrences repeats verbatim — the LRM tie-group "wobble" is periodic.
# The engine then replays whole periods at O(runs) emission cost with no
# allocation or event-horizon work, which is what keeps LRM-contended
# million-cycle problems tractable *without* giving up bit-exactness.
# (Because runs are merged to maximal length, replay fidelity only needs
# the per-cycle counts to repeat — how the original events happened to
# split the period into jumps is irrelevant.)
#
# Safety guards: every moving task must stay in the bulk regime across
# the replay (rem - n_rep*work >= dl — in the tail, delta_eff starts
# shrinking and the fingerprint argument breaks), and the replay must
# stop at the next release (the ready set changes there).

_FP_MAP_LIMIT = 4096


def _bulk_fingerprint(ready: list[_Task]) -> tuple | None:
    """Relative state fingerprint, or None if any task is in its tail."""
    ids = []
    rel_h = []
    phases = []
    h_min = _FAR
    for t in ready:
        dl = t.delta // t.width
        if t.rem < dl:
            return None
        h = -(-t.rem // dl)
        ids.append(t.idx)
        rel_h.append(h)
        phases.append(t.rem - dl * (h - 1))
        if h < h_min:
            h_min = h
    return (tuple(ids), tuple(h - h_min for h in rel_h), tuple(phases))


def _append_run(forward: list[tuple[int, Counts]], tau: int,
                counts: Counts) -> None:
    if forward and forward[-1][1] == counts:
        forward[-1] = (forward[-1][0] + tau, counts)
    else:
        forward.append((tau, counts))


def _fast_forward(ready: list[_Task], forward: list[tuple[int, Counts]],
                  t_now: int, next_release: int | None,
                  entry: tuple) -> int:
    """Replay the detected period as many times as provably safe.

    ``entry`` is (t_prev, {idx: rem}, n_runs, last_tau) recorded when the
    same fingerprint was last seen (with no release in between).  Returns
    the cycles advanced (0 if no safe replay exists); mutates ``forward``
    and the tasks' ``rem``.
    """
    t_prev, rem_prev, n_runs, last_tau = entry
    t_period = t_now - t_prev
    if t_period <= 0:
        return 0
    work = {t.idx: rem_prev[t.idx] - t.rem for t in ready}
    n_rep = _FAR
    if next_release is not None:
        n_rep = (next_release - t_now) // t_period
    for t in ready:
        w = work[t.idx]
        if w <= 0:
            continue
        dl = t.delta // t.width
        n_safe = (t.rem - dl) // w
        if n_safe < n_rep:
            n_rep = n_safe
    if n_rep >= _FAR or n_rep < 1:
        return 0
    period: list[tuple[int, Counts]] = []
    if n_runs > 0 and forward[n_runs - 1][0] > last_tau:
        # the period's first run merged into the run open at record time
        period.append((forward[n_runs - 1][0] - last_tau,
                       forward[n_runs - 1][1]))
    period.extend(forward[n_runs:])
    assert sum(tau for tau, _ in period) == t_period
    for _ in range(n_rep):
        for tau, counts in period:
            _append_run(forward, tau, counts)
    for t in ready:
        t.rem -= n_rep * work[t.idx]
    return n_rep * t_period


# ----------------------------------------------------------------------
# the unified engine
# ----------------------------------------------------------------------
def _run_engine(tasks: list[_Task], m: int, fill_residual: bool,
                per_cycle: bool) -> list[tuple[int, Counts]]:
    """Event loop shared by both modes; ``per_cycle`` pins tau to 1.

    Releases live in a heap; completions and height-equalizations are
    folded into the jump bound; recurring bulk-regime fingerprints
    trigger the periodic fast-forward.  Consecutive identical allocations
    merge, so both modes emit maximal runs — hence bit-identical layouts.
    """
    heap = [(t.release, i) for i, t in enumerate(tasks)]
    heapq.heapify(heap)
    forward: list[tuple[int, Counts]] = []
    ready: list[_Task] = []
    # fingerprint -> (t_at, {idx: rem}, n_runs, last_tau); cleared on
    # every release so a period never spans one
    fp_map: dict[tuple, tuple] = {}
    t_now = 0
    while heap or ready:
        released = False
        while heap and heap[0][0] <= t_now:
            _, i = heapq.heappop(heap)
            ready.append(tasks[i])
            released = True
        if released:
            fp_map.clear()
        ready = [t for t in ready if t.rem > 0]
        if not ready:
            if not heap:
                break
            # idle until the next release; idle cycles are *not* emitted —
            # dropping them in due-date space only reduces lateness
            t_now = heap[0][0]
            continue
        next_release = heap[0][0] if heap else None
        if not per_cycle:
            fp = _bulk_fingerprint(ready)
            if fp is not None:
                ent = fp_map.get(fp)
                if ent is not None:
                    advanced = _fast_forward(ready, forward, t_now,
                                             next_release, ent)
                    if advanced:
                        t_now += advanced
                        fp_map.clear()
                        continue
                if len(fp_map) >= _FP_MAP_LIMIT:
                    fp_map.clear()
                fp_map[fp] = (t_now, {t.idx: t.rem for t in ready},
                              len(forward),
                              forward[-1][0] if forward else 0)
        alloc = _find_capabilities(ready, m, fill_residual)
        assert alloc, "FIND_CAPABILITIES must allocate at least one task"
        tau = 1 if per_cycle else _exact_tau(ready, alloc, next_release,
                                             t_now)
        counts: Counts = tuple(
            (task.idx, beta // task.width) for task, beta in alloc
        )
        _append_run(forward, tau, counts)
        for task, beta in alloc:
            task.rem -= tau * (beta // task.width)
            assert task.rem >= 0
        t_now += tau
    return forward


def schedule(problem: LayoutProblem, *, mode: str = "auto",
             fill_residual: bool = False,
             cache: "LayoutCache | None" = None,
             _cycle_limit: int = 1 << 16) -> Layout:
    """Run Iris on ``problem`` and return the due-date-space :class:`Layout`.

    mode: 'cycle' (per-cycle replay, O(C_max)), 'interval' (event-driven,
    O(events)), or 'auto' (cycle below ``_cycle_limit`` estimated cycles).
    Both modes produce bit-identical layouts; they differ only in cost.

    ``cache``: an optional :class:`LayoutCache`; on a hit the scheduler
    does not run at all.
    """
    if mode not in ("auto", "cycle", "interval"):
        raise ValueError(f"unknown mode {mode!r}")
    if cache is not None:
        hit = cache.lookup(problem, fill_residual)
        if hit is not None:
            return hit
    prob = problem
    d_max = prob.d_max
    tasks = [
        _Task(
            idx=i,
            width=a.width,
            release=d_max - a.due,
            delta=a.delta(prob.m),
            rem=a.depth,
        )
        for i, a in enumerate(prob.arrays)
    ]
    if mode == "auto":
        est = sum(t.rem * t.width for t in tasks) / prob.m + d_max
        mode = "cycle" if est <= _cycle_limit else "interval"

    forward = _run_engine(tasks, prob.m, fill_residual,
                          per_cycle=(mode == "cycle"))
    lay = Layout.from_count_intervals(prob, forward, reverse=True)
    if cache is not None:
        cache.insert(problem, fill_residual, lay)
    return lay


# ----------------------------------------------------------------------
# layout cache + batch API
# ----------------------------------------------------------------------
class LayoutCache:
    """Content-addressed LRU cache of solved layout problems.

    Keyed on ``LayoutProblem.canonical_signature()`` (name-independent)
    plus the ``fill_residual`` flag.  Mode is deliberately *not* part of
    the key: the unified engine emits bit-identical layouts in both
    modes, so a layout solved in either mode answers both.  A hit whose
    cached problem differs only in array names is rebound via
    :meth:`Layout.rebind` — O(intervals), no scheduling.
    """

    def __init__(self, maxsize: int = 256) -> None:
        if maxsize <= 0:
            raise ValueError(f"maxsize must be positive, got {maxsize}")
        self.maxsize = maxsize
        self._store: OrderedDict[tuple, Layout] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    @staticmethod
    def _key(problem: LayoutProblem, fill_residual: bool) -> tuple:
        return (problem.canonical_signature(), bool(fill_residual))

    def lookup(self, problem: LayoutProblem,
               fill_residual: bool = False) -> Layout | None:
        key = self._key(problem, fill_residual)
        lay = self._store.get(key)
        if lay is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return lay.rebind(problem)

    def insert(self, problem: LayoutProblem, fill_residual: bool,
               layout: Layout) -> None:
        key = self._key(problem, fill_residual)
        self._store[key] = layout
        self._store.move_to_end(key)
        while len(self._store) > self.maxsize:
            self._store.popitem(last=False)

    def clear(self) -> None:
        self._store.clear()
        self.hits = 0
        self.misses = 0

    @property
    def stats(self) -> dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "size": len(self._store),
            "maxsize": self.maxsize,
        }


#: Process-wide cache used by the DSE sweeps, model packing and serving.
DEFAULT_CACHE = LayoutCache(maxsize=512)


def schedule_many(problems: Sequence[LayoutProblem], *, mode: str = "auto",
                  fill_residual: bool = False,
                  cache: LayoutCache | None = DEFAULT_CACHE) -> list[Layout]:
    """Batch API: one scheduler run per *unique* scheduling instance.

    Problems sharing a canonical signature (e.g. every layer of a uniform
    decoder) are scheduled once and rebound; results are returned in
    input order.  ``cache=None`` still dedupes within the batch via an
    ephemeral cache.
    """
    local = cache if cache is not None \
        else LayoutCache(maxsize=max(1, len(problems)))
    return [
        schedule(p, mode=mode, fill_residual=fill_residual, cache=local)
        for p in problems
    ]
