"""Iris layout scheduler (paper Algorithms 1.1, 1.2, 1.3).

The bus-layout problem is solved as preemptive multiprocessor scheduling of
linear-speedup tasks (Drozdowski 1996): the m-bit bus is m identical
processors, array j is a task with processing time ``p_j = W_j * D_j``,
maximum parallelism ``delta_j = floor(m/W_j)*W_j``, and release time
``r_j = d_max - d_j``.  The schedule is computed forward in release-time
space and reversed into due-date space to optimize ``L_max``.

Two execution modes:

* ``cycle``    — re-run FIND_CAPABILITIES every bus cycle.  Exact w.r.t.
  element indivisibility and integral heights; used for paper-scale
  problems and all reproduction tests.
* ``interval`` — the paper's event-driven form: compute one allocation and
  jump ``tau = min(tau', tau'', next-release)`` cycles at once (Alg 1.1
  lines 8-13).  O(events) instead of O(C_max); required for model-packing
  problems with millions of cycles.  Produces the same allocations at event
  boundaries; transient single-cycle tie-group differences may shift
  metrics by O(1) cycles (property-tested against ``cycle`` mode).

Deviations from the paper's pseudocode are deliberate and documented in
DESIGN.md §2 (the pseudocode has typos; our resolution reproduces every
worked number in the paper).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .layout import Counts, Layout
from .task import LayoutProblem


@dataclasses.dataclass
class _Task:
    idx: int          # index into problem.arrays
    width: int
    release: int
    delta: int        # max bits/cycle (already max_lanes-clamped)
    rem: int          # remaining elements

    @property
    def delta_eff(self) -> int:
        """Usable width right now: never claim lanes beyond remaining work."""
        return min(self.delta, self.rem * self.width)

    @property
    def lanes_eff(self) -> int:
        return self.delta_eff // self.width

    @property
    def height(self) -> int:
        """h(j) = ceil(rem / lanes) — remaining cycles at max parallelism."""
        return -(-self.rem // self.lanes_eff)

    @property
    def frac_height(self) -> float:
        return self.rem / self.lanes_eff


def _lrm_allocation(group: list[_Task], avail: int) -> dict[int, int]:
    """Largest-remainder (Hamilton) apportionment in element-width seats.

    Paper Alg 1.3, with the §4 modification: allocations are whole
    multiples of each element's bitwidth (elements are indivisible).
    Returns {task_idx: beta_bits}; beta is a multiple of W and <= delta_eff.
    """
    total = sum(t.delta_eff for t in group)
    assert total > avail > 0
    beta: dict[int, int] = {}
    rem_frac: list[tuple[float, int, _Task]] = []
    for order, t in enumerate(group):
        v = t.delta_eff * avail / total          # fair fractional share
        b = min((int(v) // t.width) * t.width, t.delta_eff)
        beta[t.idx] = b
        rem_frac.append((v - b, order, t))
    spent = sum(beta.values())
    left = avail - spent
    # hand out remaining seats (one element = W_j bits) by largest remainder
    rem_frac.sort(key=lambda x: (-x[0], x[1]))
    progressed = True
    while left > 0 and progressed:
        progressed = False
        for _, _, t in rem_frac:
            if left >= t.width and beta[t.idx] + t.width <= t.delta_eff:
                beta[t.idx] += t.width
                left -= t.width
                progressed = True
                if left == 0:
                    break
    return beta


def _find_capabilities(ready: list[_Task], m: int,
                       fill_residual: bool) -> list[tuple[_Task, int]]:
    """Paper Alg 1.2: allocate bus bits to the highest tasks first.

    Returns [(task, beta_bits)] in allocation (lane) order, beta > 0.
    ``fill_residual=False`` is the paper-faithful behaviour (avail := 0
    after an LRM round, line 27); ``True`` keeps offering leftover bits to
    lower groups — a beyond-paper refinement measured in EXPERIMENTS.md.
    """
    avail = m
    out: list[tuple[_Task, int]] = []
    # group by equal height, tallest first; stable within a group
    by_height: dict[int, list[_Task]] = {}
    for t in ready:
        by_height.setdefault(t.height, []).append(t)
    for h in sorted(by_height, reverse=True):
        if avail <= 0:
            break
        group = by_height[h]
        total = sum(t.delta_eff for t in group)
        if total <= avail:
            for t in group:
                out.append((t, t.delta_eff))
            avail -= total
        else:
            beta = _lrm_allocation(group, avail)
            spent = 0
            for t in group:
                b = beta.get(t.idx, 0)
                if b > 0:
                    out.append((t, b))
                    spent += b
            avail -= spent
            if not fill_residual:
                break          # paper line 27: avail := 0
    return out


def _tau_jump(ready: list[_Task], alloc: list[tuple[_Task, int]],
              next_release: int | None, t_now: int) -> int:
    """Event horizon: paper Alg 1.1 lines 8-13 (tau', tau'', next release)."""
    taus: list[float] = []
    # tau'': earliest completion of any allocated task at its current rate
    for task, beta in alloc:
        n = beta // task.width
        taus.append(task.rem // n)           # full cycles it can sustain
    # tau': first height equalization between adjacent rate-diverse tasks
    rates = {t.idx: 0.0 for t in ready}
    for task, beta in alloc:
        rates[task.idx] = beta / task.delta_eff
    ordered = sorted(ready, key=lambda t: -t.frac_height)
    for a, b in zip(ordered, ordered[1:]):
        ra, rb = rates[a.idx], rates[b.idx]
        ha, hb = a.frac_height, b.frac_height
        if ha > hb and ra > rb:
            taus.append((ha - hb) / (ra - rb))
    if next_release is not None:
        taus.append(next_release - t_now)
    tau = int(math.floor(min(taus)))
    return max(1, tau)


def schedule(problem: LayoutProblem, *, mode: str = "auto",
             fill_residual: bool = False,
             _cycle_limit: int = 1 << 16) -> Layout:
    """Run Iris on ``problem`` and return the due-date-space :class:`Layout`.

    mode: 'cycle' (exact, O(C_max)), 'interval' (event-driven, O(events)),
    or 'auto' (cycle below ``_cycle_limit`` estimated cycles).
    """
    if mode not in ("auto", "cycle", "interval"):
        raise ValueError(f"unknown mode {mode!r}")
    prob = problem
    d_max = prob.d_max
    tasks = [
        _Task(
            idx=i,
            width=a.width,
            release=d_max - a.due,
            delta=a.delta(prob.m),
            rem=a.depth,
        )
        for i, a in enumerate(prob.arrays)
    ]
    if mode == "auto":
        est = sum(t.rem * t.width for t in tasks) / prob.m + d_max
        mode = "cycle" if est <= _cycle_limit else "interval"

    releases = sorted({t.release for t in tasks})
    forward: list[tuple[int, Counts]] = []
    t_now = 0
    pending = sorted(tasks, key=lambda t: t.release)
    ready: list[_Task] = []
    pi = 0

    while pi < len(pending) or any(t.rem > 0 for t in ready):
        # admit newly released tasks (stable: release order, then input order)
        while pi < len(pending) and pending[pi].release <= t_now:
            ready.append(pending[pi])
            pi += 1
        ready = [t for t in ready if t.rem > 0]
        if not ready:
            # idle until the next release; idle cycles are *not* emitted —
            # dropping them in due-date space only reduces lateness
            assert pi < len(pending)
            t_now = pending[pi].release
            continue
        next_release = pending[pi].release if pi < len(pending) else None
        alloc = _find_capabilities(ready, prob.m, fill_residual)
        assert alloc, "FIND_CAPABILITIES must allocate at least one task"
        if mode == "cycle":
            tau = 1
        else:
            tau = _tau_jump(ready, alloc, next_release, t_now)
        counts: Counts = tuple(
            (task.idx, beta // task.width) for task, beta in alloc
        )
        if forward and forward[-1][1] == counts:
            forward[-1] = (forward[-1][0] + tau, counts)
        else:
            forward.append((tau, counts))
        for task, beta in alloc:
            task.rem -= tau * (beta // task.width)
            assert task.rem >= 0
        t_now += tau

    return Layout.from_count_intervals(prob, forward, reverse=True)
