"""Design-space exploration helpers (paper §1: Iris enables rapid DSE
over custom-precision widths and the delta/W resource/efficiency knob).

Sweeps run through the :mod:`repro.api` façade against a shared
:class:`repro.core.iris.LayoutCache` (the process-wide ``DEFAULT_CACHE``
unless overridden), so re-running a sweep — or running overlapping
sweeps — never re-solves a scheduling instance it has already seen.
Cached and uncached sweeps return identical rows because the unified
engine is deterministic and bit-exact in every mode (tested in
tests/test_dse.py).

:func:`sweep_strategies` is the registry-generic form: one metrics
column per registered strategy, no per-family imports.
"""
from __future__ import annotations

from typing import Callable, Sequence

from .iris import DEFAULT_CACHE, LayoutCache, schedule_many
from .layout import LayoutMetrics
from .task import LayoutProblem, make_problem


def sweep_strategies(problems: Sequence[LayoutProblem],
                     strategies: Sequence[str] | None = None,
                     cache: LayoutCache | None = DEFAULT_CACHE,
                     workers: int | None = None,
                     ) -> list[dict[str, LayoutMetrics]]:
    """Metrics for every problem x registered strategy.

    Iterates the façade's strategy registry (all registered strategies
    unless narrowed), returning one ``{strategy: LayoutMetrics}`` dict
    per problem in input order.

    The Iris column is pre-solved through the parallel
    :func:`~repro.core.iris.schedule_many` (pool fan-out over unique
    signatures, warm-start chaining, serial fallback), so a sweep over N
    unique problems no longer re-plans them one by one inside the
    compare loop — the loop then runs entirely on cache hits.  Results
    are bit-identical either way because the engine is deterministic in
    every mode.  ``workers`` caps the pool (``None`` = one per core).
    """
    from repro import api

    if strategies is None or "iris" in strategies:
        if cache is None:
            cache = LayoutCache(maxsize=max(1, len(problems)))
        schedule_many(list(problems), cache=cache, workers=workers)
    return [
        api.compare(p, strategies=strategies, cache=cache) for p in problems
    ]


def sweep_widths(problem_fn: Callable[..., LayoutProblem],
                 width_pairs: Sequence[tuple[int, int]],
                 cache: LayoutCache | None = DEFAULT_CACHE) -> list[dict]:
    """Paper Table 7: metrics across custom element widths.

    Row keys keep the paper's naming: ``naive_*`` is the homogeneous
    ('packed naive') comparator of §6.
    """
    problems = [problem_fn(*widths) for widths in width_pairs]
    swept = sweep_strategies(problems, ("homogeneous", "iris"), cache=cache)
    out = []
    for widths, row in zip(width_pairs, swept):
        nm, im = row["homogeneous"], row["iris"]
        out.append({
            "widths": widths,
            "naive_eff": nm.efficiency,
            "naive_cmax": nm.c_max,
            "naive_lmax": nm.l_max,
            "iris_eff": im.efficiency,
            "iris_cmax": im.c_max,
            "iris_lmax": im.l_max,
            "iris_fifo": sum(im.fifo_depth.values()),
            "naive_fifo": sum(nm.fifo_depth.values()),
        })
    return out


def sweep_max_lanes(problem: LayoutProblem,
                    lane_caps: Sequence[int | None],
                    cache: LayoutCache | None = DEFAULT_CACHE) -> list[dict]:
    """Paper Table 6: the delta/W knob trades efficiency for decode
    resources (FIFO write ports)."""
    problems = [
        make_problem(
            problem.m,
            [(a.name, a.width, a.depth, a.due) for a in problem.arrays],
            max_lanes=cap)
        for cap in lane_caps
    ]
    swept = sweep_strategies(problems, ("iris",), cache=cache)
    out = []
    for cap, row in zip(lane_caps, swept):
        m = row["iris"]
        out.append({
            "max_lanes": cap,
            "eff": m.efficiency,
            "cmax": m.c_max,
            "lmax": m.l_max,
            "fifo": sum(m.fifo_depth.values()),
        })
    return out
