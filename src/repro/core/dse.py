"""Design-space exploration helpers (paper §1: Iris enables rapid DSE
over custom-precision widths and the delta/W resource/efficiency knob)."""
from __future__ import annotations

from typing import Callable, Sequence

from .baselines import homogeneous_layout
from .iris import schedule
from .task import LayoutProblem, make_problem


def sweep_widths(problem_fn: Callable[..., LayoutProblem],
                 width_pairs: Sequence[tuple[int, int]]) -> list[dict]:
    """Paper Table 7: metrics across custom element widths."""
    out = []
    for widths in width_pairs:
        p = problem_fn(*widths)
        nm = homogeneous_layout(p).metrics()
        im = schedule(p).metrics()
        out.append({
            "widths": widths,
            "naive_eff": nm.efficiency,
            "naive_cmax": nm.c_max,
            "naive_lmax": nm.l_max,
            "iris_eff": im.efficiency,
            "iris_cmax": im.c_max,
            "iris_lmax": im.l_max,
            "iris_fifo": sum(im.fifo_depth.values()),
            "naive_fifo": sum(nm.fifo_depth.values()),
        })
    return out


def sweep_max_lanes(problem: LayoutProblem,
                    lane_caps: Sequence[int | None]) -> list[dict]:
    """Paper Table 6: the delta/W knob trades efficiency for decode
    resources (FIFO write ports)."""
    out = []
    for cap in lane_caps:
        p = make_problem(
            problem.m,
            [(a.name, a.width, a.depth, a.due) for a in problem.arrays],
            max_lanes=cap)
        m = schedule(p).metrics()
        out.append({
            "max_lanes": cap,
            "eff": m.efficiency,
            "cmax": m.c_max,
            "lmax": m.l_max,
            "fifo": sum(m.fifo_depth.values()),
        })
    return out
