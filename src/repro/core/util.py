"""Small shared helpers used across the core/kernels layers.

Hosts the bits that used to be copy-pasted per module: the ceiling
round-up every table-lowering and tile-padding site needs, and the
bundle element-padding step shared by :func:`repro.core.packing.pack_bundle`
and :func:`repro.tree.pack_tree`.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - type-only imports
    from .exec_plan import ExecProgram
    from .task import LayoutProblem

__all__ = ["round_up", "pad_bundle_elements"]


def round_up(x: int, to: int) -> int:
    """Smallest multiple of ``to`` that is >= ``x`` (``to`` > 0)."""
    if to <= 0:
        raise ValueError(f"round_up: 'to' must be positive, got {to}")
    return -(-x // to) * to


def pad_bundle_elements(prob: "LayoutProblem", prog: "ExecProgram",
                        data: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Flatten + zero-pad per-tensor element data up to whole scheduling
    units (``prog.piece_depths``), ready for
    :func:`repro.core.exec_plan.pack_compiled`.

    Shared by :func:`repro.core.packing.pack_bundle` and
    :func:`repro.tree.pack_tree` — the one place bundle element streams
    meet the compiled pack program.
    """
    padded: dict[str, np.ndarray] = {}
    for i, spec in enumerate(prob.arrays):
        vals = np.asarray(data[spec.name]).reshape(-1).astype(np.uint64)
        pad = prog.piece_depths[i] - vals.shape[0]
        if pad < 0:
            raise ValueError(
                f"{spec.name}: {vals.shape[0]} elements exceed the "
                f"scheduled capacity {prog.piece_depths[i]}"
            )
        if pad:
            vals = np.pad(vals, (0, pad))
        padded[spec.name] = vals
    return padded
