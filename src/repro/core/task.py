"""Problem specification for the Iris bus-layout problem.

An *array* (paper: "task") is a 1-D stream of ``depth`` elements, each
``width`` bits wide, that must be transferred over an ``m``-bit bus and is
wanted by the accelerator at cycle ``due`` (the due date, derived from the
consumer dataflow graph).  See paper §3 (Table 1) for the notation.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """One input array of the layout problem (paper Table 3 row)."""

    name: str
    width: int           # W_j: element bitwidth
    depth: int           # D_j: number of elements
    due: int             # d_j: due date in bus cycles
    max_lanes: int | None = None  # optional cap on delta_j / W_j (Table 6 sweep)

    def __post_init__(self) -> None:
        if self.width <= 0:
            raise ValueError(f"{self.name}: width must be positive, got {self.width}")
        if self.depth <= 0:
            raise ValueError(f"{self.name}: depth must be positive, got {self.depth}")
        if self.due < 0:
            raise ValueError(f"{self.name}: due date must be >= 0, got {self.due}")
        if self.max_lanes is not None and self.max_lanes <= 0:
            raise ValueError(f"{self.name}: max_lanes must be positive")

    @property
    def processing_time(self) -> int:
        """p_j = W_j * D_j — total bits of the array."""
        return self.width * self.depth

    def delta(self, m: int) -> int:
        """delta_j = floor(m / W_j) * W_j — max bits usable per cycle.

        Optionally clamped to ``max_lanes`` whole elements (paper Table 6's
        delta/W sweep).
        """
        lanes = m // self.width
        if lanes == 0:
            raise ValueError(
                f"{self.name}: element width {self.width} exceeds bus width {m}"
            )
        if self.max_lanes is not None:
            lanes = min(lanes, self.max_lanes)
        return lanes * self.width

    def height(self, m: int) -> int:
        """h(j) = ceil(D_j / (delta_j / W_j)) — min cycles at max parallelism.

        Matches paper Table 4 (h is an integral cycle count).
        """
        lanes = self.delta(m) // self.width
        return -(-self.depth // lanes)


@dataclasses.dataclass(frozen=True)
class LayoutProblem:
    """A full bus-layout problem instance (bus width + arrays)."""

    m: int                       # bus width in bits
    arrays: tuple[ArraySpec, ...]

    def __post_init__(self) -> None:
        if self.m <= 0:
            raise ValueError(f"bus width must be positive, got {self.m}")
        names = [a.name for a in self.arrays]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate array names: {names}")
        if not self.arrays:
            raise ValueError("problem must contain at least one array")
        object.__setattr__(self, "arrays", tuple(self.arrays))

    @property
    def p_tot(self) -> int:
        """Total bits across all arrays (paper: p_tot)."""
        return sum(a.processing_time for a in self.arrays)

    @property
    def d_max(self) -> int:
        return max(a.due for a in self.arrays)

    def release_time(self, a: ArraySpec) -> int:
        """r_j = d_max - d_j (paper §4: due-date -> release-time conversion)."""
        return self.d_max - a.due

    def canonical_signature(self) -> tuple:
        """Name-independent content signature of the problem.

        Two problems with the same signature are the *same scheduling
        instance*: the scheduler's output depends only on the bus width and
        the ordered (width, depth, due, max_lanes) tuples — array names are
        labels.  Input order is part of the signature because the scheduler
        breaks ties by it.  This is the content-address used by
        :class:`repro.core.iris.LayoutCache`.
        """
        return (
            self.m,
            tuple((a.width, a.depth, a.due, a.max_lanes) for a in self.arrays),
        )

    # ---- (de)serialization: the paper's prototype reads a JSON file ----
    def to_json(self) -> str:
        return json.dumps(
            {
                "bus_width": self.m,
                "arrays": [
                    {
                        "name": a.name,
                        "width": a.width,
                        "depth": a.depth,
                        "due": a.due,
                        **(
                            {"max_lanes": a.max_lanes}
                            if a.max_lanes is not None
                            else {}
                        ),
                    }
                    for a in self.arrays
                ],
            },
            indent=2,
        )

    @staticmethod
    def from_json(text: str) -> "LayoutProblem":
        obj = json.loads(text)
        return LayoutProblem(
            m=obj["bus_width"],
            arrays=tuple(
                ArraySpec(
                    name=a["name"],
                    width=a["width"],
                    depth=a["depth"],
                    due=a.get("due", 0),
                    max_lanes=a.get("max_lanes"),
                )
                for a in obj["arrays"]
            ),
        )


def make_problem(
    m: int,
    specs: Sequence[tuple[str, int, int, int]],
    max_lanes: int | None = None,
) -> LayoutProblem:
    """Convenience constructor from (name, width, depth, due) tuples."""
    return LayoutProblem(
        m=m,
        arrays=tuple(
            ArraySpec(name=n, width=w, depth=d, due=dd, max_lanes=max_lanes)
            for (n, w, d, dd) in specs
        ),
    )


#: The worked example of paper §4, Table 3.
PAPER_EXAMPLE = make_problem(
    m=8,
    specs=[
        ("A", 2, 5, 2),
        ("B", 3, 5, 6),
        ("C", 4, 3, 3),
        ("D", 5, 4, 6),
        ("E", 6, 2, 3),
    ],
)

#: Paper Table 5 — Inverse Helmholtz accelerator inputs (m=256 on Alveo u280).
INV_HELMHOLTZ = make_problem(
    m=256,
    specs=[
        ("u", 64, 1331, 333),
        ("S", 64, 121, 31),
        ("D", 64, 1331, 363),
    ],
)


def matmul_problem(w_a: int = 64, w_b: int = 64, depth: int = 625,
                   due: int = 157, m: int = 256) -> LayoutProblem:
    """Paper Table 5/7 — Matrix-Multiplication accelerator inputs."""
    return make_problem(m, [("A", w_a, depth, due), ("B", w_b, depth, due)])
