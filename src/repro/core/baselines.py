"""Baseline layouts the paper compares against (Figs. 3 and 4, §6).

All baselines are emitted directly in due-date space (no reversal): arrays
are concatenated in increasing-due-date order.
"""
from __future__ import annotations

from .layout import Counts, Layout
from .task import LayoutProblem


def _due_order(problem: LayoutProblem) -> list[int]:
    """Array indices sorted by increasing due date (stable)."""
    return sorted(range(len(problem.arrays)),
                  key=lambda i: (problem.arrays[i].due, i))


def naive_layout(problem: LayoutProblem) -> Layout:
    """Fig. 3: one element per bus word, arrays concatenated by due date.

    Reproduces the paper's 'completely naive' §4 numbers:
    C_max=19, L_max=13, B_eff=45.4%.
    """
    intervals: list[tuple[int, Counts]] = []
    for i in _due_order(problem):
        intervals.append((problem.arrays[i].depth, ((i, 1),)))
    return Layout.from_count_intervals(problem, intervals)


def homogeneous_layout(problem: LayoutProblem) -> Layout:
    """Fig. 4: per-array dense packing, arrays concatenated by due date.

    Each cycle carries ``floor(m/W)`` elements of a single array (the last
    cycle of an array may be partial).  This is the 'packed naive' layout of
    [22] used as the main comparator in §6.  Reproduces C_max=13, L_max=7,
    B_eff=66.3% on the §4 example and the naive columns of Tables 6/7.
    """
    intervals: list[tuple[int, Counts]] = []
    for i in _due_order(problem):
        a = problem.arrays[i]
        lanes = a.delta(problem.m) // a.width
        full, rem = divmod(a.depth, lanes)
        if full:
            intervals.append((full, ((i, lanes),)))
        if rem:
            intervals.append((1, ((i, rem),)))
    return Layout.from_count_intervals(problem, intervals)


def hls_padded_layout(problem: LayoutProblem) -> Layout:
    """What an HLS tool does automatically: pad W to the next power of two.

    Elements are widened to ``2^ceil(log2(W))`` so the bus divides into
    equal lanes, then packed homogeneously.  Models the 'HLS-optimized'
    comparator of §1 (bus width evenly divisible by data width).  Efficiency
    still counts only the true ``p_tot`` bits, so padding shows up as waste.
    """
    intervals: list[tuple[int, Counts]] = []
    for i in _due_order(problem):
        a = problem.arrays[i]
        padded = 1 << max(0, (a.width - 1).bit_length())
        padded = min(padded, problem.m)
        lanes = max(1, problem.m // padded)
        if a.max_lanes is not None:
            lanes = min(lanes, a.max_lanes)
        full, rem = divmod(a.depth, lanes)
        if full:
            intervals.append((full, ((i, lanes),)))
        if rem:
            intervals.append((1, ((i, rem),)))
    # NOTE: bit offsets inside the Layout are computed with the TRUE widths,
    # so the layout object remains a valid dense plan; the padding cost is
    # modelled in the cycle count (lanes per cycle), which is what drives
    # every metric.  See tests/test_iris_paper_example.py.
    return Layout.from_count_intervals(problem, intervals)


ALL_BASELINES = {
    "naive": naive_layout,
    "homogeneous": homogeneous_layout,
    "hls_padded": hls_padded_layout,
}
