"""Compiled execution plans: lower a :class:`Layout` once into flat tables.

The per-slot paths in :mod:`repro.core.codegen` walk the layout with a
Python loop per (interval, slot, lane); a real LM layer bundle has
hundreds of decode units, so execution cost is dominated by interpreter
and launch overhead instead of bandwidth — the exact failure the paper's
single ``read_data`` module (one II=1 loop over bus words) avoids.  This
module compiles the layout *once* into numpy index tables so that
executing it is a handful of whole-buffer vectorized passes:

* :func:`pack_compiled` / :func:`unpack_compiled` — host-side pack and
  its inverse with zero per-lane Python loops.  Packing shifts every
  piece into word position at once, then ORs contributions into the
  destination words in *rank layers* (layer r holds each word's (r+1)-th
  contribution, so indices within a layer are unique and every pass is a
  conflict-free vectorized ``|=``); unpacking is a flat gather + funnel
  shift.
* :class:`KernelTable` — the static slot encoding consumed by the fused
  Pallas decode kernel (``repro.kernels.layout_decode.decode_layout_fused``):
  one ``(c_max, lanes)`` uint32 table holding ``bit_offset | width << 20``
  per decoded element per bus row, plus per-array gather indices that
  rearrange the kernel's row-major output grid into element streams.

**Element granularity.**  A program is lowered at a chosen *piece* width
per array (``elem_widths``).  ``None`` means one piece per element
(requires ``width <= 64``).  Model bundles schedule multi-element *units*
whose widths exceed 64 bits; lowering them at their natural sub-element
width (``BundleTensor.width_bits``) lets the same tables pack and decode
bundle data directly at element granularity — absorbing the per-unit
merge loop ``pack_bundle`` used to run, and making >64-bit-unit bundles
packable at all.

Programs contain **no array names** (indices only), so one program is
shared by every :meth:`Layout.rebind` of the same scheduling instance —
a :class:`~repro.core.iris.LayoutCache` hit returns a layout whose
``_exec_cache`` already holds the lowered program, and the lowering cost
is paid once per cache entry, not per consumer.

Bit conventions match :mod:`repro.core.codegen`: bus cycle = one row of
``m`` bits, element LSB at its bit offset, rows little-endian in bytes.
The uint64 word views below rely on the host being little-endian, like
the byte views in ``codegen._scatter_bits``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .layout import Layout
from .util import round_up as _round_up

#: Piece widths above this go to the host path instead of the Pallas
#: kernel (u32 funnel shifts decode at most 32-bit pieces).
KERNEL_MAX_WIDTH = 32

#: Kernel slot-table encoding: ``bit_offset | width << _TAB_WIDTH_SHIFT``.
_TAB_WIDTH_SHIFT = 20


@dataclasses.dataclass(eq=False)
class KernelTable:
    """Static per-row slot table for the fused Pallas decode kernel."""

    words32: int                 # u32 words per bus row
    lanes: int                   # table width: max decoded pieces per row
    tab: np.ndarray              # (c_max, lanes) uint32, 0 = empty lane
    #: (array_index, flat indices ``row * lanes + col`` in piece order)
    gathers: tuple[tuple[int, np.ndarray], ...]


@dataclasses.dataclass(eq=False)
class ExecProgram:
    """A lowered layout: flat destination tables plus the pack program.

    All tables are in *global piece order* (arrays concatenated in
    problem order, each array's pieces in element order).
    """

    m: int
    c_max: int
    row_bytes: int
    wpr: int                             # uint64 words per row
    elem_widths: tuple[int, ...]         # piece width per array
    piece_depths: tuple[int, ...]        # pieces per array
    piece_base: tuple[int, ...]          # prefix sums, len n_arrays + 1
    # index dtypes are downcast to int32 when the program fits (they
    # almost always do); shifts are uint8 — numpy promotes uint64 OP
    # uint8 to uint64, and the narrow tables halve index memory traffic
    word: np.ndarray                     # int[P] dest uint64-word index
    shift: np.ndarray                    # uint8[P] bit shift within word
    # pack program.  Contribution vector cv = [each piece's shifted lo
    # part (piece order), hi parts of word-straddling pieces (piece
    # order, grouped per array)].  Building cv is sequential; each rank
    # layer then ORs every word's (r+1)-th contribution into place —
    # word indices within a layer are unique, so the passes are
    # conflict-free vectorized ``|=``, and the single random-access pass
    # per layer (the cv gather) is the information-theoretic minimum for
    # the piece-order -> word-order permutation.
    hi_tabs: tuple[tuple[np.ndarray, np.ndarray], ...]
    # per array: (local piece idx int[h_i], shr uint8[h_i])
    hi_base: tuple[int, ...]             # prefix sums of h_i, len n+1
    pack_layers: tuple[tuple[np.ndarray, np.ndarray], ...]
    # per rank layer: (sel int (contribution ids), words int)
    n_contribs: int
    kernel: KernelTable
    host_arrays: tuple[int, ...]         # arrays with piece width > 32

    #: decode-side jit memo, keyed by (tile_rows, interpret) — filled by
    #: repro.kernels.layout_decode so one trace serves every decode of
    #: this layout signature
    jit_cache: dict = dataclasses.field(default_factory=dict)

    @property
    def n_pieces(self) -> int:
        return self.piece_base[-1]

    @property
    def n_pallas_calls(self) -> int:
        """Fused-decode kernel launches: 1, or 0 if everything is host-side."""
        return 1 if self.kernel.gathers else 0

    # ------------------------------------------------------------------
    # host execution (index space; named wrappers below)
    # ------------------------------------------------------------------
    def pack_indexed(self, data: list[np.ndarray]) -> np.ndarray:
        """Pack per-array piece vectors into the ``(c_max, m/8)`` buffer."""
        flat = np.zeros(self.c_max * self.wpr, dtype=np.uint64)
        n = self.n_pieces
        if len(self.pack_layers) == 1 and self.n_contribs == n:
            # no word is shared and nothing straddles: shift straight
            # into place, one pass per array, no contribution vector
            for i, a in enumerate(data):
                sl = slice(self.piece_base[i], self.piece_base[i + 1])
                flat[self.word[sl]] = a << self.shift[sl]
        else:
            cv = np.empty(self.n_contribs, dtype=np.uint64)
            for i, a in enumerate(data):
                sl = slice(self.piece_base[i], self.piece_base[i + 1])
                np.left_shift(a, self.shift[sl], out=cv[sl])
                loc, shr = self.hi_tabs[i]
                if loc.shape[0]:
                    cv[n + self.hi_base[i]:n + self.hi_base[i + 1]] = \
                        a[loc] >> shr
            sel0, words0 = self.pack_layers[0]
            flat[words0] = cv[sel0]      # rank 0 covers every used word
            for sel, words in self.pack_layers[1:]:
                flat[words] |= cv[sel]
        return flat.view(np.uint8).reshape(
            self.c_max, self.wpr * 8)[:, :self.row_bytes]

    def unpack_array(self, flat: np.ndarray, i: int) -> np.ndarray:
        """Gather array ``i``'s pieces from the flat uint64 word vector."""
        lo, hi = self.piece_base[i], self.piece_base[i + 1]
        w, sh = self.word[lo:hi], self.shift[lo:hi]
        ew = self.elem_widths[i]
        v = flat[w] >> sh
        straddle = sh > np.uint64(64 - ew)
        if straddle.any():
            # (64 - sh) & 63 is exact where straddle holds (sh >= 1 there)
            part = flat[np.minimum(w + 1, flat.shape[0] - 1)] \
                << ((np.uint64(64) - sh) & np.uint64(63))
            v |= np.where(straddle, part, np.uint64(0))
        if ew < 64:
            v &= np.uint64((1 << ew) - 1)
        return v

    def unpack_indexed(self, buf: np.ndarray,
                       arrays: tuple[int, ...] | None = None,
                       ) -> dict[int, np.ndarray]:
        flat = self.buffer_words64(buf)
        idxs = range(len(self.piece_depths)) if arrays is None else arrays
        return {i: self.unpack_array(flat, i) for i in idxs}

    # ------------------------------------------------------------------
    def buffer_words64(self, buf: np.ndarray) -> np.ndarray:
        """(c_max, m/8) uint8 rows -> flat little-endian uint64 words."""
        if buf.shape != (self.c_max, self.row_bytes):
            raise ValueError(
                f"buffer shape {buf.shape} != "
                f"({self.c_max}, {self.row_bytes})"
            )
        padded = np.zeros((self.c_max, self.wpr * 8), dtype=np.uint8)
        padded[:, :self.row_bytes] = buf
        return padded.view(np.uint64).reshape(-1)

    def buffer_words32(self, buf: np.ndarray) -> np.ndarray:
        """(c_max, m/8) uint8 rows -> (c_max, words32) uint32 rows."""
        if buf.shape != (self.c_max, self.row_bytes):
            raise ValueError(
                f"buffer shape {buf.shape} != "
                f"({self.c_max}, {self.row_bytes})"
            )
        padded = np.zeros((self.c_max, self.kernel.words32 * 4),
                          dtype=np.uint8)
        padded[:, :self.row_bytes] = np.asarray(buf, dtype=np.uint8)
        return padded.view(np.uint32)

    def stream_bit_offsets(self, i: int) -> np.ndarray:
        """Global bit offset of each of array ``i``'s pieces, in the
        flattened :meth:`buffer_words32` view.

        The u64 pack view pads each row to ``wpr * 8`` bytes while the
        u32 kernel view pads to ``words32 * 4``, so offsets must be
        rebuilt from (row, bit-within-row) rather than scaled from the
        u64 word index.  Returned as uint32 — one table entry addresses
        up to 2^32 stream bits (512 MiB), validated here.
        """
        lo, hi = self.piece_base[i], self.piece_base[i + 1]
        w = self.word[lo:hi].astype(np.int64)
        row, w_in_row = np.divmod(w, self.wpr)
        gbit = (row * (self.kernel.words32 * 32)
                + w_in_row * 64 + self.shift[lo:hi].astype(np.int64))
        if gbit.size and int(gbit.max()) + self.elem_widths[i] > (1 << 32):
            raise ValueError(
                "stream exceeds the 2^32-bit addressing range of the "
                "uint32 stream tables"
            )
        return gbit.astype(np.uint32)


@dataclasses.dataclass(eq=False)
class StreamTables:
    """Per-matmul operand tables for the stream-direct kernel.

    ``w_tab[kk, nn]`` / ``s_tab[gg, nn]`` hold the *global bit offset*
    (u32-word view, :meth:`ExecProgram.stream_bit_offsets`) of weight
    code ``(kk, nn)`` and scale ``(gg, nn)`` inside the packed stream.
    The kernel derives word index (``tab >> 5``) and shift (``tab & 31``)
    in registers; element width is static per operand (``bits`` / 16).
    """

    bits: int
    group_size: int
    w_tab: np.ndarray            # (K, N) uint32
    s_tab: np.ndarray            # (K // group_size, N) uint32


def stream_matmul_tables(layout: Layout, weights: int | str,
                         shape: tuple[int, int], *,
                         scales: int | str, group_size: int,
                         elem_widths: tuple[int, ...] | None = None,
                         program: ExecProgram | None = None,
                         ) -> StreamTables:
    """Build :class:`StreamTables` for one ``(K, N)`` weight matrix.

    ``weights`` / ``scales`` name (or index) the layout arrays holding
    the row-major flattened weight codes and bf16 scale bit patterns —
    the flattening convention of ``repro.tree``.  Works for any piece
    width <= 32 (no lane-packing divisibility constraint), which is what
    lifts ``packed_matmul``'s ``SUPPORTED_BITS`` restriction.
    """
    prog = program if program is not None \
        else lower_exec(layout, elem_widths)
    names = [a.name for a in layout.problem.arrays]

    def _resolve(ref) -> int:
        if isinstance(ref, str):
            if ref not in names:
                raise KeyError(f"no array named {ref!r}")
            return names.index(ref)
        return int(ref)

    wi, si = _resolve(weights), _resolve(scales)
    k, n = shape
    bits = prog.elem_widths[wi]
    if bits > KERNEL_MAX_WIDTH:
        raise ValueError(
            f"weight piece width {bits} > {KERNEL_MAX_WIDTH}; "
            "stream-direct extraction is u32-register based"
        )
    if prog.elem_widths[si] != 16:
        raise ValueError(
            f"scale piece width {prog.elem_widths[si]} != 16 (bf16)"
        )
    if k % group_size:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    if k * n > prog.piece_depths[wi]:
        raise ValueError(
            f"shape {shape} needs {k * n} weight pieces, array has "
            f"{prog.piece_depths[wi]}"
        )
    g = k // group_size
    if g * n > prog.piece_depths[si]:
        raise ValueError(
            f"shape {shape} needs {g * n} scale pieces, array has "
            f"{prog.piece_depths[si]}"
        )
    w_tab = prog.stream_bit_offsets(wi)[:k * n].reshape(k, n)
    s_tab = prog.stream_bit_offsets(si)[:g * n].reshape(g, n)
    return StreamTables(bits=bits, group_size=group_size,
                        w_tab=w_tab, s_tab=s_tab)


# ----------------------------------------------------------------------
# lowering
# ----------------------------------------------------------------------
def lower_exec(layout: Layout,
               elem_widths: tuple[int, ...] | None = None) -> ExecProgram:
    """Lower ``layout`` into an :class:`ExecProgram` (memoized per layout).

    ``elem_widths[i]`` is the piece width for array ``i`` — the
    granularity at which data enters ``pack`` and leaves ``unpack``.  It
    must divide the array's scheduled width and be <= 64.  ``None``
    lowers at whole-element granularity.

    The program is cached on the layout (``layout._exec_cache``) keyed by
    the resolved widths; :meth:`Layout.rebind` shares the cache dict, so
    every rebound copy handed out by a :class:`LayoutCache` hit sees the
    already-lowered program.
    """
    prob = layout.problem
    if elem_widths is None:
        key = tuple(a.width for a in prob.arrays)
    else:
        key = tuple(int(w) for w in elem_widths)
        if len(key) != len(prob.arrays):
            raise ValueError(
                f"elem_widths has {len(key)} entries for "
                f"{len(prob.arrays)} arrays"
            )
    cache = layout._exec_cache
    prog = cache.get(key)
    if prog is None:
        prog = _lower(layout, key)
        cache[key] = prog
    return prog


def _lower(layout: Layout, elem_widths: tuple[int, ...]) -> ExecProgram:
    prob = layout.problem
    if prob.m % 8 != 0:
        raise ValueError(f"bus width {prob.m} is not byte-aligned")
    for a, ew in zip(prob.arrays, elem_widths):
        if ew <= 0 or a.width % ew:
            raise ValueError(
                f"{a.name}: piece width {ew} does not divide width {a.width}"
            )
        if ew > 64:
            raise ValueError(
                f"{a.name}: piece width {ew} > 64; lower at a finer "
                "granularity (e.g. the bundle's element width)"
            )
    row_bytes = prob.m // 8
    wpr = -(-row_bytes // 8)
    c_max = layout.c_max
    subs = [a.width // ew for a, ew in zip(prob.arrays, elem_widths)]
    piece_depths = tuple(a.depth * s for a, s in zip(prob.arrays, subs))
    piece_base = (0, *np.cumsum(piece_depths).tolist())
    n_pieces = piece_base[-1]

    word = np.empty(n_pieces, dtype=np.int64)
    shift = np.empty(n_pieces, dtype=np.uint8)
    for iv in layout.intervals():
        rows = np.arange(iv.start_cycle, iv.start_cycle + iv.n_cycles)
        for (a, off, n), base in zip(iv.slots, iv.elem_base):
            w_elem, ew, s = prob.arrays[a].width, elem_widths[a], subs[a]
            # piece (c, k, j): cycle c, lane k, sub-element j
            c = np.arange(iv.n_cycles)[:, None, None]
            k = np.arange(n)[None, :, None]
            j = np.arange(s)[None, None, :]
            pid = piece_base[a] + (base + c * n + k) * s + j
            bits = off + k * w_elem + j * ew          # (1, n, s)
            word[pid] = rows[:, None, None] * wpr + (bits >> 6)
            shift[pid] = (bits & 63).astype(np.uint8)

    ewv = np.empty(n_pieces, dtype=np.int64)
    for i, ew in enumerate(elem_widths):
        ewv[piece_base[i]:piece_base[i + 1]] = ew
    hi_sel = np.flatnonzero(shift.astype(np.int64) + ewv > 64)

    # contribution order: [lo (piece order), hi (piece order)]; sort by
    # destination word and group by rank within each word
    cw = np.concatenate([word, word[hi_sel] + 1])
    n_contribs = cw.shape[0]
    perm = np.argsort(cw, kind="stable")
    sw = cw[perm]
    new_seg = np.concatenate([[True], sw[1:] != sw[:-1]])
    seg_starts = np.flatnonzero(new_seg)
    # rank of each sorted contribution within its destination word
    rank = np.arange(n_contribs) - seg_starts[np.cumsum(new_seg) - 1]
    # int32 indices where the program fits (halves index memory traffic)
    n_words = c_max * wpr
    idx_t = np.int32 \
        if max(n_words, n_contribs) < (1 << 31) else np.int64
    layers = []
    for r in range(int(rank.max()) + 1 if rank.size else 0):
        sel = rank == r
        layers.append((perm[sel].astype(idx_t), sw[sel].astype(idx_t)))
    hi_tabs = []
    hi_base = [0]
    for i in range(len(prob.arrays)):
        mask = (hi_sel >= piece_base[i]) & (hi_sel < piece_base[i + 1])
        loc = (hi_sel[mask] - piece_base[i]).astype(idx_t)
        shr = (64 - shift[hi_sel[mask]].astype(np.int64)).astype(np.uint8)
        hi_tabs.append((loc, shr))
        hi_base.append(hi_base[-1] + loc.shape[0])

    kernel, host = _lower_kernel_table(
        prob, elem_widths, piece_base, word, shift, wpr, c_max, row_bytes)
    return ExecProgram(
        m=prob.m, c_max=c_max, row_bytes=row_bytes, wpr=wpr,
        elem_widths=elem_widths, piece_depths=piece_depths,
        piece_base=piece_base, word=word.astype(idx_t),
        shift=shift, hi_tabs=tuple(hi_tabs), hi_base=tuple(hi_base),
        pack_layers=tuple(layers), n_contribs=n_contribs,
        kernel=kernel, host_arrays=host,
    )


def _lower_kernel_table(prob, elem_widths, piece_base, word, shift,
                        wpr, c_max, row_bytes,
                        ) -> tuple[KernelTable, tuple[int, ...]]:
    """Row-major slot encoding for the fused kernel.

    Kernel-eligible pieces (width <= 32) are sorted by (row, bit offset)
    and assigned dense per-row lane columns; ``tab[row, col]`` encodes
    ``bit_offset | width << 20`` (0 = empty).  The per-array gather
    indices invert the assignment: ``grid.ravel()[gathers[i]]`` is array
    ``i``'s piece stream.
    """
    if prob.m > (1 << _TAB_WIDTH_SHIFT):
        raise ValueError(
            f"bus width {prob.m} exceeds the kernel slot-table encoding"
        )
    kernel_arrays = tuple(
        i for i, ew in enumerate(elem_widths) if ew <= KERNEL_MAX_WIDTH)
    host_arrays = tuple(
        i for i, ew in enumerate(elem_widths) if ew > KERNEL_MAX_WIDTH)
    words32 = -(-row_bytes // 4)
    if not kernel_arrays:
        empty = KernelTable(words32=words32, lanes=0,
                            tab=np.zeros((c_max, 0), dtype=np.uint32),
                            gathers=())
        return empty, host_arrays

    ids = np.concatenate([
        np.arange(piece_base[i], piece_base[i + 1]) for i in kernel_arrays])
    rows = word[ids] // wpr
    bit_in_row = (word[ids] - rows * wpr) * 64 + shift[ids].astype(np.int64)
    order = np.lexsort((bit_in_row, rows))
    ids_s, rows_s, bits_s = ids[order], rows[order], bit_in_row[order]
    counts = np.bincount(rows_s, minlength=c_max)
    lanes = _round_up(max(int(counts.max()), 1), 128)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    cols = np.arange(ids_s.shape[0]) - starts[rows_s]

    widths = np.empty(ids_s.shape[0], dtype=np.uint32)
    garr = np.full(piece_base[-1], -1, dtype=np.int64)
    garr[ids_s] = rows_s * lanes + cols
    for i in kernel_arrays:
        sel = (ids_s >= piece_base[i]) & (ids_s < piece_base[i + 1])
        widths[sel] = elem_widths[i]
    tab = np.zeros((c_max, lanes), dtype=np.uint32)
    tab[rows_s, cols] = bits_s.astype(np.uint32) \
        | (widths << _TAB_WIDTH_SHIFT)
    gathers = tuple(
        (i, garr[piece_base[i]:piece_base[i + 1]].astype(np.int32))
        for i in kernel_arrays)
    return KernelTable(words32=words32, lanes=lanes, tab=tab,
                       gathers=gathers), host_arrays


# ----------------------------------------------------------------------
# named host entry points
# ----------------------------------------------------------------------
def pack_compiled(layout: Layout, arrays: dict[str, np.ndarray], *,
                  elem_widths: tuple[int, ...] | None = None,
                  program: ExecProgram | None = None) -> np.ndarray:
    """Vectorized :func:`~repro.core.codegen.pack_arrays` (bit-identical).

    ``arrays[name]`` holds each array's piece codes at the program's
    granularity (= element codes when ``elem_widths`` is None).  Lowering
    happens once per layout; repeated packs reuse the cached program.
    """
    prog = program if program is not None \
        else lower_exec(layout, elem_widths)
    data: list[np.ndarray] = []
    for i, spec in enumerate(layout.problem.arrays):
        if spec.name not in arrays:
            raise KeyError(f"missing array {spec.name!r}")
        a = np.asarray(arrays[spec.name]).reshape(-1)
        if a.dtype != np.uint64:
            a = a.astype(np.uint64)
        if a.shape[0] != prog.piece_depths[i]:
            raise ValueError(
                f"{spec.name}: expected {prog.piece_depths[i]} elements, "
                f"got {a.shape[0]}"
            )
        ew = prog.elem_widths[i]
        if ew < 64 and (a >> np.uint64(ew)).any():
            raise ValueError(f"{spec.name}: codes overflow {ew} bits")
        data.append(a)
    return prog.pack_indexed(data)


def unpack_compiled(layout: Layout, buf: np.ndarray, *,
                    elem_widths: tuple[int, ...] | None = None,
                    program: ExecProgram | None = None,
                    ) -> dict[str, np.ndarray]:
    """Vectorized :func:`~repro.core.codegen.unpack_arrays` (bit-identical)."""
    prog = program if program is not None \
        else lower_exec(layout, elem_widths)
    out = prog.unpack_indexed(np.asarray(buf))
    names = [a.name for a in layout.problem.arrays]
    return {names[i]: v for i, v in out.items()}


# ----------------------------------------------------------------------
# device pack tables (the inverse of the KernelTable direction)
# ----------------------------------------------------------------------
def pack_kernel_tables(prog: ExecProgram,
                       ) -> tuple[np.ndarray, np.ndarray, int]:
    """Gather-only contribution tables for the fused device pack kernel.

    The host pack (:meth:`ExecProgram.pack_indexed`) scatters piece
    contributions into destination words; scatters are pathological on
    the XLA CPU backend, so the device kernel inverts the mapping at
    lowering time: for every destination u32 word (``words32`` per row,
    :meth:`ExecProgram.buffer_words32` view) we precompute the <= K
    source pieces that contribute to it and the shift each needs.

    Returns ``(src, scode, K)`` where ``src``/``scode`` are
    ``(c_max, words32 * K)`` int32 tables.  ``src`` indexes a flat
    piece-order stream vector with a zero sentinel at index 0 (entry 0 =
    empty contribution slot, piece ``p`` stored as ``p + 1``);
    ``scode >= 0`` means shift left, ``< 0`` shift right (the hi part of
    a u32-straddling piece).  The kernel computes, per word,
    ``OR_k shift(flat[src_k], scode_k)`` — pure gathers, rank layers
    vectorized across the whole tile.  Memoized on the program
    (``jit_cache``), so the one-time numpy build is paid once per layout
    signature and shared across :class:`LayoutCache` rebinds.
    """
    key = ("pack_tables",)
    cached = prog.jit_cache.get(key)
    if cached is not None:
        return cached
    kt = prog.kernel
    w32 = kt.words32
    if not kt.gathers:
        empty = (np.zeros((prog.c_max, 0), dtype=np.int32),
                 np.zeros((prog.c_max, 0), dtype=np.int32), 1)
        prog.jit_cache[key] = empty
        return empty
    ids = np.concatenate([
        np.arange(prog.piece_base[i], prog.piece_base[i + 1])
        for i, _g in kt.gathers])
    word = prog.word[ids].astype(np.int64)
    rows = word // prog.wpr
    bit = (word - rows * prog.wpr) * 64 + prog.shift[ids].astype(np.int64)
    widths = np.empty(ids.shape[0], dtype=np.int64)
    for i, _g in kt.gathers:
        sel = (ids >= prog.piece_base[i]) & (ids < prog.piece_base[i + 1])
        widths[sel] = prog.elem_widths[i]
    w0 = bit >> 5
    sh = bit & 31
    strad = sh + widths > 32
    # contribution list: (destination u32 word, source piece, shift code);
    # a straddling piece contributes twice, its hi part right-shifted
    gw = np.concatenate([rows * w32 + w0, (rows * w32 + w0 + 1)[strad]])
    src = np.concatenate([ids, ids[strad]])
    sc = np.concatenate([sh, sh[strad] - 32])
    order = np.argsort(gw, kind="stable")
    gw, src, sc = gw[order], src[order], sc[order]
    new_seg = np.concatenate([[True], gw[1:] != gw[:-1]])
    seg_starts = np.flatnonzero(new_seg)
    rank = np.arange(gw.shape[0]) - seg_starts[np.cumsum(new_seg) - 1]
    k = int(rank.max()) + 1 if rank.size else 1
    src_t = np.zeros(prog.c_max * w32 * k, dtype=np.int32)
    sc_t = np.zeros(prog.c_max * w32 * k, dtype=np.int32)
    src_t[gw * k + rank] = src + 1          # 0 = empty slot sentinel
    sc_t[gw * k + rank] = sc
    tables = (src_t.reshape(prog.c_max, w32 * k),
              sc_t.reshape(prog.c_max, w32 * k), k)
    prog.jit_cache[key] = tables
    return tables
