"""Deterministic, shardable, checkpointable synthetic token pipeline.

Produces language-modeling batches from a seeded Markov-ish token
generator (so losses actually *decrease* during the example training runs
— the stream has learnable structure).  The pipeline state is a single
(step, seed) pair: restoring a checkpoint resumes the exact stream, and
each data-parallel host can slice its shard deterministically
(``host_slice``) — no coordination required, which is what survives
elastic re-scaling.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def as_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d: dict) -> "PipelineState":
        return PipelineState(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticLMPipeline:
    """Structured synthetic stream: tokens follow a degree-2 recurrence
    ``t[i] = (a * t[i-1] + b * t[i-2] + 7) % K`` over a small *active set*
    K = min(vocab, 97), with occasional noise jumps over the full vocab.
    The restriction to K matters: modulo the full vocab the next-token
    map is a pseudo-random permutation a small model cannot fit in a few
    hundred steps (measured); over ~100 tokens the transitions are
    memorizable and the loss drops well under the uniform floor."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, noise: float = 0.05,
                 active_vocab: int | None = None):
        self.vocab_size = vocab_size
        self.active = min(vocab_size, active_vocab or 97)
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.noise = noise
        self.state = PipelineState(seed=seed, step=0)

    # ------------------------------------------------------------------
    def _gen_batch(self, step: int, lo: int, hi: int) -> dict:
        """Rows [lo, hi) of the global batch for ``step``."""
        n = hi - lo
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, step]))
        # draw the whole batch's row params, slice our shard (cheap,
        # keeps every host bit-identical on overlapping rows)
        a = rng.integers(1, 8, size=self.global_batch)
        b = rng.integers(0, 8, size=self.global_batch)
        t0 = rng.integers(0, self.active, size=(self.global_batch, 2))
        flip = rng.random((self.global_batch, self.seq_len + 1))
        jump = rng.integers(0, self.vocab_size,
                            size=(self.global_batch, self.seq_len + 1))
        a, b, t0 = a[lo:hi], b[lo:hi], t0[lo:hi]
        flip, jump = flip[lo:hi], jump[lo:hi]
        toks = np.empty((n, self.seq_len + 1), dtype=np.int64)
        toks[:, 0] = t0[:, 0]
        toks[:, 1] = t0[:, 1]
        for i in range(2, self.seq_len + 1):
            nxt = (a * toks[:, i - 1] + b * toks[:, i - 2] + 7) \
                % self.active
            noisy = flip[:, i] < self.noise
            toks[:, i] = np.where(noisy, jump[:, i], nxt)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def next_batch(self, lo: int = 0, hi: int | None = None) -> dict:
        """Advance one step; return rows [lo, hi) of the global batch."""
        hi = self.global_batch if hi is None else hi
        out = self._gen_batch(self.state.step, lo, hi)
        self.state.step += 1
        return out

    def peek_batch(self, step: int, lo: int = 0, hi: int | None = None
                   ) -> dict:
        hi = self.global_batch if hi is None else hi
        return self._gen_batch(step, lo, hi)

    # ------------------------------------------------------------------
    def host_slice(self, host_id: int, n_hosts: int) -> tuple[int, int]:
        if self.global_batch % n_hosts:
            raise ValueError(
                f"global batch {self.global_batch} not divisible by "
                f"{n_hosts} hosts")
        per = self.global_batch // n_hosts
        return host_id * per, (host_id + 1) * per

    # checkpoint integration -------------------------------------------
    def state_dict(self) -> dict:
        return self.state.as_dict()

    def load_state_dict(self, d: dict) -> None:
        self.state = PipelineState.from_dict(d)
