"""Pallas TPU kernel: matmul straight out of an Iris-packed stream.

The legacy serving path is two passes — ``decode_layout_fused``
materializes dense codes/scales in HBM, then ``packed_matmul`` re-reads
them — paying the packed->dense expansion in memory traffic twice, which
is exactly the redundant transfer the paper's scheduled layout exists to
eliminate.  This kernel makes the decode part of the matmul *prologue*:
each grid tile gathers the packed words it needs from the stream buffer,
funnel-shifts codes and bf16 scale patterns out in registers,
dequantizes, and feeds the MXU.  HBM -> registers -> MXU, no dense
intermediate.

The extraction is table-driven: :class:`~repro.core.exec_plan.StreamTables`
holds one uint32 *global bit offset* per weight code / scale (u32-word
view of the stream, ``word = tab >> 5``, ``shift = tab & 31``).  Because
the table addresses bits, not lanes, any piece width <= 32 works — this
is what lifts ``packed_matmul``'s ``SUPPORTED_BITS=(2, 4, 8)``
restriction (int3 LM bundles become servable end-to-end).

Blocking mirrors ``packed_matmul`` exactly — grid (M/bm, N/bn, K/bk) with
K innermost and a VMEM f32 accumulator — so on shapes both kernels accept
the two paths perform the identical float ops in the identical order and
agree *bit-for-bit* (locked down by tests/test_stream_matmul.py).  Unlike
``packed_matmul``, ragged K and N are handled by zero-padding the offset
tables and masking the dequantized tile, so non-power-of-two layers need
no caller-side tiling gymnastics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.util import round_up as _round_up

try:  # pltpu is importable on CPU for scratch-shape declarations
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

#: lane width of the stream buffer's 2-D staging shape (VREG-aligned)
_STREAM_LANES = 128


def _extract(flat: jax.Array, tab: jax.Array, width: int) -> jax.Array:
    """Funnel-shift ``width``-bit fields out of ``flat`` u32 words.

    ``tab`` holds global bit offsets; an element straddles at most one
    word boundary (layout invariant: never a row boundary), so two reads
    suffice.  The ``min(wi + 1, last)`` clamp keeps the second read in
    bounds for non-straddling elements at the buffer end; its bits land
    above ``width`` and are masked off.
    """
    wi = (tab >> jnp.uint32(5)).astype(jnp.int32)
    sh = tab & jnp.uint32(31)
    last = flat.shape[0] - 1
    lo = jnp.take(flat, wi)
    hi = jnp.take(flat, jnp.minimum(wi + 1, last))
    v = lo >> sh
    # (32 - sh) & 31 is exact when sh > 0; sh == 0 contributes nothing
    hi_part = hi << ((jnp.uint32(32) - sh) & jnp.uint32(31))
    v = v | jnp.where(sh > 0, hi_part, jnp.uint32(0))
    mask = jnp.uint32((1 << width) - 1 if width < 32 else 0xFFFFFFFF)
    return v & mask


def _stream_matmul_kernel(x_ref, words_ref, wtab_ref, stab_ref, o_ref,
                          acc_ref, *, bits: int, group_size: int,
                          n_k_steps: int, k_true: int | None,
                          n_true: int | None) -> None:
    bias = float(1 << (bits - 1))

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    flat = words_ref[...].reshape(-1)
    wtab = wtab_ref[...]                       # (bk, bn) bit offsets
    bk, bn = wtab.shape
    codes = _extract(flat, wtab, bits)
    wq = codes.astype(jnp.float32) - bias      # symmetric biased codes
    spat = _extract(flat, stab_ref[...], 16)   # bf16 bit patterns
    scales = jax.lax.bitcast_convert_type(
        spat << jnp.uint32(16), jnp.float32)   # == bf16.astype(f32)
    wf = (wq.reshape(bk // group_size, group_size, bn)
          * scales[:, None, :]).reshape(bk, bn)
    # ragged K/N: padded table entries decode garbage (possibly NaN
    # scale patterns) — zero them so 0 * NaN never reaches the
    # accumulator.  Static None means no padding and keeps the unpadded
    # path bit-identical to packed_matmul.
    if k_true is not None or n_true is not None:
        valid = None
        if k_true is not None:
            krow = pl.program_id(2) * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bk, bn), 0)
            valid = krow < k_true
        if n_true is not None:
            ncol = pl.program_id(1) * bn + jax.lax.broadcasted_iota(
                jnp.int32, (bk, bn), 1)
            nv = ncol < n_true
            valid = nv if valid is None else valid & nv
        wf = jnp.where(valid, wf, 0.0)
    x = x_ref[...].astype(jnp.float32)         # (bm, bk)
    acc_ref[...] += jnp.dot(x, wf, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits", "group_size", "block_m", "block_n", "block_k", "interpret",
        "out_dtype",
    ),
)
def stream_matmul(x: jax.Array, stream_words: jax.Array, w_tab: jax.Array,
                  s_tab: jax.Array, *, bits: int, group_size: int,
                  block_m: int = 128, block_n: int = 128, block_k: int = 512,
                  out_dtype=jnp.float32, interpret: bool = True) -> jax.Array:
    """``x @ dequant(stream)`` gathering weights straight from the stream.

    x:            (M, K) float activations
    stream_words: uint32 packed stream, the flattened
                  :meth:`~repro.core.exec_plan.ExecProgram.buffer_words32`
                  view (any shape; flattened row-major)
    w_tab:        (K, N) uint32 global bit offsets of the weight codes
    s_tab:        (K // group_size, N) offsets of the bf16 scale patterns

    Any ``1 <= bits <= 32`` is supported; M, K and N may all be ragged.
    """
    if not 1 <= bits <= 32:
        raise ValueError(f"bits must be in [1, 32]; got {bits}")
    m, k = x.shape
    kt, n = w_tab.shape
    if kt != k:
        raise ValueError(f"w_tab K {kt} != activations K {k}")
    if k % group_size:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    if s_tab.shape != (k // group_size, n):
        raise ValueError(
            f"s_tab shape {s_tab.shape} != {(k // group_size, n)}")
    if stream_words.dtype != jnp.uint32:
        raise ValueError(f"stream must be uint32, got {stream_words.dtype}")
    if w_tab.dtype != jnp.uint32 or s_tab.dtype != jnp.uint32:
        raise ValueError("offset tables must be uint32")

    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = _round_up(min(block_k, k), group_size)
    m_pad = _round_up(m, block_m)
    n_pad = _round_up(n, block_n)
    k_pad = _round_up(k, block_k)
    g = group_size
    if m_pad != m or k_pad != k:
        x = jnp.pad(x, ((0, m_pad - m), (0, k_pad - k)))
    if k_pad != k or n_pad != n:
        w_tab = jnp.pad(w_tab, ((0, k_pad - k), (0, n_pad - n)))
        s_tab = jnp.pad(s_tab, ((0, (k_pad - k) // g), (0, n_pad - n)))

    # stage the stream as a VREG-aligned 2-D block; every grid step sees
    # the whole buffer (gathers are data-dependent on the tables)
    flat = stream_words.reshape(-1)
    s_len = _round_up(flat.shape[0], _STREAM_LANES * 8)
    if s_len != flat.shape[0]:
        flat = jnp.pad(flat, (0, s_len - flat.shape[0]))
    words2d = flat.reshape(s_len // _STREAM_LANES, _STREAM_LANES)

    n_k_steps = k_pad // block_k
    grid = (m_pad // block_m, n_pad // block_n, n_k_steps)
    kernel = functools.partial(
        _stream_matmul_kernel,
        bits=bits,
        group_size=group_size,
        n_k_steps=n_k_steps,
        k_true=k if k_pad != k else None,
        n_true=n if n_pad != n else None,
    )
    scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec(words2d.shape, lambda i, j, kk: (0, 0)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // g, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, words2d, w_tab, s_tab)
    return out[:m, :n] if (m_pad, n_pad) != (m, n) else out


def stream_words(program, buf_u8) -> jax.Array:
    """Packed ``(c_max, m/8)`` buffer -> flat uint32 device stream.

    One host-side conversion at load time; every subsequent
    :func:`stream_matmul` reads the same device array.
    """
    return jnp.asarray(program.buffer_words32(buf_u8).reshape(-1))

