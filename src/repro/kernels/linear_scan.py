"""Pallas TPU kernel: chunked linear-attention scan (SSD / scalar decay).

The §Perf iterD "next lever": the pure-JAX recurrence
(``models.linear_attention``) charges HBM for every mini-chunk state
round-trip; this kernel keeps the (dk, dv) state in a VMEM scratch across
the sequential T-grid, so per-chunk traffic is just the q/k/v tiles.

Math (per head; scalar per-token decay a_t = exp(logw_t) <= 1):

    S_t  = a_t S_{t-1} + k_t^T v_t
    o_t  = q_t S_t

Chunked closed form per C-token tile, with L = cumsum(logw) (L_t <= 0,
and L_t - L_i <= 0 for i <= t, so every exponential is <= 1 — stable):

    o      = (q * e^L) @ S_in  +  tril(q k^T * e^{L_t - L_i}) @ v
    S_out  = e^{L_C} S_in + (k * e^{L_C - L})^T @ v

Grid: (B*H, T/C) with T innermost — TPU grids iterate sequentially, so
the VMEM scratch legitimately carries S across T tiles of the same
(batch, head).  The per-channel-decay (RWKV) variant needs the
log-domain ratio trick with clamping and stays on the pure-JAX path.

Validated against ``models.linear_attention.recurrent_scan`` in
interpret mode (tests/test_linear_scan_kernel.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _ssd_kernel(q_ref, k_ref, v_ref, logw_ref, o_ref, state_ref, *,
                n_t_tiles: int) -> None:
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    logw = logw_ref[0].astype(jnp.float32)    # (C,)
    c = q.shape[0]

    el = jnp.cumsum(logw)                     # L_t, <= 0, nonincreasing
    s_in = state_ref[...]
    # inter-chunk: tokens see the carried state decayed to their position
    o_inter = (q * jnp.exp(el)[:, None]) @ s_in
    # intra-chunk: stable because L_t - L_i <= 0 on the kept triangle
    scores = q @ k.T                          # (C, C)
    ratio = jnp.exp(el[:, None] - el[None, :])
    mask = jnp.tril(jnp.ones((c, c), jnp.bool_))
    a = jnp.where(mask, scores * ratio, 0.0)
    o = o_inter + a @ v
    o_ref[0] = o.astype(o_ref.dtype)
    # carry the state to the next T tile
    w_suffix = jnp.exp(el[-1] - el)           # decay token i -> chunk end
    state_ref[...] = jnp.exp(el[-1]) * s_in + (k * w_suffix[:, None]).T @ v


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(q: jax.Array, k: jax.Array, v: jax.Array,
             logw: jax.Array, *, chunk: int = 128,
             interpret: bool = True) -> jax.Array:
    """q/k: (B, T, H, dk), v: (B, T, H, dv), logw: (B, T, H) (<= 0).

    Returns out (B, T, H, dv) — the scalar-decay linear-attention scan.
    Requires T % chunk == 0 (pad upstream).
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    if t % chunk:
        raise ValueError(f"T={t} not divisible by chunk={chunk}")
    # (B*H, T, d) layout so the grid is (BH, T/C) with T innermost
    def to_bh(a):
        return a.transpose(0, 2, 1, 3).reshape(b * h, t, a.shape[-1])
    qb, kb, vb = to_bh(q), to_bh(k), to_bh(v)
    wb = logw.transpose(0, 2, 1).reshape(b * h, t)

    grid = (b * h, t // chunk)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, n_t_tiles=t // chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qb, kb, vb, wb)
    return out.reshape(b, h, t, dv).transpose(0, 2, 1, 3)
