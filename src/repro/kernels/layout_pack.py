"""Pallas TPU kernels: pack per-array streams into an Iris bus buffer.

The inverse of :mod:`repro.kernels.layout_decode`: where the fused decode
funnel-shifts every (row, lane) slot *out* of the packed words, the fused
pack ORs every destination word together *from* its contributing pieces.
The host pack (:meth:`~repro.core.exec_plan.ExecProgram.pack_indexed`)
is a scatter — piece order -> word order — which the XLA CPU backend
executes pathologically (serialized scatter updates).  The device kernel
therefore runs the precomputed gather-only inverse
(:func:`~repro.core.exec_plan.pack_kernel_tables`): per destination u32
word, <= K static (source piece, shift) contributions; the kernel gathers
the flat piece stream through the ``src`` table, shifts by ``scode``
(negative = the hi part of a word-straddling piece, shifted right), and
OR-reduces the K rank layers.  No scatter, no inter-lane dependency —
every grid step is a dense VREG-shaped gather + shift + OR.

The jitted closure is memoized on the
:class:`~repro.core.exec_plan.ExecProgram` (``jit_cache``), so one trace
serves every pack of a layout signature, including across
:class:`~repro.core.iris.LayoutCache` rebinds.  Arrays whose piece width
exceeds ``KERNEL_MAX_WIDTH`` (32) are packed by the vectorized numpy host
path with the kernel arrays zeroed and OR-merged into the same buffer —
bit regions are disjoint by construction, so the merge is exact.

Bit conventions match ``core.codegen``: little-endian u32 bus words; an
element's LSB sits at its bit offset and may straddle one u32 boundary
(never a row boundary).
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.exec_plan import (
    ExecProgram,
    lower_exec,
    pack_kernel_tables,
)
from repro.core.layout import Layout
from repro.core.util import round_up as _round_up

from .layout_decode import HostFallbackWarning

# Rows of the packed buffer produced per grid step.  The pack kernel
# reads the *entire* flat piece stream each step (the src table may pull
# any piece into any row tile), so unlike decode the per-step cost has a
# large stream-sized component; big tiles amortize it.  On the interpret
# path each grid step also costs ~0.5ms of fixed overhead — another
# reason to prefer few, large steps.
DEFAULT_TILE_ROWS = 4096

#: (layout signature, array name) pairs already warned about; serving
#: loops pack the same signature repeatedly, so warn once per pair.
_FALLBACK_WARNED: set[tuple] = set()


def reset_host_fallback_warnings() -> None:
    """Forget which (layout, array) host fallbacks have been warned about."""
    _FALLBACK_WARNED.clear()


# ----------------------------------------------------------------------
# fused whole-buffer pack (one pallas_call)
# ----------------------------------------------------------------------
def _pack_fused_kernel(flat_ref, src_ref, sl_ref, sr_ref, neg_ref,
                       out_ref) -> None:
    """OR-assemble a row tile of packed u32 words from the piece stream.

    flat_ref: (n_flat,)          uint32 — piece stream, sentinel 0 at [0].
    src_ref:  (tile, words32*K)  int32  — flat indices (0 = empty slot).
    sl_ref/sr_ref: (tile, words32*K) int32 — left/right shift amounts.
    neg_ref:  (tile, words32*K)  int32  — 1 where the shift is right.
    out_ref:  (tile, words32)    uint32 — packed bus rows.
    """
    flat = flat_ref[...]
    v = jnp.take(flat, src_ref[...])
    c = jnp.where(neg_ref[...] != 0,
                  v >> sr_ref[...].astype(jnp.uint32),
                  v << sl_ref[...].astype(jnp.uint32))
    rows = out_ref.shape[0]
    w32 = out_ref.shape[1]
    k = c.shape[1] // w32
    w = c.reshape(rows, w32, k)
    acc = w[:, :, 0]
    for j in range(1, k):
        acc = acc | w[:, :, j]
    out_ref[...] = acc


def _fused_pack_fn(prog: ExecProgram, tile_rows: int, interpret: bool):
    """Jitted (flat piece stream -> words32 buffer) closure, memoized
    per program.

    Tables are baked in as constants: the trace happens once per (layout
    signature, piece widths, tile, interpret) and is shared across
    LayoutCache rebinds via the program's ``jit_cache``.
    """
    key = ("pack", tile_rows, interpret)
    fn = prog.jit_cache.get(key)
    if fn is not None:
        return fn
    src_t, sc_t, k = pack_kernel_tables(prog)
    w32 = prog.kernel.words32
    tile = min(tile_rows, _round_up(prog.c_max, 8))
    padded = _round_up(prog.c_max, tile)

    def _pad(a: np.ndarray) -> jax.Array:
        out = np.zeros((padded, a.shape[1]), dtype=a.dtype)
        out[:prog.c_max] = a
        return jnp.asarray(out)

    src_j = _pad(src_t)
    sl_j = _pad(np.clip(sc_t, 0, 31).astype(np.int32))
    sr_j = _pad(np.clip(-sc_t, 0, 31).astype(np.int32))
    neg_j = _pad((sc_t < 0).astype(np.int32))
    n_flat = prog.n_pieces + 1
    cols = w32 * k

    @jax.jit
    def run(flat: jax.Array) -> jax.Array:
        out = pl.pallas_call(
            _pack_fused_kernel,
            grid=(padded // tile,),
            in_specs=[
                pl.BlockSpec((n_flat,), lambda i: (0,)),
                pl.BlockSpec((tile, cols), lambda i: (i, 0)),
                pl.BlockSpec((tile, cols), lambda i: (i, 0)),
                pl.BlockSpec((tile, cols), lambda i: (i, 0)),
                pl.BlockSpec((tile, cols), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((tile, w32), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((padded, w32), jnp.uint32),
            interpret=interpret,
        )(flat, src_j, sl_j, sr_j, neg_j)
        return out[:prog.c_max]

    prog.jit_cache[key] = run
    return run


def _check_stream(name: str, a, depth: int, ew: int) -> np.ndarray:
    arr = np.asarray(a).reshape(-1)
    if arr.dtype != np.uint64:
        arr = arr.astype(np.uint64)
    if arr.shape[0] != depth:
        raise ValueError(
            f"{name}: expected {depth} elements, got {arr.shape[0]}")
    if ew < 64 and (arr >> np.uint64(ew)).any():
        raise ValueError(f"{name}: codes overflow {ew} bits")
    return arr


def pack_layout_fused(layout: Layout, arrays: dict, *,
                      program: ExecProgram | None = None,
                      elem_widths: tuple[int, ...] | None = None,
                      tile_rows: int = DEFAULT_TILE_ROWS,
                      interpret: bool = True) -> np.ndarray:
    """Pack per-array piece streams with a single ``pallas_call``.

    Bit-identical to :func:`~repro.core.exec_plan.pack_compiled`: returns
    the same ``(c_max, m/8)`` uint8 buffer.  Pieces up to 32 bits wide go
    through the fused kernel; wider arrays are packed by the numpy host
    path (kernel arrays zeroed) and OR-merged — their bit regions are
    disjoint, so the merge is exact.
    """
    prog = program if program is not None \
        else lower_exec(layout, elem_widths)
    specs = layout.problem.arrays
    names = [a.name for a in specs]
    for name in names:
        if name not in arrays:
            raise KeyError(f"missing array {name!r}")
    streams = [
        _check_stream(names[i], arrays[names[i]],
                      prog.piece_depths[i], prog.elem_widths[i])
        for i in range(len(specs))]

    out32: np.ndarray | None = None
    if prog.kernel.gathers:
        flat = np.zeros(prog.n_pieces + 1, dtype=np.uint32)
        for i, _g in prog.kernel.gathers:
            flat[1 + prog.piece_base[i]:1 + prog.piece_base[i + 1]] = \
                streams[i].astype(np.uint32)
        run = _fused_pack_fn(prog, tile_rows, interpret)
        out32 = np.asarray(jax.block_until_ready(run(jnp.asarray(flat))))

    if prog.host_arrays:
        sig = layout.problem.canonical_signature()
        fresh = tuple(
            (names[i], prog.elem_widths[i]) for i in prog.host_arrays
            if (sig, names[i]) not in _FALLBACK_WARNED)
        if fresh:
            _FALLBACK_WARNED.update((sig, n) for n, _w in fresh)
            warnings.warn(HostFallbackWarning(fresh), stacklevel=2)
        host_set = set(prog.host_arrays)
        host_data = [
            s if i in host_set else np.zeros_like(s)
            for i, s in enumerate(streams)]
        host_buf = prog.pack_indexed(host_data)
        host32 = prog.buffer_words32(host_buf)
        out32 = host32 if out32 is None else out32 | host32

    if out32 is None:               # degenerate: a problem with no arrays
        out32 = np.zeros((prog.c_max, prog.kernel.words32), dtype=np.uint32)
    return np.ascontiguousarray(out32).view(np.uint8).reshape(
        prog.c_max, prog.kernel.words32 * 4)[:, :prog.row_bytes]
