"""Pallas TPU kernels: decode an Iris-packed bus buffer into per-array streams.

This is the accelerator-side read module of the paper (Listing 2), adapted
to the TPU memory hierarchy.  Two generations live here:

* :func:`decode_layout_fused` — **one** ``pallas_call`` for the whole
  buffer.  The HLS ``for (t) #pragma HLS pipeline II=1`` loop over bus
  words becomes a single Pallas grid over row tiles; the per-cycle
  ``elem.range(hi, lo)`` arms become a static slot table
  (:class:`~repro.core.exec_plan.KernelTable`): per (row, lane) one
  uint32 encoding ``bit_offset | width << 20``.  Each grid step funnel-
  shifts every lane of its tile out of the packed words (dynamic per-lane
  word gather + shift), writing a row-major ``(rows, lanes)`` uint32
  grid; static per-array gathers then rearrange the grid into element
  streams.  The whole decode jit-traces once per layout signature (the
  trace is memoized on the :class:`~repro.core.exec_plan.ExecProgram`,
  which the layout cache shares across rebinds).  Arrays whose piece
  width exceeds 32 bits are decoded by the vectorized host path and
  merged into the same output dict.
* :func:`decode_slot` — the legacy per-(interval, slot) decode unit, one
  ``pallas_call`` per unit.  Kept as the reference oracle
  (``ops.decode_layout(..., fused=False)``) and for property tests.

Bit conventions match ``core.codegen``: bus rows are little-endian u32
words; an element's LSB sits at ``bit_offset`` and may straddle one word
boundary (never a row boundary) — a two-word funnel shift recovers it.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.exec_plan import _TAB_WIDTH_SHIFT, ExecProgram, lower_exec
from repro.core.layout import Layout
from repro.core.util import round_up as _round_up


class HostFallbackWarning(UserWarning):
    """Fused decode silently routed some arrays to the numpy host path.

    Raised (as a warning) when piece widths exceed ``KERNEL_MAX_WIDTH``:
    those arrays never touch the Pallas kernel, so the decode is not the
    single-launch accelerator pass the caller likely expects.  Carries
    the offending ``(name, width)`` pairs on :attr:`arrays`.  Stream-
    direct matmul avoids this entirely by lowering bundles at element
    granularity (every element width <= 32).
    """

    def __init__(self, arrays: tuple[tuple[str, int], ...]):
        self.arrays = arrays
        detail = ", ".join(f"{n} ({w}b)" for n, w in arrays)
        super().__init__(
            f"decode_layout_fused: {len(arrays)} array(s) exceed the "
            f"32-bit kernel piece width and fell back to the host "
            f"path: {detail}. Lower at element granularity "
            "(elem_widths) to keep the decode on-device."
        )

# Rows of the packed buffer processed per grid step.  8 sublanes x 128
# lanes is the native f32/u32 VREG tile; 256 rows keeps the input block
# (256, words) comfortably under VMEM while amortizing control overhead.
DEFAULT_TILE_ROWS = 256

#: (layout signature, array name) pairs already warned about — serving
#: loops decode the same layout thousands of times per second, so the
#: fallback warning fires once per distinct (layout, array), not per call
_FALLBACK_WARNED: set[tuple] = set()


def reset_host_fallback_warnings() -> None:
    """Forget which (layout, array) host fallbacks have been warned about."""
    _FALLBACK_WARNED.clear()


# ----------------------------------------------------------------------
# fused whole-buffer decode (one pallas_call)
# ----------------------------------------------------------------------
def _decode_fused_kernel(words_ref, tab_ref, out_ref) -> None:
    """Decode every lane of a row tile against its static slot table.

    words_ref: (tile, words32) uint32 — packed bus rows.
    tab_ref:   (tile, lanes)   uint32 — ``bit_offset | width << 20``.
    out_ref:   (tile, lanes)   uint32 — decoded piece per (row, lane).
    """
    x = words_ref[...]
    tab = tab_ref[...]
    off = tab & jnp.uint32((1 << _TAB_WIDTH_SHIFT) - 1)
    width = tab >> _TAB_WIDTH_SHIFT
    w0 = (off >> 5).astype(jnp.int32)
    sh = off & jnp.uint32(31)
    last = x.shape[1] - 1
    lo = jnp.take_along_axis(x, w0, axis=1)
    hi = jnp.take_along_axis(x, jnp.minimum(w0 + 1, last), axis=1)
    v = lo >> sh
    # funnel in the straddling word; (32 - sh) & 31 is exact when sh > 0
    hi_part = hi << ((jnp.uint32(32) - sh) & jnp.uint32(31))
    v = v | jnp.where(sh > 0, hi_part, jnp.uint32(0))
    # width == 0 marks an empty lane; width == 32 keeps every bit
    mask = jnp.where(
        width == 0,
        jnp.uint32(0),
        jnp.uint32(0xFFFFFFFF) >> ((jnp.uint32(32) - width) & jnp.uint32(31)),
    )
    out_ref[...] = v & mask


def _fused_grid_fn(prog: ExecProgram, tile_rows: int, interpret: bool):
    """Jitted (words32 -> per-array streams) closure, memoized per program.

    The slot table and gather indices are baked in as constants, so the
    trace happens once per (layout signature, piece widths) — repeated
    decodes, including across LayoutCache rebinds, reuse it.
    """
    key = ("fused", tile_rows, interpret)
    fn = prog.jit_cache.get(key)
    if fn is not None:
        return fn
    kt = prog.kernel
    tile = min(tile_rows, _round_up(prog.c_max, 8))
    padded = _round_up(prog.c_max, tile)
    tab = np.zeros((padded, kt.lanes), dtype=np.uint32)
    tab[:prog.c_max] = kt.tab
    tab_j = jnp.asarray(tab)
    gathers = [(i, jnp.asarray(g)) for i, g in kt.gathers]

    @jax.jit
    def run(words: jax.Array) -> dict[int, jax.Array]:
        if padded != prog.c_max:
            words = jnp.pad(words, ((0, padded - prog.c_max), (0, 0)))
        grid = pl.pallas_call(
            _decode_fused_kernel,
            grid=(padded // tile,),
            in_specs=[
                pl.BlockSpec((tile, kt.words32), lambda i: (i, 0)),
                pl.BlockSpec((tile, kt.lanes), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((tile, kt.lanes), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((padded, kt.lanes), jnp.uint32),
            interpret=interpret,
        )(words, tab_j)
        flat = grid.reshape(-1)
        return {i: jnp.take(flat, g) for i, g in gathers}

    prog.jit_cache[key] = run
    return run


def decode_layout_fused(layout: Layout, buf_u8, *,
                        program: ExecProgram | None = None,
                        elem_widths: tuple[int, ...] | None = None,
                        tile_rows: int = DEFAULT_TILE_ROWS,
                        interpret: bool = True) -> dict[str, jax.Array]:
    """Decode the whole packed buffer with a single ``pallas_call``.

    Pieces up to 32 bits wide go through the fused kernel; wider arrays
    are decoded by the vectorized numpy host path
    (:meth:`ExecProgram.unpack_array`) and merged into the result, so
    mixed-width bundles decode end-to-end.
    """
    prog = program if program is not None \
        else lower_exec(layout, elem_widths)
    names = [a.name for a in layout.problem.arrays]
    buf = np.asarray(buf_u8, dtype=np.uint8)
    outs: dict[str, jax.Array] = {}
    if prog.kernel.gathers:
        words = jnp.asarray(prog.buffer_words32(buf))
        kern = _fused_grid_fn(prog, tile_rows, interpret)(words)
        for i, v in kern.items():
            outs[names[i]] = v
    if prog.host_arrays:
        sig = layout.problem.canonical_signature()
        fresh = tuple(
            (names[i], prog.elem_widths[i]) for i in prog.host_arrays
            if (sig, names[i]) not in _FALLBACK_WARNED)
        if fresh:
            _FALLBACK_WARNED.update((sig, n) for n, _w in fresh)
            warnings.warn(HostFallbackWarning(fresh), stacklevel=2)
        flat = prog.buffer_words64(buf)
        for i in prog.host_arrays:
            # stays numpy uint64: jnp would truncate to 32 bits under the
            # default x64-disabled config
            outs[names[i]] = prog.unpack_array(flat, i)
    return outs


# ----------------------------------------------------------------------
# legacy per-(interval, slot) decode unit — the reference oracle
# ----------------------------------------------------------------------
def _decode_slot_kernel(in_ref, out_ref, *, offsets: tuple[int, ...],
                        width: int) -> None:
    """Unpack ``len(offsets)`` fixed-position lanes from each bus row.

    in_ref:  (tile, words) uint32 — packed bus rows.
    out_ref: (tile, lanes) uint32 — one decoded element per lane per row.
    """
    x = in_ref[...]
    mask = jnp.uint32((1 << width) - 1 if width < 32 else 0xFFFFFFFF)
    cols = []
    for off in offsets:
        w0, sh = off // 32, off % 32
        v = x[:, w0]
        if sh:
            v = v >> jnp.uint32(sh)
            if sh + width > 32:
                v = v | (x[:, w0 + 1] << jnp.uint32(32 - sh))
        cols.append(v & mask)
    out_ref[...] = jnp.stack(cols, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("offsets", "width", "n_rows", "tile_rows", "interpret"),
)
def decode_slot(rows_u32: jax.Array, *, offsets: tuple[int, ...], width: int,
                n_rows: int, tile_rows: int = DEFAULT_TILE_ROWS,
                interpret: bool = True) -> jax.Array:
    """Decode one (interval, slot) unit: ``n_rows`` bus rows -> codes.

    ``rows_u32`` is the (n_rows, words) u32 slab of the interval.  Returns
    (n_rows * lanes,) uint32 element codes in stream order.
    """
    lanes = len(offsets)
    words = rows_u32.shape[1]
    tile = min(tile_rows, _round_up(n_rows, 8))
    padded = _round_up(n_rows, tile)
    if padded != n_rows:
        rows_u32 = jnp.pad(rows_u32, ((0, padded - n_rows), (0, 0)))
    grid = (padded // tile,)
    out = pl.pallas_call(
        functools.partial(_decode_slot_kernel, offsets=offsets, width=width),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, words), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, lanes), jnp.uint32),
        interpret=interpret,
    )(rows_u32)
    return out[:n_rows].reshape(n_rows * lanes)
