"""Pallas TPU kernel: decode an Iris-packed bus buffer into per-array streams.

This is the accelerator-side read module of the paper (Listing 2), adapted
to the TPU memory hierarchy:

* the HLS ``for (t) #pragma HLS pipeline II=1`` loop over bus words becomes
  a Pallas grid over row tiles of the packed buffer — BlockSpec pipelining
  gives the same effect as II=1: the next tile's HBM->VMEM DMA overlaps the
  current tile's unpack (double buffering);
* the per-cycle ``elem.range(hi, lo)`` bit-slices become static funnel
  shifts over VREG lanes (offsets are compile-time constants per layout
  interval, exactly like the generated HLS code);
* the per-array output streams become contiguous VMEM tiles written back
  to HBM.

One ``pallas_call`` is emitted per (interval, slot) decode unit — the
direct analogue of the unrolled ``if (t == ...)`` arms in Listing 2.  All
shapes are static; the enclosing ``ops.decode_layout`` stitches results
into per-array outputs with static slices, so the whole program jits.

Bit conventions match ``core.codegen``: bus rows are little-endian u32
words; an element's LSB sits at ``bit_offset`` and may straddle one word
boundary (never a row boundary) — a two-word funnel shift recovers it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of the packed buffer processed per grid step.  8 sublanes x 128
# lanes is the native f32/u32 VREG tile; 256 rows keeps the input block
# (256, words) comfortably under VMEM while amortizing control overhead.
DEFAULT_TILE_ROWS = 256


def _decode_slot_kernel(in_ref, out_ref, *, offsets: tuple[int, ...],
                        width: int) -> None:
    """Unpack ``len(offsets)`` fixed-position lanes from each bus row.

    in_ref:  (tile, words) uint32 — packed bus rows.
    out_ref: (tile, lanes) uint32 — one decoded element per lane per row.
    """
    x = in_ref[...]
    mask = jnp.uint32((1 << width) - 1 if width < 32 else 0xFFFFFFFF)
    cols = []
    for off in offsets:
        w0, sh = off // 32, off % 32
        v = x[:, w0]
        if sh:
            v = v >> jnp.uint32(sh)
            if sh + width > 32:
                v = v | (x[:, w0 + 1] << jnp.uint32(32 - sh))
        cols.append(v & mask)
    out_ref[...] = jnp.stack(cols, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("offsets", "width", "n_rows", "tile_rows", "interpret"),
)
def decode_slot(rows_u32: jax.Array, *, offsets: tuple[int, ...], width: int,
                n_rows: int, tile_rows: int = DEFAULT_TILE_ROWS,
                interpret: bool = True) -> jax.Array:
    """Decode one (interval, slot) unit: ``n_rows`` bus rows -> codes.

    ``rows_u32`` is the (n_rows, words) u32 slab of the interval.  Returns
    (n_rows * lanes,) uint32 element codes in stream order.
    """
    lanes = len(offsets)
    words = rows_u32.shape[1]
    tile = min(tile_rows, _round_up(n_rows, 8))
    padded = _round_up(n_rows, tile)
    if padded != n_rows:
        rows_u32 = jnp.pad(rows_u32, ((0, padded - n_rows), (0, 0)))
    grid = (padded // tile,)
    out = pl.pallas_call(
        functools.partial(_decode_slot_kernel, offsets=offsets, width=width),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, words), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, lanes), jnp.uint32),
        interpret=interpret,
    )(rows_u32)
    return out[:n_rows].reshape(n_rows * lanes)


def _round_up(x: int, to: int) -> int:
    return -(-x // to) * to
