"""Public jit'd entry points for the kernels package.

``decode_layout`` runs the full accelerator-side read module: it walks the
static :class:`~repro.core.codegen.DecodePlan` and emits one Pallas decode
unit per (interval, slot), stitching results into per-array code streams —
the whole program is static and jits into a single XLA computation (the
TPU analogue of the paper's single HLS read_data module).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import DecodePlan, decode_plan
from repro.core.layout import Layout

from .layout_decode import decode_slot
from .packed_matmul import packed_matmul  # noqa: F401  (re-export)


def buffer_to_u32(buf_u8: np.ndarray | jax.Array) -> jax.Array:
    """(c_max, m/8) uint8 rows -> (c_max, m/32 + 2) uint32 words.

    Two trailing spare words per row so a funnel shift at the last element
    never reads out of bounds (mirrors the packer's spare bytes).
    """
    buf = jnp.asarray(buf_u8, dtype=jnp.uint8)
    c, row_bytes = buf.shape
    # pad each row to a u32 boundary plus two spare words
    pad = (-row_bytes) % 4 + 8
    buf = jnp.pad(buf, ((0, 0), (0, pad)))
    words = buf.reshape(c, (row_bytes + pad) // 4, 4).astype(jnp.uint32)
    shifts = jnp.array([0, 8, 16, 24], dtype=jnp.uint32)
    return jnp.sum(words << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def decode_layout(layout: Layout, buf_u8: np.ndarray | jax.Array, *,
                  interpret: bool = True,
                  plan: DecodePlan | None = None) -> dict[str, jax.Array]:
    """Decode an Iris-packed buffer into per-array uint32 code streams."""
    plan = plan if plan is not None else decode_plan(layout)
    words = buffer_to_u32(buf_u8)
    outs = {
        a.name: jnp.zeros(a.depth, dtype=jnp.uint32)
        for a in layout.problem.arrays
    }
    for slot in plan.slots:
        if slot.width > 32:
            raise NotImplementedError(
                f"{slot.name}: widths > 32 use the numpy host path"
            )
        rows = jax.lax.slice(
            words, (slot.start_cycle, 0),
            (slot.start_cycle + slot.n_cycles, words.shape[1]),
        )
        offsets = tuple(
            slot.bit_offset + k * slot.width for k in range(slot.lanes)
        )
        codes = decode_slot(
            rows,
            offsets=offsets,
            width=slot.width,
            n_rows=slot.n_cycles,
            interpret=interpret,
        )
        outs[slot.name] = jax.lax.dynamic_update_slice(
            outs[slot.name], codes, (slot.elem_base,)
        )
    return outs
