"""Public jit'd entry points for the kernels package.

``decode_layout`` runs the accelerator-side read module.  The default
(``fused=True``) path executes the compiled
:class:`~repro.core.exec_plan.ExecProgram`: one Pallas kernel gridded
over row tiles decodes the whole buffer against a static slot table —
the TPU analogue of the paper's single HLS ``read_data`` module, one
``pallas_call`` and one jit trace per layout signature.

``fused=False`` keeps the legacy per-(interval, slot) program — one
``pallas_call`` plus one ``dynamic_update_slice`` per decode unit — as
the reference oracle.  In both paths, slots whose element width exceeds
32 bits are decoded by the vectorized numpy host path
(``core.exec_plan`` / ``core.codegen``) instead of raising, so
mixed-width bundles decode end-to-end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import DecodePlan, _gather_bits, decode_plan
from repro.core.exec_plan import ExecProgram
from repro.core.layout import Layout

from .layout_decode import (  # noqa: F401  (HostFallbackWarning re-export)
    HostFallbackWarning,
    decode_layout_fused,
    decode_slot,
    reset_host_fallback_warnings,
)
from .layout_pack import pack_layout_fused  # noqa: F401  (re-export)
from .packed_matmul import packed_matmul  # noqa: F401  (re-export)
from .stream_matmul import (  # noqa: F401  (re-exports)
    stream_matmul,
    stream_words,
)


def buffer_to_u32(buf_u8: np.ndarray | jax.Array) -> jax.Array:
    """(c_max, m/8) uint8 rows -> (c_max, m/32 + 2) uint32 words.

    Two trailing spare words per row so a funnel shift at the last element
    never reads out of bounds (mirrors the packer's spare bytes).
    """
    buf = jnp.asarray(buf_u8, dtype=jnp.uint8)
    c, row_bytes = buf.shape
    # pad each row to a u32 boundary plus two spare words
    pad = (-row_bytes) % 4 + 8
    buf = jnp.pad(buf, ((0, 0), (0, pad)))
    words = buf.reshape(c, (row_bytes + pad) // 4, 4).astype(jnp.uint32)
    shifts = jnp.array([0, 8, 16, 24], dtype=jnp.uint32)
    return jnp.sum(words << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def decode_layout(layout: Layout, buf_u8: np.ndarray | jax.Array, *,
                  interpret: bool = True,
                  plan: DecodePlan | None = None,
                  fused: bool | None = None,
                  program: ExecProgram | None = None,
                  ) -> dict[str, jax.Array]:
    """Decode an Iris-packed buffer into per-array code streams.

    ``fused=None`` (default) resolves to the fused single-kernel path
    unless a legacy per-slot ``plan`` is supplied — a caller handing in
    a precomputed :class:`DecodePlan` gets the path that consumes it.
    Passing both ``fused=True`` and ``plan`` is a contradiction and
    raises.
    """
    if fused and plan is not None:
        raise ValueError(
            "plan= belongs to the per-slot path; pass program= (or "
            "nothing) for the fused path"
        )
    if fused is None:
        fused = plan is None
    if fused:
        return decode_layout_fused(layout, buf_u8, program=program,
                                   interpret=interpret)
    plan = plan if plan is not None else decode_plan(layout)
    words = buffer_to_u32(buf_u8)
    wide = [s for s in plan.slots if s.width > 32]
    outs = {
        a.name: jnp.zeros(a.depth, dtype=jnp.uint32)
        for a in layout.problem.arrays
        if a.width <= 32
    }
    for slot in plan.slots:
        if slot.width > 32:
            continue                    # host path below
        rows = jax.lax.slice(
            words, (slot.start_cycle, 0),
            (slot.start_cycle + slot.n_cycles, words.shape[1]),
        )
        offsets = tuple(
            slot.bit_offset + k * slot.width for k in range(slot.lanes)
        )
        codes = decode_slot(
            rows,
            offsets=offsets,
            width=slot.width,
            n_rows=slot.n_cycles,
            interpret=interpret,
        )
        outs[slot.name] = jax.lax.dynamic_update_slice(
            outs[slot.name], codes, (slot.elem_base,)
        )
    if wide:
        outs.update(_decode_wide_slots_host(layout, buf_u8, wide))
    return outs


def _decode_wide_slots_host(layout: Layout, buf_u8, wide) -> dict:
    """Numpy bit-gather for slots whose width exceeds the u32 kernel path."""
    prob = layout.problem
    row_bytes = prob.m // 8
    buf = np.asarray(buf_u8, dtype=np.uint8)
    padded = np.zeros((layout.c_max, row_bytes + 9), dtype=np.uint8)
    padded[:, :row_bytes] = buf
    outs: dict[str, np.ndarray] = {}
    for slot in wide:
        out = outs.setdefault(
            slot.name,
            np.zeros(prob.arrays[slot.array].depth, dtype=np.uint64))
        rows = padded[slot.start_cycle:slot.start_cycle + slot.n_cycles]
        vals = np.empty((slot.n_cycles, slot.lanes), dtype=np.uint64)
        for k in range(slot.lanes):
            vals[:, k] = _gather_bits(
                rows, slot.bit_offset + k * slot.width, slot.width)
        n = slot.lanes * slot.n_cycles
        out[slot.elem_base:slot.elem_base + n] = vals.reshape(-1)
    # stays numpy uint64: jnp would truncate to 32 bits under the default
    # x64-disabled config
    return outs
