"""Pure-jnp / numpy oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import unpack_arrays
from repro.core.layout import Layout


def decode_layout_ref(layout: Layout, buf_u8: np.ndarray) -> dict[str, np.ndarray]:
    """Oracle for ``ops.decode_layout``: the numpy bit-gatherer."""
    return unpack_arrays(layout, np.asarray(buf_u8))


def decode_slot_ref(rows_u32: np.ndarray, offsets: tuple[int, ...],
                    width: int, n_rows: int) -> np.ndarray:
    """Oracle for ``layout_decode.decode_slot`` (vectorized numpy)."""
    rows = np.asarray(rows_u32[:n_rows], dtype=np.uint64)
    mask = np.uint64((1 << width) - 1)
    cols = []
    for off in offsets:
        w0, sh = off // 32, off % 32
        v = rows[:, w0] >> np.uint64(sh)
        if sh and sh + width > 32:
            v = v | (rows[:, w0 + 1] << np.uint64(32 - sh))
        cols.append(v & mask)
    return np.stack(cols, axis=1).reshape(-1).astype(np.uint32)


def _extract_ref(flat_u32: np.ndarray, tab: np.ndarray,
                 width: int) -> np.ndarray:
    """Numpy twin of ``stream_matmul._extract`` (u64 funnel shift)."""
    flat = np.asarray(flat_u32).reshape(-1).astype(np.uint64)
    wi = (tab >> 5).astype(np.int64)
    sh = (tab & np.uint32(31)).astype(np.uint64)
    lo = flat[wi] >> sh
    hi = flat[np.minimum(wi + 1, flat.size - 1)] \
        << ((np.uint64(32) - sh) & np.uint64(63))
    v = np.where(sh > 0, lo | hi, lo)
    mask = np.uint64((1 << width) - 1)
    return (v & mask).astype(np.uint32)


def stream_matmul_ref(x: jax.Array, stream_words, w_tab, s_tab, *,
                      bits: int, group_size: int, block_k: int = 512,
                      out_dtype=jnp.float32) -> jax.Array:
    """Oracle for ``stream_matmul``: host-side table decode, then a dot
    accumulated in the kernel's K-block order (padded K rows are zeros in
    the kernel's tile, and adding 0.0 is exact, so per-element float
    reductions match term for term — exact equality holds for any bits,
    including the widths ``packed_matmul`` cannot lane-pack)."""
    w_tab = np.asarray(w_tab)
    s_tab = np.asarray(s_tab)
    k, n = w_tab.shape
    codes = _extract_ref(stream_words, w_tab, bits)
    spat = _extract_ref(stream_words, s_tab, 16)
    wq = codes.astype(np.float32) - float(1 << (bits - 1))
    scales = (spat.astype(np.uint32) << 16).view(np.float32)
    wf = (wq.reshape(k // group_size, group_size, n)
          * scales[:, None, :]).reshape(k, n)
    bk = min(block_k, k)
    bk = -(-bk // group_size) * group_size
    xf = jnp.asarray(x).astype(jnp.float32)
    wfj = jnp.asarray(wf)
    acc = jnp.zeros((x.shape[0], n), jnp.float32)
    for kk in range(0, k, bk):
        acc = acc + jnp.dot(xf[:, kk:kk + bk], wfj[kk:kk + bk],
                            preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


def packed_matmul_ref(x: jax.Array, w_packed: jax.Array, scales: jax.Array,
                      *, bits: int, group_size: int) -> jax.Array:
    """Oracle for ``packed_matmul``: unpack everything, then one big dot."""
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    bias = float(1 << (bits - 1))
    kw, n = w_packed.shape
    k = kw * lanes
    planes = [
        ((w_packed >> jnp.uint32(ln * bits)) & mask) for ln in range(lanes)
    ]
    codes = jnp.stack(planes, axis=1).reshape(k, n)
    wq = codes.astype(jnp.float32) - bias
    wf = (wq.reshape(k // group_size, group_size, n)
          * scales.astype(jnp.float32)[:, None, :]).reshape(k, n)
    return jnp.dot(x.astype(jnp.float32), wf,
                   preferred_element_type=jnp.float32)
