"""Pure-jnp / numpy oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import unpack_arrays
from repro.core.layout import Layout


def decode_layout_ref(layout: Layout, buf_u8: np.ndarray) -> dict[str, np.ndarray]:
    """Oracle for ``ops.decode_layout``: the numpy bit-gatherer."""
    return unpack_arrays(layout, np.asarray(buf_u8))


def decode_slot_ref(rows_u32: np.ndarray, offsets: tuple[int, ...],
                    width: int, n_rows: int) -> np.ndarray:
    """Oracle for ``layout_decode.decode_slot`` (vectorized numpy)."""
    rows = np.asarray(rows_u32[:n_rows], dtype=np.uint64)
    mask = np.uint64((1 << width) - 1)
    cols = []
    for off in offsets:
        w0, sh = off // 32, off % 32
        v = rows[:, w0] >> np.uint64(sh)
        if sh and sh + width > 32:
            v = v | (rows[:, w0 + 1] << np.uint64(32 - sh))
        cols.append(v & mask)
    return np.stack(cols, axis=1).reshape(-1).astype(np.uint32)


def _extract_ref(flat_u32: np.ndarray, tab: np.ndarray,
                 width: int) -> np.ndarray:
    """Numpy twin of ``stream_matmul._extract`` (u64 funnel shift)."""
    flat = np.asarray(flat_u32).reshape(-1).astype(np.uint64)
    wi = (tab >> 5).astype(np.int64)
    sh = (tab & np.uint32(31)).astype(np.uint64)
    lo = flat[wi] >> sh
    hi = flat[np.minimum(wi + 1, flat.size - 1)] \
        << ((np.uint64(32) - sh) & np.uint64(63))
    v = np.where(sh > 0, lo | hi, lo)
    mask = np.uint64((1 << width) - 1)
    return (v & mask).astype(np.uint32)


def stream_matmul_ref(x: jax.Array, stream_words, w_tab, s_tab, *,
                      bits: int, group_size: int, block_k: int = 512,
                      out_dtype=jnp.float32) -> jax.Array:
    """Oracle for ``stream_matmul``: host-side table decode, then a dot
    accumulated in the kernel's K-block order (padded K rows are zeros in
    the kernel's tile, and adding 0.0 is exact, so per-element float
    reductions match term for term — exact equality holds for any bits,
    including the widths ``packed_matmul`` cannot lane-pack)."""
    w_tab = np.asarray(w_tab)
    s_tab = np.asarray(s_tab)
    k, n = w_tab.shape
    codes = _extract_ref(stream_words, w_tab, bits)
    spat = _extract_ref(stream_words, s_tab, 16)
    wq = codes.astype(np.float32) - float(1 << (bits - 1))
    scales = (spat.astype(np.uint32) << 16).view(np.float32)
    wf = (wq.reshape(k // group_size, group_size, n)
          * scales[:, None, :]).reshape(k, n)
    bk = min(block_k, k)
    bk = -(-bk // group_size) * group_size
    xf = jnp.asarray(x).astype(jnp.float32)
    wfj = jnp.asarray(wf)
    acc = jnp.zeros((x.shape[0], n), jnp.float32)
    for kk in range(0, k, bk):
        acc = acc + jnp.dot(xf[:, kk:kk + bk], wfj[kk:kk + bk],
                            preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


def stream_kv_ref(words_row: np.ndarray, tabs: dict, *,
                  bits: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side K/V extraction oracle for ``kvcache.stream_attention``.

    ``words_row``: one slot's flat ``(W,)`` u32 page words
    (:meth:`PackedKVCache.slot_words` row); ``tabs``: the
    :func:`~repro.kvcache.layout.full_stream_tables` dict.  Returns the
    dequantized f32 ``(smax, Hkv, hd)`` K and V exactly as the Pallas
    prologue computes them — extraction and dequantization are a u64
    funnel shift plus one f32 subtract/multiply each, so equality here
    is *bit* equality, not allclose.
    """
    bias = float(1 << (bits - 1))

    def one(code_tab, scale_tab):
        codes = _extract_ref(words_row, code_tab.reshape(-1), bits) \
            .reshape(code_tab.shape)
        spat = _extract_ref(words_row, scale_tab.reshape(-1), 16) \
            .reshape(scale_tab.shape)
        scales = (spat.astype(np.uint32) << 16).view(np.float32)
        return (codes.astype(np.float32) - bias) * scales[..., None]

    return (one(np.asarray(tabs["k"]), np.asarray(tabs["k_scales"])),
            one(np.asarray(tabs["v"]), np.asarray(tabs["v_scales"])))


def stream_attention_ref(words: np.ndarray, q: np.ndarray, pos: np.ndarray,
                         tabs: dict, *, bits: int) -> np.ndarray:
    """Oracle for ``kvcache.stream_attention``: numpy extraction through
    :func:`stream_kv_ref`, then plain f64 softmax attention.  The
    extraction half is bit-exact; the attention half is float math in a
    different summation order, so callers gate the final output with
    ``allclose`` (the *bit*-identity gate for the kernel is
    ``decode_attention`` over :meth:`PackedKVCache.dense_kv`)."""
    words = np.asarray(words)
    q = np.asarray(q, np.float64)
    b, _, h, hd = q.shape
    outs = []
    for i in range(b):
        kf, vf = stream_kv_ref(words[i], tabs, bits=bits)
        smax, hkv, _ = kf.shape
        rep = h // hkv
        kc = np.repeat(kf.astype(np.float64), rep, axis=1)
        vc = np.repeat(vf.astype(np.float64), rep, axis=1)
        s = np.einsum("qhd,khd->hqk", q[i], kc) * hd ** -0.5
        s = np.where(np.arange(smax)[None, None, :] <= int(pos[i]),
                     s, -np.inf)
        p = np.exp(s - s.max(axis=-1, keepdims=True))
        p = p / p.sum(axis=-1, keepdims=True)
        outs.append(np.einsum("hqk,khd->qhd", p, vc))
    return np.stack(outs, axis=0)


def packed_matmul_ref(x: jax.Array, w_packed: jax.Array, scales: jax.Array,
                      *, bits: int, group_size: int) -> jax.Array:
    """Oracle for ``packed_matmul``: unpack everything, then one big dot."""
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    bias = float(1 << (bits - 1))
    kw, n = w_packed.shape
    k = kw * lanes
    planes = [
        ((w_packed >> jnp.uint32(ln * bits)) & mask) for ln in range(lanes)
    ]
    codes = jnp.stack(planes, axis=1).reshape(k, n)
    wq = codes.astype(jnp.float32) - bias
    wf = (wq.reshape(k // group_size, group_size, n)
          * scales.astype(jnp.float32)[:, None, :]).reshape(k, n)
    return jnp.dot(x.astype(jnp.float32), wf,
                   preferred_element_type=jnp.float32)
