"""Pure-jnp / numpy oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import unpack_arrays
from repro.core.layout import Layout


def decode_layout_ref(layout: Layout, buf_u8: np.ndarray) -> dict[str, np.ndarray]:
    """Oracle for ``ops.decode_layout``: the numpy bit-gatherer."""
    return unpack_arrays(layout, np.asarray(buf_u8))


def decode_slot_ref(rows_u32: np.ndarray, offsets: tuple[int, ...],
                    width: int, n_rows: int) -> np.ndarray:
    """Oracle for ``layout_decode.decode_slot`` (vectorized numpy)."""
    rows = np.asarray(rows_u32[:n_rows], dtype=np.uint64)
    mask = np.uint64((1 << width) - 1)
    cols = []
    for off in offsets:
        w0, sh = off // 32, off % 32
        v = rows[:, w0] >> np.uint64(sh)
        if sh and sh + width > 32:
            v = v | (rows[:, w0 + 1] << np.uint64(32 - sh))
        cols.append(v & mask)
    return np.stack(cols, axis=1).reshape(-1).astype(np.uint32)


def packed_matmul_ref(x: jax.Array, w_packed: jax.Array, scales: jax.Array,
                      *, bits: int, group_size: int) -> jax.Array:
    """Oracle for ``packed_matmul``: unpack everything, then one big dot."""
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    bias = float(1 << (bits - 1))
    kw, n = w_packed.shape
    k = kw * lanes
    planes = [
        ((w_packed >> jnp.uint32(ln * bits)) & mask) for ln in range(lanes)
    ]
    codes = jnp.stack(planes, axis=1).reshape(k, n)
    wq = codes.astype(jnp.float32) - bias
    wf = (wq.reshape(k // group_size, group_size, n)
          * scales.astype(jnp.float32)[:, None, :]).reshape(k, n)
    return jnp.dot(x.astype(jnp.float32), wf,
                   preferred_element_type=jnp.float32)
