"""Pallas TPU kernel: dequant-on-load matmul over lane-packed intN weights.

The compute hot-spot of Iris-packed serving: activations hit quantized
weights that are *streamed packed* from HBM (bits moved = N*K*bits/8, not
N*K padded bytes) and dequantized in VMEM right before the MXU.

TPU adaptation of the paper's decode->stream->kernel dataflow (Listing 2
feeding the downstream dataflow modules): instead of per-cycle bit-slices
feeding FIFOs, each grid step DMAs a (bk*bits/32, bn) packed block into
VMEM, funnel-shifts it into a (bk, bn) int grid, applies group scales, and
feeds the MXU — the dequant is fused into the matmul pipeline so the
packed->dense expansion never touches HBM.

Blocking: grid (M/bm, N/bn, K/bk), K innermost; a VMEM f32 accumulator
carries partial sums across K steps.  bm/bn/bk default to MXU-aligned 128
multiples; bk must be a multiple of the quantization group size.  M may
be ragged (serving batch sizes are): activations are zero-padded up to
the M tile internally and the padding sliced off the output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu is importable on CPU for scratch-shape declarations
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

#: element widths the lane-packed kernel path supports: the funnel shift
#: needs a whole number of lanes per uint32 word (32 % bits == 0).  The
#: serving CLI (`launch.serve --bits`) and `api.pack_tree` validate
#: against this set up front instead of erroring inside the kernel.
SUPPORTED_BITS = (2, 4, 8)


def _packed_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                          bits: int, group_size: int, n_k_steps: int) -> None:
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    bias = float(1 << (bits - 1))

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_packed = w_ref[...]                      # (bk // lanes, bn) uint32
    rows, bn = w_packed.shape
    bk = rows * lanes
    # funnel-shift each lane out of its word: lane ln of word r is code
    # k = r * lanes + ln  ->  (rows, lanes, bn) -> (bk, bn)
    planes = [
        ((w_packed >> jnp.uint32(ln * bits)) & mask) for ln in range(lanes)
    ]
    codes = jnp.stack(planes, axis=1).reshape(bk, bn)
    wq = codes.astype(jnp.float32) - bias      # symmetric biased codes
    scales = s_ref[...].astype(jnp.float32)    # (bk // group_size, bn)
    wf = (wq.reshape(bk // group_size, group_size, bn)
          * scales[:, None, :]).reshape(bk, bn)
    x = x_ref[...].astype(jnp.float32)         # (bm, bk)
    acc_ref[...] += jnp.dot(x, wf, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits", "group_size", "block_m", "block_n", "block_k", "interpret",
        "out_dtype",
    ),
)
def packed_matmul(x: jax.Array, w_packed: jax.Array, scales: jax.Array, *,
                  bits: int, group_size: int, block_m: int = 128,
                  block_n: int = 128, block_k: int = 512,
                  out_dtype=jnp.float32, interpret: bool = True) -> jax.Array:
    """``x @ dequant(w_packed, scales)`` with on-the-fly dequantization.

    x:        (M, K) float
    w_packed: (K * bits // 32, N) uint32 lane-packed codes
              (see ``quant.pack_codes_u32``)
    scales:   (K // group_size, N)
    """
    if bits not in SUPPORTED_BITS:
        raise ValueError(
            f"packed_matmul supports bits in {sorted(SUPPORTED_BITS)}; "
            f"got {bits}"
        )
    m, k = x.shape
    lanes = 32 // bits
    kw, n = w_packed.shape
    if kw * lanes != k:
        raise ValueError(f"packed K mismatch: {kw}*{lanes} != {k}")
    if scales.shape != (k // group_size, n):
        raise ValueError(f"scales shape {scales.shape} != {(k // group_size, n)}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if k % block_k or block_k % group_size:
        raise ValueError(
            f"K={k} must tile by block_k={block_k}, "
            f"block_k by group_size={group_size}"
        )
    if n % block_n:
        raise ValueError(f"N={n} must tile by block_n={block_n}")
    # serving batches are ragged: pad activations up to the M tile and
    # slice the padding back off the output (zero rows cost one tile at
    # most and never perturb real rows)
    m_pad = -(-m // block_m) * block_m
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    n_k_steps = k // block_k
    grid = (m_pad // block_m, n // block_n, n_k_steps)

    kernel = functools.partial(
        _packed_matmul_kernel,
        bits=bits,
        group_size=group_size,
        n_k_steps=n_k_steps,
    )
    # pltpu.VMEM scratch works in interpret mode too (plain f32 buffer)
    scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k * bits // 32, block_n),
                         lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // group_size, block_n),
                         lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, w_packed, scales)
    return out[:m] if m_pad != m else out
