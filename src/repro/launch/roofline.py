"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), all in seconds (EXPERIMENTS.md
§Roofline):

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / (links * link_bw)

Sources: ``compiled.cost_analysis()`` (flops / bytes accessed, reported
for one SPMD partition = one chip) and a text pass over the optimized HLO
summing operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (result-shape bytes of each ``-start`` or
sync op — the DMA the ICI actually carries).

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI with 2 usable links per torus axis (conservative: we
divide collective bytes by 1 link's bandwidth and report the link count
separately).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.5 = bf16[128,1024]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
# tuple-result collectives:  %x = (bf16[..], bf16[..]) all-to-all(...)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum result bytes of every collective op in (optimized) HLO text.

    ``-done`` ops are skipped (their ``-start`` was already counted);
    ``-start`` result tuples double-count operand aliases, so for starts we
    take the largest tuple element only.
    """
    by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind, _start = m.groups()
            by_kind[kind] += _shape_bytes(dtype, dims)
            count[kind] += 1
            continue
        m = _TUPLE_RE.search(line)
        if m:
            tup, kind, start = m.group(1), m.group(2), m.group(3)
            sizes = [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tup)]
            if not sizes:
                continue
            by_kind[kind] += max(sizes) if start else sum(sizes)
            count[kind] += 1
    return CollectiveStats(by_kind, count)


@dataclasses.dataclass
class RooflineTerms:
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: float
    useful_flops_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(cost: dict, coll: CollectiveStats, n_chips: int,
                   model_flops_total: float) -> RooflineTerms:
    """cost: compiled.cost_analysis() of one SPMD partition."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.total_bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = cbytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    hlo_total = flops * n_chips
    return RooflineTerms(
        flops_per_chip=flops,
        bytes_per_chip=byts,
        collective_bytes_per_chip=cbytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        useful_flops_ratio=(model_flops_total / hlo_total
                            if hlo_total else 0.0),
    )


# ----------------------------------------------------------------------
# MODEL_FLOPS: 6*N*D (train) / 2*N*D (inference) + attention terms
# ----------------------------------------------------------------------
def model_flops(cfg, shape) -> float:
    """Useful FLOPs for one step of this cell (active params for MoE)."""
    n_active = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_is_attn(i))
    hd, h = cfg.head_dim, cfg.n_heads
    if shape.kind == "train":
        tokens = b * s
        mm = 6.0 * n_active * tokens
        attn = n_attn * 3 * 2 * 2 * b * s * s * h * hd * 0.5  # causal, fwd+bwd
    elif shape.kind == "prefill":
        tokens = b * s
        mm = 2.0 * n_active * tokens
        attn = n_attn * 2 * 2 * b * s * s * h * hd * 0.5
    else:  # decode: one token against an s-long context
        tokens = b
        mm = 2.0 * n_active * tokens
        attn = n_attn * 2 * 2 * b * s * h * hd
    if cfg.family == "ssm" or cfg.ssm is not None:
        # linear-attention state updates: ~6 flops per (head, dk, dv) elem
        n_lin = cfg.n_layers - n_attn
        if cfg.rwkv is not None:
            dk = dv = cfg.rwkv.head_dim
            heads = cfg.d_model // dk
        else:
            dk = cfg.ssm.d_state
            dv = cfg.ssm.head_dim
            heads = cfg.ssm.expand * cfg.d_model // cfg.ssm.head_dim
        per_tok = 6.0 * heads * dk * dv
        mult = 3.0 if shape.kind == "train" else 1.0
        n_tok = b if shape.kind == "decode" else b * s
        attn += n_lin * per_tok * n_tok * mult
    return mm + attn
