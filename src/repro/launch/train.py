"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --batch 8 --seq-len 256 [--reduced] \
        [--ckpt-dir artifacts/ckpt] [--remat dots] [--opt-dtype bfloat16]

Drives the fault-tolerant runtime (checkpoint/restart, straggler
detection) on the synthetic pipeline.  On a real cluster the same entry
point runs under `jax.distributed.initialize()` with the production mesh;
on this CPU container it runs single-process (use --reduced).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU)")
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"])
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLMPipeline
    from repro.launch.steps import build_train_step
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.runtime.train_loop import TrainLoopConfig, run_training

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({'reduced' if args.reduced else 'full'})")

    step_fn = jax.jit(
        build_train_step(cfg, AdamWConfig(lr=args.lr, warmup_steps=10,
                                          total_steps=args.steps),
                         remat=args.remat),
        donate_argnums=(0,))
    pipeline = SyntheticLMPipeline(cfg.vocab_size, args.seq_len,
                                   args.batch, seed=args.seed)

    def init_state():
        model = Model(cfg, remat=args.remat)
        params = model.init(jax.random.PRNGKey(args.seed))
        return {"params": params,
                "opt": init_opt_state(params, args.opt_dtype)}

    rep = run_training(
        step_fn, init_state, pipeline, args.ckpt_dir,
        TrainLoopConfig(total_steps=args.steps,
                        ckpt_interval=args.ckpt_interval),
        on_straggler=lambda s, dt: print(f"[straggler] step {s}: {dt:.2f}s"))
    print(f"steps={rep.steps_run} final_loss={rep.final_loss:.4f} "
          f"restarts={rep.restarts} stragglers={rep.stragglers} "
          f"resumed_from={rep.resumed_from}")
    if rep.losses:
        print(f"loss curve: {np.array2string(np.asarray(rep.losses[::max(1, len(rep.losses)//8)]), precision=3)}")


if __name__ == "__main__":
    main()
