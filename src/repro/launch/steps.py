"""Step functions lowered by the dry-run and driven by the runtime.

* ``train_step(state, batch)``   — loss, grads, AdamW update (donated state)
* ``prefill_step(params, batch)``— forward logits + prefill KV caches
* ``serve_step(params, state, tokens[, cross_kv])`` — one decode token

All functions are built per-config and are pure (jit/pjit-ready).
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def build_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                     remat: str = "full",
                     transform_grads: Callable | None = None) -> Callable:
    opt_cfg = opt_cfg or AdamWConfig()
    model = Model(cfg, remat=remat)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, grads, state["opt"], params,
            transform_grads=transform_grads)
        metrics = {"loss": loss, **metrics}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, remat: str = "none") -> Callable:
    model = Model(cfg, remat=remat)

    def prefill_step(params: dict, batch: dict):
        logits, _aux, caches = model.forward(params, batch,
                                             collect_cache=True)
        return logits, caches

    return prefill_step


def build_serve_step(cfg: ModelConfig) -> Callable:
    model = Model(cfg)

    def serve_step(params: dict, state: dict, tokens: jax.Array,
                   cross_kv=None):
        return model.decode_step(params, state, tokens, cross_kv)

    return serve_step


def init_train_state(cfg: ModelConfig, key) -> dict:
    model = Model(cfg)
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params)}
