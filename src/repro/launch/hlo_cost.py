"""Loop-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE (verified:
a length-8 scanned matmul reports 1/8th the flops of its unrolled twin),
which silently voids roofline math for scan-over-layers models.  This
module re-derives the three roofline inputs from the HLO text with loop
multipliers propagated through the call graph:

* **flops**      — 2*M*N*K per ``dot`` (dominant; elementwise ignored),
* **collective bytes** — result bytes per collective op,
* **hbm bytes**  — per materializing op: result bytes + operand-read
  bytes (fusion interiors are skipped — fused values never hit HBM;
  the fusion node itself accounts for its operands/results).

Multiplier rules: entry = 1; ``while`` body/condition inherit
parent x known_trip_count; ``fusion``/``call``/``to_apply`` inherit parent.

This is an estimator, not a simulator: constants/layout-change copies are
counted at face value and operand reads are counted once per use.  Its
job is to make the three terms *comparable and loop-correct*, which is
what the §Perf iteration needs.  Validated against unrolled references in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_VALUE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"^\(?\s*([a-z][a-z0-9]*)\[([0-9,]*)\]")
_ALL_SHAPES_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPNAME_RE = re.compile(r"\)?\s*([a-z][a-z0-9\-]*)\(")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    result_bytes: int
    tuple_bytes: int
    operands: list[str]
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list[_Op]
    shapes: dict[str, int]      # value name -> result bytes


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_bytes_by_kind: dict[str, float]
    collective_count_by_kind: dict[str, int]
    n_while_loops: int
    max_trip_count: int
    #: same accumulations with every loop multiplier forced to 1 — the
    #: ratio loop/unit rescales XLA's own (loop-blind) cost_analysis
    #: numbers without inheriting this estimator's per-op biases.
    flops_unit: float = 0.0
    hbm_bytes_unit: float = 0.0

    @property
    def loop_scale_bytes(self) -> float:
        return (self.hbm_bytes / self.hbm_bytes_unit
                if self.hbm_bytes_unit else 1.0)


def _parse_computations(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        m = _COMP_RE.match(line) if not line.startswith(" ") else None
        if m and stripped.endswith("{"):
            cur = _Computation(m.group(1), [], {})
            comps[cur.name] = cur
            continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        vm = _VALUE_RE.match(line)
        if not vm:
            continue
        name, rhs = vm.groups()
        sm = _SHAPE_RE.match(rhs)
        result_bytes = _shape_bytes(*sm.groups()) if sm else 0
        tuple_bytes = sum(
            _shape_bytes(d, s)
            for d, s in _ALL_SHAPES_RE.findall(rhs.split("(")[0]))
        om = _OPNAME_RE.search(rhs)
        kind = om.group(1) if om else "unknown"
        paren = rhs[rhs.find("("):]
        operands = _OPERANDS_RE.findall(paren.split(")")[0]) if paren else []
        cur.shapes[name] = tuple_bytes or result_bytes
        cur.ops.append(_Op(name, kind, result_bytes, tuple_bytes,
                           operands, rhs))
    return comps


def analyze(text: str, entry: str | None = None) -> HloCost:
    comps = _parse_computations(text)
    if not comps:
        return HloCost(0, 0, 0, {}, {}, 0, 0)
    # find the entry computation
    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        entry_name = m.group(1) if m else next(iter(comps))

    # propagate multipliers through the call graph
    mult: dict[str, float] = {entry_name: 1.0}
    fused_body: set[str] = set()
    stack = [entry_name]
    n_while = 0
    max_trip = 0
    seen = set()
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in comps:
            continue
        seen.add(cname)
        parent_m = mult.get(cname, 1.0)
        for op in comps[cname].ops:
            if op.kind == "while":
                tm = _TRIP_RE.search(op.line)
                trip = int(tm.group(1)) if tm else 1
                n_while += 1
                max_trip = max(max_trip, trip)
                wm = _WHILE_RE.search(op.line)
                if wm:
                    cond, body = wm.groups()
                    for sub, f in ((body, trip), (cond, trip)):
                        mult[sub] = max(mult.get(sub, 0.0), parent_m * f)
                        stack.append(sub)
            else:
                cm = _CALLS_RE.search(op.line)
                if cm:
                    sub = cm.group(1)
                    mult[sub] = max(mult.get(sub, 0.0), parent_m)
                    stack.append(sub)
                    if op.kind == "fusion":
                        fused_body.add(sub)

    flops = 0.0
    flops_unit = 0.0
    hbm = 0.0
    hbm_unit = 0.0
    coll_b: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_n: dict[str, int] = {k: 0 for k in _COLLECTIVES}

    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue
        in_fusion = cname in fused_body
        for op in comp.ops:
            if op.kind == "dot":
                df = _dot_flops(op, comp)
                flops += m * df
                flops_unit += df
            base = op.kind.split("-start")[0]
            if base in _COLLECTIVES and not op.kind.endswith("-done"):
                b = op.result_bytes if op.kind.endswith("-start") \
                    else (op.tuple_bytes or op.result_bytes)
                coll_b[base] += m * b
                coll_n[base] += int(m) if m >= 1 else 1
            if in_fusion:
                continue            # fused interiors never touch HBM
            if op.kind in ("parameter", "constant", "get-tuple-element",
                           "tuple", "bitcast", "while", "conditional"):
                continue
            reads = sum(comp.shapes.get(o, 0) for o in op.operands)
            b = (op.tuple_bytes or op.result_bytes + 0.0) + reads
            hbm += m * b
            hbm_unit += b

    return HloCost(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=sum(coll_b.values()),
        collective_bytes_by_kind=coll_b,
        collective_count_by_kind=coll_n,
        n_while_loops=n_while,
        max_trip_count=max_trip,
        flops_unit=flops_unit,
        hbm_bytes_unit=hbm_unit,
    )


def _dot_flops(op: _Op, comp: _Computation) -> float:
    """2 * prod(result dims) * prod(contracted dims) from the HLO line."""
    sm = _SHAPE_RE.match(op.line)
    if not sm:
        return 0.0
    dtype, dims = sm.groups()
    out_elems = 1
    if dims:
        for d in dims.split(","):
            out_elems *= int(d)
    # contracted size: lhs shape at lhs_contracting_dims
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not cm or not op.operands:
        return 2.0 * out_elems          # fallback: treat as elementwise-ish
    lhs = op.operands[0]
    # find the lhs declaration to get its dims
    lhs_line = next((o.line for o in comp.ops if o.name == lhs), None)
    if lhs_line is None:
        return 2.0 * out_elems
    lm = _SHAPE_RE.match(lhs_line)
    if lm is None:
        return 2.0 * out_elems
    lhs_dims = [int(x) for x in lm.group(2).split(",")] if lm.group(2) else []
    k = 1
    for idx in cm.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k
