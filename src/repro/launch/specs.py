"""Input specifications per (architecture x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation — which is what the
dry-run lowers against, and what the data pipeline must produce at run
time.  The decode cells include the full KV/SSM state (the dominant memory
term at 32k/500k context).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model
from repro.models.transformer import n_periods


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {
        "tokens": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
    }
    if cfg.encoder is not None:
        batch["frames"] = sds((b, cfg.encoder.n_ctx, cfg.d_model),
                              jnp.float32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    batch = train_batch_specs(cfg, shape)
    batch.pop("labels")
    return batch


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract version of Model.init_decode_state + step inputs."""
    b, s = shape.global_batch, shape.seq_len
    model = Model(cfg)
    state_shape = jax.eval_shape(
        lambda: model.init_decode_state(b, max_seq=s))
    state = dict(state_shape)
    # decode starts with a full context: pos is traced anyway
    inputs: dict[str, Any] = {
        "state": state,
        "tokens": sds((b,), jnp.int32),
    }
    if cfg.encoder is not None:
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        np_ = n_periods(cfg)
        ctx = cfg.encoder.n_ctx
        inputs["cross_kv"] = (
            sds((np_, b, ctx, hkv, hd), cfg.dtype),
            sds((np_, b, ctx, hkv, hd), cfg.dtype),
        )
    return inputs


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """The non-parameter inputs of the step function for this cell."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    if shape.kind == "decode":
        return decode_state_specs(cfg, shape)
    raise ValueError(f"unknown shape kind {shape.kind!r}")


def abstract_params(cfg: ModelConfig) -> Any:
    model = Model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_train_state(cfg: ModelConfig,
                         opt_dtype: str = "float32") -> dict:
    from repro.optim.adamw import init_opt_state
    params = abstract_params(cfg)
    opt = jax.eval_shape(lambda p: init_opt_state(p, opt_dtype), params)
    return {"params": params, "opt": opt}
