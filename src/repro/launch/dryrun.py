import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first initialization).  Everything below is normal code.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we jit the step function with production shardings,
``.lower().compile()`` it against ShapeDtypeStruct inputs (no allocation),
and record:

* ``memory_analysis()``  — bytes per device (proves the cell fits),
* ``cost_analysis()``    — FLOPs / bytes for the roofline terms,
* collective bytes       — parsed from the optimized HLO,
* the derived roofline terms + MODEL_FLOPS ratio (launch/roofline.py).

Artifacts are written as JSON under --out (default artifacts/dryrun) and
aggregated into EXPERIMENTS.md by benchmarks/roofline_report.py.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all --mesh single
  python -m repro.launch.dryrun --arch jamba-1.5-large-398b --shape long_500k --mesh multi
"""
import argparse
import json
import pathlib
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, remat: str = "full",
             fsdp: bool | None = None, donate: bool = True,
             opt_dtype: str = "float32",
             kv_dtype: str = "bfloat16", tag: str = "",
             kv_replicate: bool = True) -> dict:
    import jax

    from repro.configs import SHAPES, get_config
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import (
        batch_sharding,
        decode_state_shardings,
        opt_state_shardings,
        param_shardings,
    )
    from repro.launch.specs import (
        abstract_params,
        abstract_train_state,
        input_specs,
    )
    from repro.launch.steps import (
        build_prefill_step,
        build_serve_step,
        build_train_step,
    )

    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    tp = mesh.shape["model"]
    cfg = get_config(arch)
    if kv_replicate:
        # GQA TP practice: replicate KV heads to a multiple of the model
        # axis.  kv_replicate=False keeps the true head count and lets the
        # sharding rules fall back to head_dim sharding (halves KV bytes
        # for kv8/tp16 archs at the cost of a psum over hd in decode).
        cfg = cfg.with_tp(tp)
    if kv_dtype != "bfloat16":
        import dataclasses as _dc
        cfg = _dc.replace(cfg, kv_cache_dtype=kv_dtype)
    # FSDP for >= 8B params (everything smaller fits replicated-over-data)
    if fsdp is None:
        fsdp = cfg.param_count() > 8e9
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            state_shape = abstract_train_state(cfg, opt_dtype)
            p_shard = param_shardings(state_shape["params"], mesh, fsdp=fsdp)
            o_shard = opt_state_shardings(state_shape["opt"], p_shard, mesh)
            in_state_shard = {"params": p_shard, "opt": o_shard}
            batch = input_specs(cfg, shape)["batch"]
            b_shard = batch_sharding(batch, mesh)
            fn = build_train_step(cfg, remat=remat)
            jitted = jax.jit(
                fn,
                in_shardings=(in_state_shard, b_shard),
                donate_argnums=(0,) if donate else (),
            )
            lowered = jitted.lower(state_shape, batch)
        elif shape.kind == "prefill":
            params = abstract_params(cfg)
            p_shard = param_shardings(params, mesh, fsdp=fsdp)
            batch = input_specs(cfg, shape)["batch"]
            b_shard = batch_sharding(batch, mesh)
            fn = build_prefill_step(cfg, remat="none")
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params, batch)
        else:  # decode
            params = abstract_params(cfg)
            p_shard = param_shardings(params, mesh, fsdp=fsdp)
            spec = input_specs(cfg, shape)
            shard_seq = shape.global_batch == 1
            s_shard = decode_state_shardings(spec["state"], mesh,
                                             shard_seq=shard_seq)
            t_shard = batch_sharding(spec["tokens"], mesh)
            fn = build_serve_step(cfg)
            if "cross_kv" in spec:
                c_shard = decode_state_shardings(
                    {"cross_kv": spec["cross_kv"]}, mesh,
                    shard_seq=shard_seq)["cross_kv"]
                jitted = jax.jit(
                    fn, in_shardings=(p_shard, s_shard, t_shard, c_shard),
                    donate_argnums=(1,) if donate else ())
                lowered = jitted.lower(params, spec["state"], spec["tokens"],
                                       spec["cross_kv"])
            else:
                jitted = jax.jit(
                    fn, in_shardings=(p_shard, s_shard, t_shard),
                    donate_argnums=(1,) if donate else ())
                lowered = jitted.lower(params, spec["state"], spec["tokens"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.launch import hlo_cost

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # loop-aware costs: XLA's cost_analysis counts while bodies once,
    # which voids roofline math for scan-over-layers models (see
    # launch/hlo_cost.py); the analyzer propagates known_trip_counts.
    hc = hlo_cost.analyze(hlo)
    coll = rl.CollectiveStats(
        {k: int(v) for k, v in hc.collective_bytes_by_kind.items()},
        hc.collective_count_by_kind)
    n_chips = mesh.devices.size
    mf = rl.model_flops(cfg, shape)
    # memory: XLA's per-op 'bytes accessed' estimate, rescaled by the
    # analyzer's loop/unit byte ratio (fixes the loop-blindness without
    # inheriting the analyzer's per-op read double-counting)
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    mem_bytes = raw_bytes * hc.loop_scale_bytes
    terms = rl.roofline_terms(
        {"flops": hc.flops, "bytes accessed": mem_bytes},
        coll, n_chips, mf)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "fsdp": fsdp,
        "remat": remat,
        "opt_dtype": opt_dtype,
        "kv_dtype": kv_dtype,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "cost_raw_xla": {k: cost.get(k) for k in ("flops",
                                                  "bytes accessed")},
        "cost": {"flops": hc.flops, "bytes accessed": hc.hbm_bytes,
                 "n_while_loops": hc.n_while_loops,
                 "max_trip_count": hc.max_trip_count},
        "collectives": {
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
            "total_bytes": coll.total_bytes,
        },
        "roofline": terms.as_dict(),
        "status": "ok",
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{result['mesh']}"
    if tag:
        name += f"__{tag}"
        result["tag"] = tag
    (out_dir / f"{name}.json").write_text(json.dumps(result, indent=2))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--kv-dtype", default="bfloat16",
                    choices=["bfloat16", "float8_e5m2"])
    ap.add_argument("--tag", default="",
                    help="suffix for the artifact filename (perf iters)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    out = pathlib.Path(args.out)

    from repro.configs import ARCH_IDS, shape_cells

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            for sc in shape_cells(arch):
                cells.append((arch, sc.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch} x {shape} x {'multi' if multi else 'single'}"
            try:
                r = run_cell(arch, shape, multi, out, remat=args.remat,
                             fsdp=False if args.no_fsdp else None,
                             opt_dtype=args.opt_dtype,
                             kv_dtype=args.kv_dtype, tag=args.tag)
                rt = r["roofline"]
                print(f"OK   {tag}: compile={r['compile_s']}s "
                      f"peak={r['memory']['peak_bytes']/2**30:.2f}GiB/dev "
                      f"bottleneck={rt['bottleneck']} "
                      f"(c={rt['compute_s']:.2e}s m={rt['memory_s']:.2e}s "
                      f"coll={rt['collective_s']:.2e}s)", flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures += 1
                out.mkdir(parents=True, exist_ok=True)
                mesh_tag = "pod2x16x16" if multi else "pod16x16"
                (out / f"{arch}__{shape}__{mesh_tag}.json").write_text(
                    json.dumps({"arch": arch, "shape": shape,
                                "mesh": mesh_tag, "status": "error",
                                "error": str(e)[:2000]}, indent=2))
                print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:300]}",
                      flush=True)
                traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
