"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the 'pod'
axis carries pure data parallelism (gradient all-reduce crosses the
inter-pod DCN/optical links only once per step).

Defined as functions so importing this module never touches jax device
state (the dry-run pins XLA_FLAGS *before* any jax initialization).
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run entry "
            "point must set xla_force_host_platform_device_count first")
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_debug_mesh(shape: tuple[int, ...] = (2, 2),
                    axes: tuple[str, ...] = ("data", "model")
                    ) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires enough host devices)."""
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The pure data-parallel axes of a mesh ('pod' folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_axis_size(mesh: jax.sharding.Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out
