"""HLO inspection helpers for the dry-run perf loop (no real hardware).

The 'profile' on this container is the optimized HLO text: these helpers
surface the largest tensors, op-category FLOP/byte histograms, and
collective inventories that drive the §Perf hypothesis loop.
"""
from __future__ import annotations

import collections
import re

_SHAPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]+)\]")
_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8,
}


def tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def largest_tensors(hlo_text: str, top: int = 25) -> list[tuple[int, str]]:
    """(bytes, hlo_line_prefix) for the largest result tensors."""
    out = []
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        m = _SHAPE.search(rhs.strip()[:120])
        if not m:
            continue
        b = tensor_bytes(m.group(1), m.group(2))
        out.append((b, line[:160]))
    out.sort(key=lambda x: -x[0])
    return out[:top]


def op_histogram(hlo_text: str) -> dict[str, int]:
    """Count of ops by kind in the optimized module."""
    hist: collections.Counter = collections.Counter()
    op_re = re.compile(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
                       r"([a-z][a-z0-9-]*)\(")
    for line in hlo_text.splitlines():
        m = op_re.search(line)
        if m:
            hist[m.group(1)] += 1
    return dict(hist.most_common())


def collective_inventory(hlo_text: str) -> list[str]:
    """Every collective op line (for eyeballing redundant collectives)."""
    keys = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute")
    return [
        line.strip()[:200] for line in hlo_text.splitlines()
        if any(k in line for k in keys) and "=" in line
        and "-done" not in line
    ]
