"""Sharding rules: param pytrees and runtime state -> PartitionSpecs.

Strategy (DESIGN.md §5):

* TP over 'model' on the "wide" dimension of every weight matrix
  (ffn hidden, attention heads, vocab, experts);
* FSDP over 'data' on the other dimension for large configs (XLA
  all-gathers per scanned layer);
* DP over ('pod', 'data') for activations/batch;
* EP: expert dimension of MoE weights over 'model';
* every rule is divisibility-checked per tensor dimension — axes that do
  not divide are dropped (replicated) rather than failing, which is what
  lets one rule set serve 10 heterogeneous architectures.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import dp_axes, mesh_axis_size

# weights whose FIRST data dim is the contraction/output-projection side
_OUT_PROJ = ("wo", "w_o", "w_down", "w_out", "w_v_channel", "decay_b")
# small / replicated leaves
_REPLICATED = ("norm", "scale", "bias", "mix", "bonus_u", "a_log", "d_skip",
               "dt_bias", "decay_w0", "router", "step")


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % mesh_axis_size(mesh, axes) == 0


def _maybe(axis, dim, mesh):
    """axis if it divides dim else None."""
    if axis is None:
        return None
    return axis if _fits(dim, mesh, axis) else None


def _path_str(path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "idx", k))) for k in path).lower()


def leaf_partition_spec(path, leaf, mesh: Mesh, *, fsdp: bool = True) -> P:
    """PartitionSpec for one param leaf, by name + shape."""
    name = _path_str(path)
    shape = tuple(leaf.shape)
    stacked = "blocks" in name or "encoder" in name
    fsdp_ax = "data" if (fsdp and "data" in mesh.axis_names) else None

    def build(dims: tuple) -> P:
        """dims: per-dim axis proposals for the *unstacked* trailing dims."""
        specs = [None] * (len(shape) - len(dims)) + [
            _maybe(a, d, mesh) for a, d in zip(dims, shape[-len(dims):])
        ]
        return P(*specs)

    base = name.rsplit("/", 1)[-1]
    if any(s in base for s in _REPLICATED) or leaf.ndim <= 1 + int(stacked):
        return P()
    is_moe = "/moe/" in name or name.endswith("moe")
    core = shape[1:] if stacked else shape
    if is_moe and len(core) == 3:                 # (E, d_in, d_out)
        if any(base.endswith(o) for o in _OUT_PROJ):
            return build(("model", None, fsdp_ax))
        return build(("model", fsdp_ax, None))
    if base == "embed":                           # (V, d) vocab-parallel
        return build(("model", fsdp_ax))
    if base == "unembed":                         # (d, V)
        return build((fsdp_ax, "model"))
    if len(core) == 2:
        if any(base.endswith(o) for o in _OUT_PROJ):
            return build(("model", fsdp_ax))      # contraction on 'model'
        return build((fsdp_ax, "model"))
    return P()


def param_shardings(params_shape: Any, mesh: Mesh, *, fsdp: bool = True
                    ) -> Any:
    """Map a pytree of ShapeDtypeStructs/arrays to NamedShardings."""
    def f(path, leaf):
        return NamedSharding(mesh, leaf_partition_spec(
            path, leaf, mesh, fsdp=fsdp))
    return jax.tree_util.tree_map_with_path(f, params_shape)


def opt_state_shardings(opt_shape: Any, param_sharding_tree: Any,
                        mesh: Mesh) -> Any:
    """Moments m/v shard exactly like their params; step is replicated."""
    del param_sharding_tree

    def f(path, leaf):
        top = getattr(path[0], "key", None)
        if top == "step":
            return NamedSharding(mesh, P())
        # reuse the param rule on the path below m/v
        return NamedSharding(mesh, leaf_partition_spec(
            path[1:], leaf, mesh))
    return jax.tree_util.tree_map_with_path(f, opt_shape)


# ----------------------------------------------------------------------
# runtime state (batches, KV caches, decode state)
# ----------------------------------------------------------------------
def batch_sharding(shape_tree: Any, mesh: Mesh) -> Any:
    """Token batches: leading (global) batch dim over DP axes."""
    dp = dp_axes(mesh)

    def f(_path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = [None] * leaf.ndim
        if _fits(leaf.shape[0], mesh, dp):
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(f, shape_tree)


def decode_state_shardings(state_shape: Any, mesh: Mesh, *,
                           shard_seq: bool = False) -> Any:
    """KV caches: batch over DP (or sequence for long-context, B=1),
    heads over 'model' (falling back to head_dim, then replication)."""
    dp = dp_axes(mesh)

    def kv_spec(shape):
        # (n_periods, B, S, Hkv, hd)
        np_, b, s, hkv, hd = shape
        spec = [None, None, None, None, None]
        if shard_seq:
            if _fits(s, mesh, dp):
                spec[2] = dp
        elif _fits(b, mesh, dp):
            spec[1] = dp
        if _fits(hkv, mesh, "model"):
            spec[3] = "model"
        elif _fits(hd, mesh, "model"):
            spec[4] = "model"
        return P(*spec)

    def f(path, leaf):
        name = _path_str(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if "k_cache" in name or "v_cache" in name or "cross_kv" in name:
            return NamedSharding(mesh, kv_spec(leaf.shape))
        if "ssm" in name:
            # (np, n_mamba, B, H, n, hd)
            spec = [None] * leaf.ndim
            if not shard_seq and _fits(leaf.shape[2], mesh, dp):
                spec[2] = dp
            for dim in (3, 4, 5):
                if _fits(leaf.shape[dim], mesh, "model"):
                    spec[dim] = "model"
                    break
            return NamedSharding(mesh, P(*spec))
        if "rwkv" in name:
            # (np, B, H, dk, dv)
            spec = [None] * leaf.ndim
            if not shard_seq and _fits(leaf.shape[1], mesh, dp):
                spec[1] = dp
            for dim in (2, 3, 4):
                if _fits(leaf.shape[dim], mesh, "model"):
                    spec[dim] = "model"
                    break
            return NamedSharding(mesh, P(*spec))
        if "shift" in name:
            spec = [None] * leaf.ndim
            if not shard_seq and _fits(leaf.shape[1], mesh, dp):
                spec[1] = dp
            if _fits(leaf.shape[-1], mesh, "model"):
                spec[-1] = "model"
            return NamedSharding(mesh, P(*spec))
        # tokens (B,) / pos ()
        spec = [None] * leaf.ndim
        if leaf.ndim >= 1 and _fits(leaf.shape[0], mesh, dp):
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(f, state_shape)


def shardings_to_specs(tree: Any) -> Any:
    return jax.tree.map(lambda s: s.spec, tree,
                        is_leaf=lambda x: isinstance(x, NamedSharding))


# ----------------------------------------------------------------------
# PackedTree placement
# ----------------------------------------------------------------------
def packed_tree_shardings(pt: Any, mesh: Mesh) -> Any:
    """NamedShardings for a :class:`repro.tree.PackedTree`.

    Because a ``PackedTree`` is a registered pytree, placement is just
    another tree of the same structure — no packed-state special-casing
    at call sites: ``jax.device_put(pt, packed_tree_shardings(pt, mesh))``.

    Rules: lane-packed codes and scales are tensor-parallel on the
    output (N) dimension over ``'model'`` when it divides; the unified
    stream buffers shard their layer dimension over the DP axes when it
    divides (each host streams its layers) and replicate otherwise;
    ``other`` leaves follow :func:`leaf_partition_spec` for embeddings
    and replicate the per-layer norm/bias vectors.
    """
    from repro.tree import PackedTree  # lazy: keeps module JAX-only

    def tp_n(x) -> NamedSharding:
        # (n_layers, K', N): shard only the last (output) dim
        spec = [None] * (x.ndim - 1) + [_maybe("model", x.shape[-1], mesh)]
        return NamedSharding(mesh, P(*spec))

    def other_spec(path, leaf) -> NamedSharding:
        name = _path_str(path)
        base = name.rsplit("/", 1)[-1]
        if base in ("embed", "unembed") and leaf.ndim >= 2:
            return NamedSharding(
                mesh, leaf_partition_spec(path, leaf, mesh, fsdp=False))
        return NamedSharding(mesh, P())     # norms/biases: replicated

    streams = None
    if pt.streams is not None:
        dp = dp_axes(mesh)
        lead = dp if _fits(pt.streams.shape[0], mesh, dp) else None
        streams = NamedSharding(mesh, P(lead, None, None))
    return PackedTree(
        packed={k: tp_n(v) for k, v in pt.packed.items()},
        scales={k: tp_n(v) for k, v in pt.scales.items()},
        other=jax.tree_util.tree_map_with_path(other_spec, pt.other),
        streams=streams,
        manifest=pt.manifest,
    )
