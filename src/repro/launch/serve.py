"""Serving launcher CLI (continuous batching; optional Iris-packed path).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --requests 6 --batch-size 2 --max-new 8 [--packed --bits 8]

`--packed` serves through the quantized dequant-on-load path for
dense-family archs.  All pack/plan wiring goes through the one front
door — ``repro.api.pack_tree`` — which quantizes the weights, plans the
per-layer Iris stream layouts through the shared layout cache (one
scheduler run for the whole uniform stack; repeated requests with the
same shapes never re-run the scheduler) and packs the unified per-layer
HBM stream buffers.  Lane-packable widths (2/4/8) serve through the
legacy kernel views; every other width (3/5/6/7) serves *stream-direct*
— the Pallas matmul gathers weights straight from the packed stream
(``kernels.stream_matmul``), no dense intermediate.  The report prints
the weight-stream bytes-per-token comparison plus the one-line
`Plan`/`PackedTree` summaries and a stream-direct demo matmul.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.kernels.packed_matmul import SUPPORTED_BITS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--packed", action="store_true")
    # the stream-direct matmul lifts the old lane-packing restriction:
    # any QuantSpec width serves (2/4/8 via kernel views, the rest
    # straight off the Iris stream)
    ap.add_argument("--bits", type=int, default=8,
                    choices=list(range(2, 9)),
                    help="quantization width for --packed; "
                         f"{sorted(SUPPORTED_BITS)} use the lane-packed "
                         "kernel views, other widths serve stream-direct")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.model import Model
    from repro.runtime.serve_loop import Request, ServeLoop

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    if args.packed:
        from repro import api
        from repro.models.quantized import bytes_per_token_report, quantizable
        from repro.quant import QuantSpec

        if not quantizable(cfg):
            raise SystemExit(f"{cfg.name}: packed path covers dense archs")
        qspec = QuantSpec(bits=args.bits, group_size=32)

        # the one front door: quantize -> plan (cached) -> pack streams
        pt = api.pack_tree(cfg, params, qspec)
        rep = bytes_per_token_report(cfg, pt)
        print(f"weight stream/token: packed={rep['packed_MiB']:.2f} MiB "
              f"padded-int={rep['padded_int_MiB']:.2f} "
              f"bf16={rep['bf16_MiB']:.2f} "
              f"({rep['bf16_MiB']/rep['packed_MiB']:.2f}x reduction)")
        print(pt.summary())
        # per-layer plan summary: the shared cache answers by signature,
        # so this never re-runs the scheduler
        print(api.plan(pt.manifest.problem()).summary())

        # compiled execution plan (one per layout signature, shared by
        # every layer through the layout cache): the whole stream decodes
        # with a single fused Pallas kernel per layer
        prog = pt.exec_program()
        print(f"exec program: pieces={prog.n_pieces}, "
              f"kernel lanes={prog.kernel.lanes}, "
              f"host-path arrays={len(prog.host_arrays)}, "
              f"pallas calls/decode={prog.n_pallas_calls}")

        # stream-direct exec surface: one demo matmul gathered straight
        # from layer 0's packed stream — the path packed_decode_step
        # routes through automatically when kernel views are absent
        mode = "kernel-views" if pt.packed else "stream-direct"
        key = next(iter(dict(pt.manifest.shapes)))
        kk, nn = dict(pt.manifest.shapes)[key]
        x = jax.numpy.ones((1, kk), jax.numpy.float32)
        y = pt.matmul_direct(x, key, 0, interpret=True)
        print(f"serving path: {mode} (int{args.bits}); stream-direct "
              f"demo {key} (1x{kk})@({kk}x{nn}) -> "
              f"finite={bool(np.isfinite(np.asarray(y)).all())}")

    loop = ServeLoop(model, params, batch_size=args.batch_size,
                     max_seq=args.max_seq)
    for uid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              rng.integers(2, 6)).tolist()
        loop.submit(Request(uid=uid, prompt=prompt,
                            max_new_tokens=args.max_new))
    stats = loop.run_until_drained(max_steps=5000)
    print(f"completed={stats.completed}/{args.requests} "
          f"steps={stats.steps} tokens={stats.tokens_generated} "
          f"admitted={stats.admitted}")


if __name__ == "__main__":
    main()
