"""Serving launcher CLI (continuous batching; optional Iris-packed path).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --requests 6 --batch-size 2 --max-new 8 [--packed --bits 8]

Serving runs on :mod:`repro.engine` — the stage-decoupled continuous-
batching engine with bounded admission and per-request metrics.
``--qps`` switches from closed-loop (submit everything, drain) to
open-loop load: requests arrive at the given rate on the wall clock and
queue-time shows up in the metrics.  ``--metrics-out`` writes the
engine's JSON metrics snapshot (schema: DESIGN.md §Serving-engine).

`--packed` serves through the quantized dequant-on-load path for
dense-family archs.  All pack/plan wiring goes through the one front
door — ``repro.api.pack_tree`` — which quantizes the weights, plans the
per-layer Iris stream layouts through the shared layout cache (one
scheduler run for the whole uniform stack; repeated requests with the
same shapes never re-run the scheduler) and packs the unified per-layer
HBM stream buffers.  Lane-packable widths (2/4/8) serve through the
legacy kernel views; every other width (3/5/6/7) serves *stream-direct*
— the Pallas matmul gathers weights straight from the packed stream
(``kernels.stream_matmul``), no dense intermediate — with host->device
uploads double-buffered by :class:`repro.engine.StreamUploader` so the
next layer's transfer overlaps the current layer's compute.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.kernels.packed_matmul import SUPPORTED_BITS


def _run_open_loop(engine, requests, qps: float,
                   max_steps: int = 100_000) -> None:
    """Submit ``requests`` at ``qps`` arrivals/s (uniform spacing) on the
    wall clock while stepping the engine; drain after the last arrival."""
    t0 = time.monotonic()
    arrivals = [(i / qps, req) for i, req in enumerate(requests)]
    steps = 0
    while arrivals or engine.has_work():
        now = time.monotonic() - t0
        while arrivals and arrivals[0][0] <= now:
            engine.submit(arrivals.pop(0)[1])
        if engine.has_work():
            engine.step()
            steps += 1
            if steps >= max_steps:
                break
        elif arrivals:
            time.sleep(min(0.001, arrivals[0][0] - now))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--packed", action="store_true")
    # the stream-direct matmul lifts the old lane-packing restriction:
    # any QuantSpec width serves (2/4/8 via kernel views, the rest
    # straight off the Iris stream)
    ap.add_argument("--bits", type=int, default=8,
                    choices=list(range(2, 9)),
                    help="quantization width for --packed; "
                         f"{sorted(SUPPORTED_BITS)} use the lane-packed "
                         "kernel views, other widths serve stream-direct")
    ap.add_argument("--policy", choices=["continuous", "static"],
                    default="continuous",
                    help="slot admission policy (static = drain the whole "
                         "batch before admitting, the baseline)")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="open-loop arrival rate (requests/s); 0 = closed "
                         "loop (submit all up front, drain)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the engine metrics JSON snapshot here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.engine import (
        DenseAdapter,
        Engine,
        EngineConfig,
        EngineRequest,
        PackedAdapter,
        StreamUploader,
    )
    from repro.models.model import Model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    uploader = None
    if args.packed:
        from repro import api
        from repro.models.quantized import bytes_per_token_report, quantizable
        from repro.quant import QuantSpec

        if not quantizable(cfg):
            raise SystemExit(f"{cfg.name}: packed path covers dense archs")
        qspec = QuantSpec(bits=args.bits, group_size=32)

        # the one front door: quantize -> plan (cached) -> pack streams
        pt = api.pack_tree(cfg, params, qspec)
        rep = bytes_per_token_report(cfg, pt)
        print(f"weight stream/token: packed={rep['packed_MiB']:.2f} MiB "
              f"padded-int={rep['padded_int_MiB']:.2f} "
              f"bf16={rep['bf16_MiB']:.2f} "
              f"({rep['bf16_MiB']/rep['packed_MiB']:.2f}x reduction)")
        print(pt.summary())
        # per-layer plan summary: the shared cache answers by signature,
        # so this never re-runs the scheduler
        print(api.plan(pt.manifest.problem()).summary())

        # compiled execution plan (one per layout signature, shared by
        # every layer through the layout cache): the whole stream decodes
        # with a single fused Pallas kernel per layer
        prog = pt.exec_program()
        print(f"exec program: pieces={prog.n_pieces}, "
              f"kernel lanes={prog.kernel.lanes}, "
              f"host-path arrays={len(prog.host_arrays)}, "
              f"pallas calls/decode={prog.n_pallas_calls}")

        mode = "kernel-views" if pt.packed else "stream-direct"
        if not pt.packed:
            # stream-direct serving: double-buffer the per-layer stream
            # uploads so transfer overlaps decode
            uploader = StreamUploader(pt)
        print(f"serving path: {mode} (int{args.bits})")
        adapter = PackedAdapter(cfg, pt, interpret=True, uploader=uploader)
    else:
        adapter = DenseAdapter(model, params)

    engine = Engine(adapter, EngineConfig(
        batch_size=args.batch_size, max_seq=args.max_seq,
        max_backlog=None, policy=args.policy))
    requests = []
    for uid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              rng.integers(2, 6)).tolist()
        requests.append(EngineRequest(uid=uid, prompt=prompt,
                                      max_new_tokens=args.max_new))
    if args.qps > 0:
        _run_open_loop(engine, requests, args.qps)
    else:
        for req in requests:
            engine.submit(req)
        engine.run_until_drained(max_steps=5000)
    stats = engine.stats
    if uploader is not None:
        print(f"stream uploads: {uploader.stats()}")
        uploader.close()
    print(f"completed={stats.completed}/{args.requests} "
          f"steps={stats.steps} tokens={stats.tokens_generated} "
          f"admitted={stats.admitted}")
    snap = engine.metrics.snapshot()
    lat = snap["latency"]["total"]
    thr = snap["throughput"]
    print(f"latency p50={lat['p50_s']*1e3:.1f}ms p99={lat['p99_s']*1e3:.1f}ms"
          f" tokens/s={thr['tokens_per_s']:.1f}"
          f" occupancy={thr['mean_batch_occupancy']:.2f}")
    if args.metrics_out:
        engine.metrics.to_json(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
