"""Serving launcher CLI (continuous batching; optional Iris-packed path).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --requests 6 --batch-size 2 --max-new 8 [--packed --bits 8]

`--packed` serves through the quantized dequant-on-load path
(models/quantized.py) for dense-family archs, prints the weight-stream
bytes-per-token comparison, and plans the per-layer Iris stream layouts
through the shared layout cache (one scheduler run for the whole uniform
stack; repeated requests with the same shapes never re-run the scheduler).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.model import Model
    from repro.runtime.serve_loop import Request, ServeLoop

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    if args.packed:
        from repro.models.quantized import (
            bytes_per_token_report,
            quantizable,
            quantize_params,
        )
        from repro.quant import QuantSpec

        if not quantizable(cfg):
            raise SystemExit(f"{cfg.name}: packed path covers dense archs")
        qspec = QuantSpec(bits=args.bits, group_size=32)
        pp = quantize_params(cfg, params, qspec)
        rep = bytes_per_token_report(cfg, pp)
        print(f"weight stream/token: packed={rep['packed_MiB']:.2f} MiB "
              f"padded-int={rep['padded_int_MiB']:.2f} "
              f"bf16={rep['bf16_MiB']:.2f} "
              f"({rep['bf16_MiB']/rep['packed_MiB']:.2f}x reduction)")

        # plan the per-layer Iris stream layouts through the façade: every
        # layer of a uniform stack is the same scheduling instance, so the
        # scheduler runs once and each further layer — and each repeated
        # request with the same shapes — is a cache hit
        from repro import api

        stack = api.plan_layer_stack(cfg, qspec)
        print(f"iris stream plan: {stack.n_layers} layers, "
              f"C_max={stack.c_max_per_layer}/layer, "
              f"B_eff={stack.b_eff:.4f}, "
              f"scheduler runs={stack.scheduler_runs} "
              f"cache hits={stack.cache_hits}")

        # compiled execution plan (one per layout signature, shared by
        # every layer through the layout cache): the whole stream decodes
        # with a single fused Pallas kernel per layer
        prog = stack.exec_program()
        print(f"exec program: pieces={prog.n_pieces}, "
              f"kernel lanes={prog.kernel.lanes}, "
              f"host-path arrays={len(prog.host_arrays)}, "
              f"pallas calls/decode={prog.n_pallas_calls}")

    loop = ServeLoop(model, params, batch_size=args.batch_size,
                     max_seq=args.max_seq)
    for uid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              rng.integers(2, 6)).tolist()
        loop.submit(Request(uid=uid, prompt=prompt,
                            max_new_tokens=args.max_new))
    stats = loop.run_until_drained(max_steps=5000)
    print(f"completed={stats.completed}/{args.requests} "
          f"steps={stats.steps} tokens={stats.tokens_generated} "
          f"admitted={stats.admitted}")


if __name__ == "__main__":
    main()
