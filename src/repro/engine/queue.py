"""Admission queue: the engine's front gate.

Requests enter the serving engine through one bounded queue.  Admission
is *explicitly* arbitrated — the queue either accepts a request or
rejects it with a machine-readable reason, so overload shows up as a
backpressure signal instead of unbounded memory growth:

* **bounded backlog** — at most ``max_backlog`` requests wait; the next
  submit is rejected with ``"backlog-full"`` (the caller sheds load or
  retries, the engine never buffers beyond its declared capacity);
* **deadlines** — a request may carry an absolute ``deadline`` (engine
  clock); one that cannot be admitted in time is rejected with
  ``"deadline-expired"``, at submit if already late, or lazily at pop
  when it went stale while waiting — serving a request whose caller has
  given up only burns decode slots;
* **priorities** — higher ``priority`` pops first; ties resolve in
  strict arrival order (FIFO), which is the fairness invariant
  tests/test_engine.py pins with a hypothesis property.

The queue knows nothing about models or slots; the
:class:`~repro.engine.scheduler.Engine` admit stage is its only
consumer, and the rejection log feeds
:class:`~repro.engine.metrics.EngineMetrics`.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Callable

__all__ = ["Admission", "AdmissionQueue", "EngineRequest",
           "REJECT_BACKLOG_FULL", "REJECT_DEADLINE_EXPIRED"]

#: rejection reasons (machine-readable; the metrics layer counts by them)
REJECT_BACKLOG_FULL = "backlog-full"
REJECT_DEADLINE_EXPIRED = "deadline-expired"


@dataclasses.dataclass
class EngineRequest:
    """One generation request.

    The first five fields match the legacy ``runtime.serve_loop.Request``
    dataclass, so pre-engine callers construct these unchanged; the rest
    is engine-level admission/observability state.
    """

    uid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: higher pops first; ties pop in arrival order
    priority: int = 0
    #: absolute engine-clock time by which the request must be *admitted*
    #: into a slot; ``None`` = never expires
    deadline: float | None = None
    #: "created" -> "queued" -> "active" -> "done" | "rejected"
    status: str = "created"
    reject_reason: str | None = None

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline


@dataclasses.dataclass(frozen=True)
class Admission:
    """Result of :meth:`AdmissionQueue.submit` (and engine ``submit``)."""

    accepted: bool
    reason: str | None = None     # rejection reason when not accepted
    backlog: int = 0              # queue depth after the decision

    def __bool__(self) -> bool:
        return self.accepted


class AdmissionQueue:
    """Bounded priority/FIFO admission queue with lazy deadline expiry.

    ``max_backlog=None`` means unbounded (the legacy ``ServeLoop``
    contract); the engine default is bounded.  All timestamps come from
    the injected ``clock`` so tests and simulations can run on virtual
    time.
    """

    def __init__(self, max_backlog: int | None = 64, *,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if max_backlog is not None and max_backlog <= 0:
            raise ValueError(f"max_backlog must be positive, got {max_backlog}")
        self.max_backlog = max_backlog
        self.clock = clock
        self._heap: list[tuple[int, int, EngineRequest]] = []
        self._seq = itertools.count()
        #: (uid, reason) in rejection order — the overflow audit trail
        self.rejections: list[tuple[int, str]] = []
        self.accepted = 0
        self.rejected_by_reason: dict[str, int] = {}

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._heap)

    def _reject(self, req: EngineRequest, reason: str) -> Admission:
        req.status = "rejected"
        req.reject_reason = reason
        self.rejections.append((req.uid, reason))
        self.rejected_by_reason[reason] = \
            self.rejected_by_reason.get(reason, 0) + 1
        return Admission(False, reason, backlog=len(self._heap))

    def submit(self, req: EngineRequest,
               now: float | None = None) -> Admission:
        """Admit ``req`` to the backlog, or reject it with a reason."""
        now = self.clock() if now is None else now
        if req.expired(now):
            return self._reject(req, REJECT_DEADLINE_EXPIRED)
        if self.max_backlog is not None and len(self._heap) >= self.max_backlog:
            return self._reject(req, REJECT_BACKLOG_FULL)
        heapq.heappush(self._heap, (-req.priority, next(self._seq), req))
        req.status = "queued"
        self.accepted += 1
        return Admission(True, backlog=len(self._heap))

    def pop(self, now: float | None = None) -> EngineRequest | None:
        """Highest-priority (then oldest) request that is still in
        deadline; stale requests encountered on the way are rejected."""
        now = self.clock() if now is None else now
        while self._heap:
            _, _, req = heapq.heappop(self._heap)
            if req.expired(now):
                self._reject(req, REJECT_DEADLINE_EXPIRED)
                continue
            return req
        return None

    def drain_expired(self, now: float | None = None) -> int:
        """Proactively reject every stale request; returns the count."""
        now = self.clock() if now is None else now
        keep = [(p, s, r) for p, s, r in self._heap if not r.expired(now)]
        n = len(self._heap) - len(keep)
        for p, s, r in self._heap:
            if r.expired(now):
                self._reject(r, REJECT_DEADLINE_EXPIRED)
        heapq.heapify(keep)
        self._heap = keep
        return n

    def snapshot(self) -> dict:
        return {
            "backlog": len(self._heap),
            "max_backlog": self.max_backlog,
            "accepted": self.accepted,
            "rejected": dict(self.rejected_by_reason),
        }
