"""Async double-buffered host->device stream uploads.

The stream-direct serving path reads each layer's packed Iris stream as
a flat uint32 device array (``kernels.stream_matmul``).  When the whole
model does not live on-device — the millions-of-users regime the
ROADMAP targets, where HBM holds a working set and host memory holds the
rest — every decode step must ship the next layer bundle up.  Done
naively that serializes transfer behind compute; the paper's bandwidth
argument (and the HLS dataflow literature it cites) says the stream only
pays off when it stays saturated.

:class:`StreamUploader` keeps it saturated with a classic two-deep
buffer ring:

* buffers are keyed by ``(manifest signature, layer)`` — trees that
  share a :class:`~repro.tree.LayoutManifest` signature share ring
  entries, mirroring how the layout cache dedupes plans;
* fetching layer ``L`` immediately schedules ``jax.device_put`` of
  layer ``L+1`` on a side thread, so the next bundle's transfer overlaps
  the current layer's matmuls;
* the ring holds ``depth`` (default 2) in-flight buffers; older entries
  fall out and their device memory is released — host->device traffic is
  bounded by two layer bundles regardless of model depth.

The uploader is the engine's ``stream_source``: calling it with a layer
index returns that layer's device words
(:func:`repro.models.quantized.packed_decode_step` consumes it directly).
Upload byte/hit counters feed :class:`~repro.engine.metrics.EngineMetrics`.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable

__all__ = ["BufferRing", "StreamUploader"]


class BufferRing:
    """FIFO ring of at most ``depth`` in-flight keyed buffers.

    Inserting beyond capacity evicts the oldest entry (its device buffer
    is dropped and garbage-collected).  ``get`` does not consume — the
    current layer's buffer stays resident while the next one uploads.
    """

    def __init__(self, depth: int = 2) -> None:
        if depth < 1:
            raise ValueError(f"ring depth must be >= 1, got {depth}")
        self.depth = depth
        self._entries: OrderedDict[Any, Any] = OrderedDict()
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Any) -> bool:
        return key in self._entries

    def get(self, key: Any) -> Any | None:
        return self._entries.get(key)

    def put(self, key: Any, value: Any) -> None:
        if key in self._entries:
            self._entries[key] = value
            self._entries.move_to_end(key)
            return
        self._entries[key] = value
        while len(self._entries) > self.depth:
            self._entries.popitem(last=False)
            self.evictions += 1

    def keys(self) -> list[Any]:
        return list(self._entries)


class StreamUploader:
    """Double-buffered host->device uploader over a ``PackedTree``.

    The tree's per-layer stream buffers stay on host (numpy); device
    copies materialize through the ring on demand.  One worker thread
    owns all ``device_put`` calls — uploads are serialized with each
    other (PCIe-order realistic) but overlap the caller's compute.

    Use as a context manager or call :meth:`close` to stop the worker.
    """

    def __init__(self, tree, *, depth: int = 2,
                 device_put: Callable[[Any], Any] | None = None) -> None:
        if tree.streams is None:
            raise ValueError(
                "tree was built with with_streams=False; stream uploads "
                "need the host stream buffers"
            )
        self.tree = tree
        self.n_layers = tree.manifest.n_layers
        #: ring keys lead with the manifest signature: trees sharing a
        #: layout signature share entries
        self._sig = tree.manifest.signature
        self.ring = BufferRing(depth)
        self._host: dict[int, Any] = {}
        self._lock = threading.Lock()
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="iris-stream-upload")
        if device_put is None:
            import jax
            device_put = jax.device_put
        self._device_put = device_put
        # counters (consumed by EngineMetrics via the engine)
        self.uploads = 0
        self.bytes_uploaded = 0
        self.prefetch_hits = 0
        self.sync_fetches = 0

    # ------------------------------------------------------------------
    def _host_words(self, layer: int):
        words = self._host.get(layer)
        if words is None:
            words = self.tree.host_stream_words(layer)
            self._host[layer] = words
        return words

    def _upload(self, layer: int):
        words = self._host_words(layer)
        out = self._device_put(words)
        with self._lock:
            self.uploads += 1
            self.bytes_uploaded += int(words.nbytes)
        return out

    def prefetch(self, layer: int) -> None:
        """Schedule layer ``layer``'s upload on the worker (idempotent
        while the buffer is still in the ring)."""
        layer = layer % self.n_layers
        key = (self._sig, layer)
        with self._lock:
            if key in self.ring:
                return
            fut = self._pool.submit(self._upload, layer)
            self.ring.put(key, fut)

    def __call__(self, layer: int):
        """Device words for ``layer`` — the engine's ``stream_source``.

        Blocks only if the buffer was never prefetched (cold start /
        ring evicted); before returning, schedules ``layer+1`` so its
        transfer rides under the caller's compute for this layer.
        """
        layer = layer % self.n_layers
        key = (self._sig, layer)
        with self._lock:
            entry = self.ring.get(key)
        if entry is None:
            self.sync_fetches += 1
            value = self._upload(layer)
            with self._lock:
                self.ring.put(key, value)
        else:
            if isinstance(entry, Future):
                value = entry.result()
                with self._lock:
                    # cache the resolved array (idempotent re-reads)
                    self.ring.put(key, value)
            else:
                value = entry
            self.prefetch_hits += 1
        if self.n_layers > 1:
            self.prefetch(layer + 1)
        return value

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "uploads": self.uploads,
            "bytes_uploaded": self.bytes_uploaded,
            "prefetch_hits": self.prefetch_hits,
            "sync_fetches": self.sync_fetches,
            "ring_depth": self.ring.depth,
            "ring_evictions": self.ring.evictions,
        }

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "StreamUploader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
