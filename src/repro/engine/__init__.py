"""repro.engine — continuous-batching packed serving engine.

The serving subsystem: a bounded admission queue with priorities and
deadlines (:mod:`~repro.engine.queue`), a stage-decoupled
continuous-batching scheduler over model adapters
(:mod:`~repro.engine.scheduler`), async double-buffered host->device
stream uploads (:mod:`~repro.engine.streams`), and per-request latency /
throughput metrics (:mod:`~repro.engine.metrics`).

Quickstart::

    from repro.engine import (DenseAdapter, Engine, EngineConfig,
                              EngineRequest)

    eng = Engine(DenseAdapter(model, params),
                 EngineConfig(batch_size=4, max_seq=128))
    eng.submit(EngineRequest(uid=0, prompt=[1, 2, 3], max_new_tokens=16))
    eng.run_until_drained()
    print(eng.metrics.to_json())

``runtime.serve_loop.ServeLoop`` is a deprecated thin wrapper over this
package.
"""
from .metrics import EngineMetrics, RequestTiming, percentile
from .queue import (
    REJECT_BACKLOG_FULL,
    REJECT_DEADLINE_EXPIRED,
    Admission,
    AdmissionQueue,
    EngineRequest,
)
from .scheduler import (
    STAGES,
    DenseAdapter,
    Engine,
    EngineConfig,
    PackedAdapter,
    ServeStats,
    greedy_sampler,
)
from .streams import BufferRing, StreamUploader

__all__ = [
    "Admission",
    "AdmissionQueue",
    "BufferRing",
    "DenseAdapter",
    "Engine",
    "EngineConfig",
    "EngineMetrics",
    "EngineRequest",
    "PackedAdapter",
    "REJECT_BACKLOG_FULL",
    "REJECT_DEADLINE_EXPIRED",
    "RequestTiming",
    "STAGES",
    "ServeStats",
    "StreamUploader",
    "greedy_sampler",
    "percentile",
]
