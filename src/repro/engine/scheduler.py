"""Stage-decoupled continuous-batching scheduler over packed weights.

The engine drives a fixed pool of decode *slots* through four explicit
stages every step — the event-driven issue/commit split of a hardware
pipeline, in host Python:

    admit      queue -> free slots (continuous: whenever a slot frees;
               static: only when the whole batch drained — the baseline
               bench_serve.py compares against)
    prefill    assemble the ragged token batch: prompt-phase slots feed
               their next prompt token, decode-phase slots feed their
               last sampled token
    decode     one adapter step over the *active* rows only (ragged M —
               the packed kernels pad internally, so a half-empty batch
               costs a half-size matmul, not a full one)
    retire     per-slot sampling, completion checks, slot release

Each stage is an overridable method with observation hooks
(:meth:`Engine.add_hook`), so admission policies, samplers and schedulers
swap without forking the loop.  Per-request timing flows into
:class:`~repro.engine.metrics.EngineMetrics` at every stage boundary.

Model access goes through an *adapter* so the engine is arch-agnostic:

* :class:`DenseAdapter` — ``Model.decode_step`` over the full slot batch
  (any family: dense/ssm/rwkv/moe), jitted once; the legacy
  ``ServeLoop`` semantics.
* :class:`PackedAdapter` — ``packed_decode_step`` over a
  :class:`~repro.tree.PackedTree`, stepping only the active rows
  (``slot_ids``) and optionally pulling per-layer stream words through a
  :class:`~repro.engine.streams.StreamUploader` so host->device uploads
  overlap decode.

Per-slot math is row-independent in every step path (matmuls, norms,
attention over per-row caches), so tokens generated under continuous
batching are **bit-identical** to a single-stream run of the same
request — the invariant tests/test_engine.py and bench_serve.py enforce.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import numpy as np

from .metrics import EngineMetrics
from .queue import Admission, AdmissionQueue, EngineRequest

__all__ = [
    "DenseAdapter", "Engine", "EngineConfig", "PackedAdapter",
    "ServeStats", "greedy_sampler",
]

#: engine stages, in execution order
STAGES = ("admit", "prefill", "decode", "retire")


def greedy_sampler(logits_row, request: EngineRequest) -> int:
    """Argmax over one slot's vocab row.

    The sampler contract is *per slot*: the engine hands each sampler
    call exactly one request's logits row.  The pre-engine loop's
    default sampler computed ``argmax`` over whatever array it was
    handed — flattened across the batch that returns an index into
    ``B*V``, i.e. another slot's token scaled out of vocab range — so
    this one refuses anything but a single row.
    """
    row = np.asarray(logits_row)
    if row.ndim != 1:
        raise ValueError(
            f"sampler expects one slot's logits row (1-D), got shape "
            f"{row.shape}; per-slot sampling is the engine's contract"
        )
    return int(row.argmax())


@dataclasses.dataclass
class ServeStats:
    """Legacy counter block (``runtime.serve_loop`` compatibility)."""

    steps: int = 0
    tokens_generated: int = 0
    completed: int = 0
    admitted: int = 0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine knobs."""

    batch_size: int
    max_seq: int
    #: queue capacity (None = unbounded, the legacy contract)
    max_backlog: int | None = 64
    #: "continuous" refills slots as they free; "static" waits for the
    #: whole batch to drain (the baseline continuous batching beats)
    policy: str = "continuous"
    eos_token: int | None = None

    def __post_init__(self) -> None:
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {self.batch_size}")
        if self.policy not in ("continuous", "static"):
            raise ValueError(
                f"policy must be 'continuous' or 'static', got {self.policy!r}"
            )


# ----------------------------------------------------------------------
# model adapters
# ----------------------------------------------------------------------
def _reset_state_slot(state: dict, i: int) -> None:
    """Zero slot ``i``'s clock and recurrent state in place.  Dense KV
    caches need no clearing: the per-row position mask hides stale
    entries.  Packed KV pages *are* cleared so page digests (and the
    checkpoint bytes built from them) are deterministic regardless of
    which request previously occupied the slot."""
    state["pos"] = state["pos"].at[i].set(0)
    if "packed_kv" in state:
        state["packed_kv"] = state["packed_kv"].reset(i)
    if "ssm" in state:
        state["ssm"] = state["ssm"].at[:, :, i].set(0.0)
    if "rwkv" in state:
        state["rwkv"] = state["rwkv"].at[:, i].set(0.0)
    for k in ("shift_t", "shift_c"):
        if k in state:
            state[k] = state[k].at[:, i].set(0.0)


class DenseAdapter:
    """Full-batch stepping over ``Model.decode_step`` (any arch family).

    Inactive rows step with token 0 and their results are discarded —
    the legacy ``ServeLoop`` semantics, kept so dense serving stays one
    jitted call per step with a stable trace.
    """

    def __init__(self, model, params) -> None:
        import jax

        self.model = model
        self.params = params
        self._step = jax.jit(model.decode_step)

    def init_state(self, batch_size: int, max_seq: int) -> dict:
        return self.model.init_decode_state(batch_size, max_seq)

    def reset_slot(self, state: dict, i: int) -> None:
        _reset_state_slot(state, i)

    def step(self, state: dict, tokens: np.ndarray,
             active: Sequence[int]) -> tuple[np.ndarray, dict]:
        """tokens: (n_active,) int32 aligned with ``active`` slot ids.
        Returns (logits rows aligned with ``active``, new state)."""
        import jax.numpy as jnp

        b = int(np.asarray(state["pos"]).shape[0])
        toks = np.zeros(b, dtype=np.int32)
        toks[list(active)] = tokens
        logits, state = self._step(self.params, state, jnp.asarray(toks),
                                   None)
        return np.asarray(logits, np.float32)[list(active)], state

    def stream_bytes_uploaded(self) -> int | None:
        return None                      # weights are resident


class PackedAdapter:
    """Ragged-M stepping over a :class:`~repro.tree.PackedTree`.

    Each step runs ``packed_decode_step`` with ``slot_ids`` = the active
    slots only: the batch the matmuls see has M = n_active rows (the
    kernels pad M internally), inactive rows cost nothing, and only
    active rows' clocks advance.  With ``uploader`` set, per-layer
    stream words come through the double-buffered
    :class:`~repro.engine.streams.StreamUploader` instead of resident
    device buffers — the next layer's transfer overlaps this layer's
    matmuls.

    ``kv="packed"`` swaps the dense per-slot K/V caches for a
    :class:`~repro.kvcache.PackedKVCache`: quantized token pages in the
    Iris-planned stream layout, appended through the device pack tables
    and consumed by the stream-direct attention kernel
    (``kv_attention="dense"`` keeps the packed pages but decodes them to
    a dense oracle first — the bit-identity verification path).
    """

    def __init__(self, cfg, tree, *, weights: str = "auto",
                 interpret: bool = True, uploader=None,
                 kv: str = "dense", kv_attention: str = "stream",
                 kv_bits: int | None = None, page_tokens: int = 8,
                 kv_m: int = 512) -> None:
        from repro.models.model import Model

        if kv not in ("dense", "packed"):
            raise ValueError(f"kv must be 'dense' or 'packed', got {kv!r}")
        if kv_attention not in ("stream", "dense"):
            raise ValueError(
                f"kv_attention must be 'stream' or 'dense', "
                f"got {kv_attention!r}")
        self.cfg = cfg
        self.tree = tree
        self.weights = weights
        self.interpret = interpret
        self.uploader = uploader
        self.kv = kv
        self.kv_attention = kv_attention
        self.kv_bits = kv_bits
        self.page_tokens = page_tokens
        self.kv_m = kv_m
        self._model = Model(cfg, remat="none")

    def init_state(self, batch_size: int, max_seq: int) -> dict:
        state = self._model.init_decode_state(batch_size, max_seq)
        if self.kv == "packed":
            from repro.kvcache import PackedKVCache

            bits = self.kv_bits if self.kv_bits is not None \
                else self.tree.spec.bits
            state["packed_kv"] = PackedKVCache.create(
                self.cfg, bits=bits, page_tokens=self.page_tokens,
                n_slots=batch_size, max_seq=max_seq, m=self.kv_m)
        return state

    def reset_slot(self, state: dict, i: int) -> None:
        _reset_state_slot(state, i)

    def step(self, state: dict, tokens: np.ndarray,
             active: Sequence[int]) -> tuple[np.ndarray, dict]:
        import jax.numpy as jnp

        from repro.models.quantized import packed_decode_step

        logits, state = packed_decode_step(
            self.cfg, self.tree, state, jnp.asarray(tokens, jnp.int32),
            interpret=self.interpret, weights=self.weights,
            slot_ids=jnp.asarray(list(active), jnp.int32),
            stream_source=self.uploader,
            kv=self.kv, kv_attention=self.kv_attention)
        return np.asarray(logits, np.float32), state

    def stream_bytes_uploaded(self) -> int | None:
        return self.uploader.bytes_uploaded if self.uploader else None

    def uploader_stats(self) -> dict | None:
        return self.uploader.stats() if self.uploader else None


# ----------------------------------------------------------------------
# the engine
# ----------------------------------------------------------------------
class Engine:
    """Multi-tenant continuous-batching serving engine.

    Typical use::

        eng = Engine(PackedAdapter(cfg, tree), EngineConfig(4, 128))
        eng.submit(EngineRequest(uid=0, prompt=[1, 2], max_new_tokens=8))
        eng.run_until_drained()
        eng.metrics.snapshot()          # p50/p99 latency, tokens/s, ...
    """

    def __init__(self, adapter, config: EngineConfig, *,
                 sampler: Callable[[Any, EngineRequest], int] = greedy_sampler,
                 queue: AdmissionQueue | None = None,
                 metrics: EngineMetrics | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 hooks: dict[str, list] | None = None) -> None:
        self.adapter = adapter
        self.config = config
        self.sampler = sampler
        self.clock = clock
        self.queue = queue if queue is not None else AdmissionQueue(
            config.max_backlog, clock=clock)
        self.metrics = metrics if metrics is not None \
            else EngineMetrics(clock=clock)
        # a fresh engine starts with a clean host-fallback dedup slate:
        # warnings a previous engine's run already surfaced must fire
        # again for this one, or a long-lived process silently reuses
        # host fallbacks across unrelated serving sessions
        try:
            from repro.kernels import layout_decode, layout_pack
        except ImportError:              # pragma: no cover - needs jax
            pass
        else:
            layout_decode.reset_host_fallback_warnings()
            layout_pack.reset_host_fallback_warnings()
        self.state = adapter.init_state(config.batch_size, config.max_seq)
        self.slots: list[EngineRequest | None] = [None] * config.batch_size
        self.slot_pos = np.zeros(config.batch_size, dtype=np.int64)
        self.hooks: dict[str, list] = {s: [] for s in STAGES}
        for stage, fns in (hooks or {}).items():
            for fn in fns:
                self.add_hook(stage, fn)
        self._stream_bytes_seen = 0
        # retire-order audit trail (slot-reuse invariants in tests)
        self.admission_order: list[int] = []
        self.completion_order: list[int] = []

    # -- introspection --------------------------------------------------
    def add_hook(self, stage: str,
                 fn: Callable[["Engine", str, dict], None]) -> None:
        """Register ``fn(engine, stage, ctx)`` to run after ``stage``."""
        if stage not in self.hooks:
            raise KeyError(f"unknown stage {stage!r}; stages are {STAGES}")
        self.hooks[stage].append(fn)

    def active_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    @property
    def n_active(self) -> int:
        return len(self.active_slots())

    @property
    def stats(self) -> ServeStats:
        """Legacy counter view (``runtime.serve_loop`` compatibility)."""
        m = self.metrics
        return ServeStats(steps=m.steps, tokens_generated=m.tokens_generated,
                          completed=m.completed, admitted=m.admitted)

    # -- request entry --------------------------------------------------
    def submit(self, req: EngineRequest) -> Admission:
        """Admit ``req`` to the backlog (or reject it with a reason)."""
        now = self.clock()
        self.metrics.record_submit(req.uid, now)
        adm = self.queue.submit(req, now)
        if not adm:
            self.metrics.record_reject(req.uid, adm.reason, now)
        return adm

    # -- stages ---------------------------------------------------------
    def _stage_admit(self, ctx: dict) -> None:
        """queue -> free slots, per the admission policy."""
        if self.config.policy == "static" and self.n_active:
            return                      # static batching: drain first
        now = self.clock()
        for i in range(self.config.batch_size):
            if self.slots[i] is not None:
                continue
            rejected0 = len(self.queue.rejections)
            req = self.queue.pop(now)
            # deadline expiries surfaced by pop land in the metrics too
            for uid, reason in self.queue.rejections[rejected0:]:
                self.metrics.record_reject(uid, reason, now)
            if req is None:
                break
            self.slots[i] = req
            self.slot_pos[i] = 0
            req.status = "active"
            self.adapter.reset_slot(self.state, i)
            self.metrics.record_admit(req.uid, now)
            self.admission_order.append(req.uid)
            ctx.setdefault("admitted", []).append((i, req.uid))

    def _stage_prefill(self, ctx: dict) -> None:
        """Assemble the ragged token batch for the active slots: prompt
        token for prompt-phase slots, last sampled token otherwise."""
        active = self.active_slots()
        toks = np.zeros(len(active), dtype=np.int32)
        for j, i in enumerate(active):
            req = self.slots[i]
            p = int(self.slot_pos[i])
            if p < len(req.prompt):
                toks[j] = req.prompt[p]
            elif req.generated:
                toks[j] = req.generated[-1]
        ctx["active"] = active
        ctx["tokens"] = toks

    def _stage_decode(self, ctx: dict) -> None:
        """One adapter step over the active rows (ragged M)."""
        active = ctx["active"]
        if not active:
            ctx["logits"] = np.zeros((0, 0), np.float32)
            return
        logits, self.state = self.adapter.step(self.state, ctx["tokens"],
                                               active)
        ctx["logits"] = logits
        self.metrics.record_step(len(active))
        uploaded = self.adapter.stream_bytes_uploaded()
        if uploaded is not None:
            self.metrics.record_stream_bytes(
                uploaded - self._stream_bytes_seen)
            self._stream_bytes_seen = uploaded
        stats_fn = getattr(self.adapter, "uploader_stats", None)
        stats = stats_fn() if stats_fn is not None else None
        if stats is not None:
            self.metrics.record_uploader_stats(stats)

    def _stage_retire(self, ctx: dict) -> None:
        """Per-slot sampling, completion checks, slot release."""
        now = self.clock()
        for j, i in enumerate(ctx["active"]):
            req = self.slots[i]
            self.slot_pos[i] += 1
            p = int(self.slot_pos[i])
            if p < len(req.prompt):
                continue                  # still consuming the prompt
            tok = self.sampler(ctx["logits"][j], req)
            if not req.generated:
                self.metrics.record_first_token(req.uid, now)
            req.generated.append(tok)
            self.metrics.record_token(req.uid)
            eos = self.config.eos_token
            if (len(req.generated) >= req.max_new_tokens
                    or (eos is not None and tok == eos)
                    or p >= self.config.max_seq - 1):
                req.done = True
                req.status = "done"
                self.metrics.record_complete(req.uid, now)
                self.completion_order.append(req.uid)
                self.slots[i] = None
                ctx.setdefault("retired", []).append((i, req.uid))

    # -- driving --------------------------------------------------------
    def step(self) -> dict:
        """Run one admit -> prefill -> decode -> retire cycle; returns
        the step context (admitted/active/tokens/retired)."""
        ctx: dict = {}
        for stage in STAGES:
            getattr(self, f"_stage_{stage}")(ctx)
            for fn in self.hooks[stage]:
                fn(self, stage, ctx)
        return ctx

    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active > 0

    def run_until_drained(self, max_steps: int = 10_000) -> ServeStats:
        """Step until queue and slots are empty (or ``max_steps``)."""
        steps0 = self.metrics.steps
        while self.has_work():
            if self.metrics.steps - steps0 >= max_steps:
                break
            self.step()
        return self.stats
