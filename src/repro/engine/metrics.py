"""Per-request serving metrics: the observability layer of the engine.

Every request is timed through four phases on the engine clock —

    submit --queue--> admit --prefill--> first_token --decode--> complete
      \\_________________________ total _________________________/

and the registry aggregates p50/p99/mean per phase plus engine-level
throughput counters (tokens/s, steps/s, stream-bytes/s).  The snapshot
is a plain JSON-able dict: ``benchmarks/bench_serve.py`` writes it into
``BENCH_serve.json``, ``launch/serve.py --metrics-out`` dumps it to a
file, and later PRs benchmark against the same schema.

Pure Python on purpose: no numpy/jax import, so the metrics layer rides
along anywhere the queue does (including the non-model hypothesis tests).
"""
from __future__ import annotations

import json
import time
from typing import Callable

__all__ = ["EngineMetrics", "RequestTiming", "percentile"]


def percentile(values: list[float], p: float) -> float:
    """Linear-interpolated percentile (numpy's default), ``p`` in [0, 100].

    Returns ``0.0`` for an empty sample so snapshots of an idle engine
    stay well-formed.
    """
    if not values:
        return 0.0
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    rank = (p / 100.0) * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


class RequestTiming:
    """Phase timestamps of one request (engine-clock seconds)."""

    __slots__ = ("uid", "submitted", "admitted", "first_token", "completed",
                 "n_tokens")

    def __init__(self, uid: int, submitted: float) -> None:
        self.uid = uid
        self.submitted = submitted
        self.admitted: float | None = None
        self.first_token: float | None = None
        self.completed: float | None = None
        self.n_tokens = 0

    # -- phase latencies (None until the closing timestamp lands) ------
    @property
    def queue_s(self) -> float | None:
        if self.admitted is None:
            return None
        return self.admitted - self.submitted

    @property
    def prefill_s(self) -> float | None:
        """Admission to first sampled token (prompt consumption)."""
        if self.first_token is None or self.admitted is None:
            return None
        return self.first_token - self.admitted

    @property
    def decode_s(self) -> float | None:
        if self.completed is None or self.first_token is None:
            return None
        return self.completed - self.first_token

    @property
    def total_s(self) -> float | None:
        if self.completed is None:
            return None
        return self.completed - self.submitted


class EngineMetrics:
    """Aggregating registry the engine stages report into."""

    def __init__(self, *, clock: Callable[[], float] = time.monotonic) -> None:
        self.clock = clock
        self.timings: dict[int, RequestTiming] = {}
        self.rejections: dict[str, int] = {}
        self.submitted = 0
        self.admitted = 0
        self.completed = 0
        self.rejected = 0
        self.steps = 0
        self.active_row_steps = 0        # sum over steps of active slots
        self.tokens_generated = 0
        self.stream_bytes = 0            # host->device stream upload bytes
        self.uploader_stats: dict = {}   # latest StreamUploader.stats()
        self._t0: float | None = None    # first submit (throughput window)
        self._t_last: float | None = None

    # -- recording hooks (one per engine stage event) -------------------
    def _touch(self, now: float) -> None:
        if self._t0 is None:
            self._t0 = now
        self._t_last = now

    def record_submit(self, uid: int, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        self._touch(now)
        self.timings[uid] = RequestTiming(uid, now)
        self.submitted += 1

    def record_reject(self, uid: int, reason: str,
                      now: float | None = None) -> None:
        now = self.clock() if now is None else now
        self._touch(now)
        self.rejections[reason] = self.rejections.get(reason, 0) + 1
        self.rejected += 1
        self.timings.pop(uid, None)      # rejected requests have no latency

    def record_admit(self, uid: int, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        self._touch(now)
        t = self.timings.get(uid)
        if t is not None and t.admitted is None:
            t.admitted = now
        self.admitted += 1

    def record_first_token(self, uid: int, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        self._touch(now)
        t = self.timings.get(uid)
        if t is not None and t.first_token is None:
            t.first_token = now

    def record_token(self, uid: int) -> None:
        self.tokens_generated += 1
        t = self.timings.get(uid)
        if t is not None:
            t.n_tokens += 1

    def record_complete(self, uid: int, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        self._touch(now)
        t = self.timings.get(uid)
        if t is not None and t.completed is None:
            t.completed = now
        self.completed += 1

    def record_step(self, n_active: int) -> None:
        self.steps += 1
        self.active_row_steps += n_active

    def record_stream_bytes(self, n: int) -> None:
        self.stream_bytes += n

    def record_uploader_stats(self, stats: dict) -> None:
        """Latest :meth:`StreamUploader.stats` counters (cumulative on
        the uploader side, so last-write-wins is the right merge)."""
        self.uploader_stats = dict(stats)

    # -- aggregation ----------------------------------------------------
    def _phase(self, attr: str) -> dict:
        xs = [getattr(t, attr) for t in self.timings.values()
              if getattr(t, attr) is not None]
        return {
            "n": len(xs),
            "p50_s": percentile(xs, 50),
            "p99_s": percentile(xs, 99),
            "mean_s": (sum(xs) / len(xs)) if xs else 0.0,
            "max_s": max(xs) if xs else 0.0,
        }

    def snapshot(self, now: float | None = None) -> dict:
        """The JSON-able metrics report (schema documented in DESIGN.md
        §Serving-engine).  ``elapsed_s`` spans first submit -> ``now``."""
        now = self.clock() if now is None else now
        t0 = self._t0 if self._t0 is not None else now
        elapsed = max(now - t0, 1e-9)
        batch = (self.active_row_steps / self.steps) if self.steps else 0.0
        return {
            "requests": {
                "submitted": self.submitted,
                "admitted": self.admitted,
                "completed": self.completed,
                "rejected": self.rejected,
                "rejected_by_reason": dict(self.rejections),
            },
            "latency": {
                "queue": self._phase("queue_s"),
                "prefill": self._phase("prefill_s"),
                "decode": self._phase("decode_s"),
                "total": self._phase("total_s"),
            },
            "throughput": {
                "elapsed_s": elapsed,
                "steps": self.steps,
                "steps_per_s": self.steps / elapsed,
                "tokens_generated": self.tokens_generated,
                "tokens_per_s": self.tokens_generated / elapsed,
                "goodput_tokens_per_s": sum(
                    t.n_tokens for t in self.timings.values()
                    if t.completed is not None) / elapsed,
                "mean_batch_occupancy": batch,
                "stream_bytes": self.stream_bytes,
                "stream_bytes_per_s": self.stream_bytes / elapsed,
                "uploader": dict(self.uploader_stats),
            },
        }

    def to_json(self, path: str | None = None, now: float | None = None,
                ) -> str:
        text = json.dumps(self.snapshot(now), indent=2) + "\n"
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text
