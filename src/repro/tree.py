"""`PackedTree`: the pytree-level front door for Iris-packed models.

The paper automates the *layout workflow*; this module automates it at
**parameter-tree granularity**.  One call —

    import repro.api as iris

    pt = iris.pack_tree(cfg, params, QuantSpec(bits=4))

— quantizes every large weight matrix, plans the per-layer Iris stream
layout through :func:`repro.api.plan_layer_stack` (one scheduler run for
the whole uniform stack, N-1 cache rebinds), packs the per-layer unified
HBM stream buffers, and returns a :class:`PackedTree` that the rest of
the toolchain composes with *as a pytree*:

* **jit / sharding** — ``PackedTree`` is registered with
  ``jax.tree_util`` (buffers as leaves, the static
  :class:`LayoutManifest` as aux_data), so it flows through ``jax.jit``,
  ``jax.device_put`` and ``NamedSharding`` unchanged.
* **serving** — ``models.quantized.packed_decode_step`` consumes the
  lane-packed kernel views (``.packed`` / ``.scales``) directly; no
  consumer re-wires quantize→plan→pack by hand.
* **checkpointing** — the per-layer stream buffers *are* the checkpoint
  (``checkpoint.save_packed``); the manifest records the layout
  signature and count-intervals, so :func:`unpack_streams` rebuilds the
  kernel views bit-identically on restore — rebinding the layout from
  the cache (or the manifest itself) without ever re-running the
  scheduler, and never materializing dense weights.

Two array-level representations coexist in the tree:

* ``streams`` — ``(n_layers, c_max, m/8)`` uint8: the unified Iris
  stream per layer, i.e. the storage/DMA byte order the paper generates
  (codes + scale bit-patterns + 16-bit norm slots, interleaved by the
  scheduler).  Canonical for checkpoint/transport.
* ``packed`` / ``scales`` — per-tensor lane-packed uint32 codes and
  group scales: the operand format of the dequant-on-load Pallas matmul
  (``kernels.packed_matmul``).  Canonical for the decode hot path.

Both are derived from the same element codes; ``unpack_streams`` proves
they stay interconvertible bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exec_plan import (
    ExecProgram,
    StreamTables,
    lower_exec,
    pack_compiled,
    stream_matmul_tables,
)
from repro.core.iris import DEFAULT_CACHE, LayoutCache
from repro.core.layout import Layout
from repro.core.packing import (
    BundleTensor,
    bundle_problem,
    pad_bundle_elements,
)
from repro.core.task import LayoutProblem
from repro.kernels.packed_matmul import SUPPORTED_BITS
from repro.quant.qtypes import QuantSpec, pack_codes_u32, quantize

__all__ = [
    "LayoutManifest", "PackedTree", "pack_tree", "unpack_streams",
]

#: weight names quantized in a dense decoder sublayer (bundle order)
_QUANT_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")

#: bundle tensor name -> quantized param key
_BUNDLE_TO_PARAM = {
    "wq": "attn/wq", "wk": "attn/wk", "wv": "attn/wv", "wo": "attn/wo",
    "w_gate": "mlp/w_gate", "w_up": "mlp/w_up", "w_down": "mlp/w_down",
}

#: bundle norm slot -> (other key, leaf key)
_BUNDLE_NORMS = {"attn_norm": "norm1", "mlp_norm": "norm2"}


def _to_tuple(x: Any) -> Any:
    """Recursively freeze lists (JSON round-trip) into hashable tuples."""
    if isinstance(x, (list, tuple)):
        return tuple(_to_tuple(v) for v in x)
    return x


# ----------------------------------------------------------------------
# the manifest: content-addressed static layout metadata
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayoutManifest:
    """Static description of how a :class:`PackedTree` is laid out.

    Everything a consumer needs to *rebind* — not re-derive — the layout:
    the bundle spec, the problem's content signature (the
    :class:`~repro.core.iris.LayoutCache` key) and the layout's
    count-intervals.  Frozen and hashable, so it rides through
    ``jax.jit`` as pytree aux_data; JSON-serializable, so it rides
    through checkpoints.  Restoring from a manifest never runs the
    scheduler: a warm cache answers by signature, a cold one is seeded
    from ``intervals``.
    """

    arch: str
    spec: QuantSpec
    shapes: tuple[tuple[str, tuple[int, int]], ...]  # quantized name -> (K, N)
    n_layers: int
    m: int
    c_max: int
    row_bytes: int
    bundle: tuple[BundleTensor, ...]
    signature: tuple                     # LayoutProblem.canonical_signature()
    intervals: tuple                     # Layout.count_intervals
    strategy: str = "iris"

    # -- layout resolution ---------------------------------------------
    def problem(self) -> LayoutProblem:
        return bundle_problem(list(self.bundle), m=self.m)

    def elem_widths(self) -> tuple[int, ...]:
        return tuple(b.width_bits for b in self.bundle)

    def resolve_layout(self, cache: LayoutCache | None = DEFAULT_CACHE,
                       ) -> tuple[Layout, str]:
        """The layout this manifest describes, **without scheduling**.

        Returns ``(layout, provenance)`` where provenance is
        ``"cache-hit"`` (the shared cache already held this scheduling
        instance — O(intervals) rebind) or ``"manifest"`` (layout rebuilt
        from the recorded count-intervals and seeded into the cache).

        Only ``"iris"`` manifests consult the cache: the
        :class:`~repro.core.iris.LayoutCache` is keyed on the problem's
        content signature alone, which for a baseline-strategy manifest
        would both return the *iris* layout for the same problem (wrong
        bit offsets for the recorded stream) and, on insert, poison the
        cache with a baseline layout under the signature iris plans
        resolve by.  Baseline layouts are O(intervals) to rebuild anyway.
        """
        prob = self.problem()
        if prob.canonical_signature() != self.signature:
            raise ValueError(
                "manifest signature does not match its bundle problem — "
                "manifest is corrupt or from an incompatible version"
            )
        use_cache = cache is not None and self.strategy == "iris"
        if use_cache:
            hit = cache.lookup(prob)
            if hit is not None:
                return hit, "cache-hit"
        lay = Layout.from_count_intervals(prob, self.intervals)
        lay.validate()
        if use_cache:
            cache.insert(prob, False, lay)
        return lay, "manifest"

    # -- (de)serialization: manifests ride inside checkpoint JSON ------
    def to_json_dict(self) -> dict:
        return {
            "arch": self.arch,
            "spec": dataclasses.asdict(self.spec),
            "shapes": [[n, list(s)] for n, s in self.shapes],
            "n_layers": self.n_layers,
            "m": self.m,
            "c_max": self.c_max,
            "row_bytes": self.row_bytes,
            "bundle": [dataclasses.asdict(b) for b in self.bundle],
            "signature": self.signature,
            "intervals": self.intervals,
            "strategy": self.strategy,
        }

    @staticmethod
    def from_json_dict(d: dict) -> "LayoutManifest":
        return LayoutManifest(
            arch=d["arch"],
            spec=QuantSpec(**d["spec"]),
            shapes=tuple((n, tuple(s)) for n, s in d["shapes"]),
            n_layers=int(d["n_layers"]),
            m=int(d["m"]),
            c_max=int(d["c_max"]),
            row_bytes=int(d["row_bytes"]),
            bundle=tuple(BundleTensor(**b) for b in d["bundle"]),
            signature=_to_tuple(d["signature"]),
            intervals=_to_tuple(d["intervals"]),
            strategy=d["strategy"],
        )

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict())

    @staticmethod
    def from_json(text: str) -> "LayoutManifest":
        return LayoutManifest.from_json_dict(json.loads(text))


# ----------------------------------------------------------------------
# the tree
# ----------------------------------------------------------------------
@jax.tree_util.register_pytree_with_keys_class
class PackedTree:
    """A parameter tree in Iris-packed form, registered as a JAX pytree.

    Children (dynamic leaves): ``packed`` (lane-packed uint32 kernel
    views), ``scales`` (group scales), ``other`` (embed / norms / biases
    — unquantized), ``streams`` (the per-layer unified Iris stream
    buffers, ``(n_layers, c_max, m/8)`` uint8, or ``None`` when built
    with ``with_streams=False``).  Aux_data (static): the
    :class:`LayoutManifest`.

    Because the manifest is hashable aux_data, a ``PackedTree`` passes
    through ``jax.jit`` boundaries, ``jax.device_put`` and
    ``NamedSharding`` placement like any parameter pytree.
    Layout/exec-program handles are *not* part of the tree: they resolve
    lazily through the content-addressed layout cache, so a tree that
    crossed a jit/transport boundary re-acquires them with zero
    scheduler runs.
    """

    def __init__(self, packed: dict, scales: dict, other: dict,
                 streams: Any, manifest: LayoutManifest, *,
                 provenance: str = "scheduled") -> None:
        self.packed = packed
        self.scales = scales
        self.other = other
        self.streams = streams
        self.manifest = manifest
        #: where this tree's layout came from: "scheduled", "cache-hit",
        #: "manifest" (checkpoint restore) or "pytree" (rebuilt by
        #: tree_unflatten, e.g. on the far side of a jit boundary)
        self.provenance = provenance
        self._layout: Layout | None = None
        self._program: ExecProgram | None = None
        # stream-direct matmul caches (static derivations, not leaves):
        # bit-offset tables per weight key, uint32 word view per layer
        self._stream_tabs: dict = {}
        self._stream_words: dict = {}

    # -- pytree protocol -----------------------------------------------
    def tree_flatten_with_keys(self):
        k = jax.tree_util.GetAttrKey
        children = (
            (k("packed"), self.packed),
            (k("scales"), self.scales),
            (k("other"), self.other),
            (k("streams"), self.streams),
        )
        return children, self.manifest

    @classmethod
    def tree_unflatten(cls, manifest, children):
        packed, scales, other, streams = children
        return cls(packed, scales, other, streams, manifest,
                   provenance="pytree")

    # -- compat surface (PackedParams fields) --------------------------
    @property
    def spec(self) -> QuantSpec:
        return self.manifest.spec

    @property
    def shapes(self) -> dict[str, tuple[int, int]]:
        return dict(self.manifest.shapes)

    @property
    def n_layers(self) -> int:
        return self.manifest.n_layers

    def hbm_bytes(self) -> int:
        """Serving-view footprint: lane-packed codes + scales + other."""
        b = sum(int(np.asarray(x).size) * 4 for x in self.packed.values())
        b += sum(int(np.asarray(x).size) * np.asarray(x).dtype.itemsize
                 for x in self.scales.values())
        b += sum(int(np.asarray(x).size) * np.asarray(x).dtype.itemsize
                 for x in jax.tree.leaves(self.other))
        return b

    @property
    def stream_bytes(self) -> int:
        """Total bytes of the unified per-layer Iris stream buffers."""
        return self.manifest.n_layers * self.manifest.c_max \
            * self.manifest.row_bytes

    # -- layout / program handles (lazy, cache-routed) ------------------
    def layout(self, cache: LayoutCache | None = DEFAULT_CACHE) -> Layout:
        """The per-layer stream :class:`Layout` (never re-scheduled)."""
        if self._layout is None:
            self._layout, prov = self.manifest.resolve_layout(cache)
            if self.provenance == "pytree":
                self.provenance = prov
        return self._layout

    def exec_program(self, cache: LayoutCache | None = DEFAULT_CACHE,
                     ) -> ExecProgram:
        """Compiled pack/decode program at bundle-element granularity."""
        if self._program is None:
            self._program = lower_exec(self.layout(cache),
                                       elem_widths=self.manifest.elem_widths())
        return self._program

    # -- stream-direct matmul (no dense intermediate) -------------------
    def stream_tables(self, key: str) -> StreamTables:
        """Bit-offset tables for quantized param ``key`` (e.g. "attn/wq").

        Memoized; all layers share one layout signature, hence one table
        per weight matrix for the whole stack.
        """
        tabs = self._stream_tabs.get(key)
        if tabs is None:
            shapes = dict(self.manifest.shapes)
            if key not in shapes:
                raise KeyError(
                    f"{key!r} is not a quantized tensor; have "
                    f"{sorted(shapes)}"
                )
            bname = key.split("/", 1)[1]
            tabs = stream_matmul_tables(
                self.layout(), bname, shapes[key],
                scales=f"{bname}_scales",
                group_size=self.manifest.spec.group_size,
                program=self.exec_program())
            self._stream_tabs[key] = tabs
        return tabs

    def host_stream_words(self, layer: int) -> np.ndarray:
        """Layer ``layer``'s stream as host uint32 words (no device copy).

        The upload-side twin of :meth:`layer_stream_words`: the engine's
        :class:`~repro.engine.streams.StreamUploader` reads these and
        owns the ``device_put`` itself, so the transfer can overlap
        decode on a side thread.
        """
        if self.streams is None:
            raise ValueError(
                "tree was built with with_streams=False; stream-"
                "direct execution needs the stream buffers"
            )
        prog = self.exec_program()
        return prog.buffer_words32(
            np.asarray(self.streams[layer])).reshape(-1)

    def layer_stream_words(self, layer: int):
        """Layer ``layer``'s stream as the flat uint32 kernel view."""
        import jax.numpy as jnp

        words = self._stream_words.get(layer)
        if words is None:
            words = jnp.asarray(self.host_stream_words(layer))
            self._stream_words[layer] = words
        return words

    def matmul_direct(self, x, key: str, layer: int, *,
                      interpret: bool = True, words=None, **block_kw):
        """``x @ dequant(key)`` gathered straight from layer ``layer``'s
        packed stream — the serving path that never materializes a dense
        weight intermediate, for any element width <= 32 (including the
        widths the lane-packed kernel views cannot represent).

        ``words`` overrides the stream word source: pass the layer's
        uint32 word view (e.g. from a
        :class:`~repro.engine.streams.StreamUploader`) to matmul against
        an externally staged buffer instead of the tree's resident copy.
        """
        import jax.numpy as jnp

        from repro.kernels.stream_matmul import stream_matmul

        tabs = self.stream_tables(key)
        if words is None:
            words = self.layer_stream_words(layer)
        return stream_matmul(
            x, words, jnp.asarray(tabs.w_tab),
            jnp.asarray(tabs.s_tab), bits=tabs.bits,
            group_size=tabs.group_size, interpret=interpret, **block_kw)

    # -- verification ---------------------------------------------------
    def verify(self, *, raise_on_error: bool = True, passes=None):
        """Statically verify this tree before serving or checkpointing.

        Runs the :mod:`repro.analysis` pass set over the manifest, the
        layout it rebinds, the lowered tables and the resident stream
        buffers.  Returns the :class:`~repro.analysis.Report`; with
        ``raise_on_error=True`` (default) any error-severity finding
        raises :class:`~repro.analysis.AnalysisError`.
        """
        from repro.analysis import verify_tree  # lazy: avoid cycle

        report = verify_tree(self, passes=passes)
        return report.raise_if_errors() if raise_on_error else report

    # -- reporting ------------------------------------------------------
    def summary(self) -> str:
        """One-line report: strategy, B_eff, buffer bytes, provenance."""
        man = self.manifest
        prob = man.problem()
        b_eff = prob.p_tot / (man.c_max * man.m)
        stream = "none" if self.streams is None \
            else f"{self.stream_bytes / 2**20:.2f} MiB"
        return (
            f"PackedTree[{man.arch}] int{man.spec.bits}/g{man.spec.group_size}"
            f" layers={man.n_layers} strategy={man.strategy}"
            f" B_eff={b_eff:.4f} stream={stream}"
            f" hbm={self.hbm_bytes() / 2**20:.2f} MiB"
            f" cache={self.provenance}"
        )

    def __repr__(self) -> str:
        return f"<{self.summary()}>"


# ----------------------------------------------------------------------
# forward: params -> PackedTree
# ----------------------------------------------------------------------
def _bits16(x: jax.Array) -> np.ndarray:
    """Bit pattern of a 16-bit float array as host uint64 elements."""
    if x.dtype.itemsize != 2:
        x = x.astype(jnp.bfloat16)
    u16 = jax.lax.bitcast_convert_type(x, jnp.uint16)
    return np.asarray(u16).reshape(x.shape[0], -1).astype(np.uint64)


def _layer_element_data(bundle, codes, scales16, norms16, layer: int,
                        ) -> dict[str, np.ndarray]:
    """Element streams for one layer, keyed by bundle tensor name."""
    data: dict[str, np.ndarray] = {}
    for b in bundle:
        if b.name in _BUNDLE_NORMS:
            data[b.name] = norms16[b.name][layer]
        elif b.name.endswith("_scales"):
            data[b.name] = scales16[b.name[:-len("_scales")]][layer]
        else:
            data[b.name] = codes[_BUNDLE_TO_PARAM[b.name]][layer] \
                .reshape(-1).astype(np.uint64)
    return data


def pack_tree(cfg, params: dict, spec: QuantSpec, *, m: int = 4096,
              strategy: str = "iris",
              cache: LayoutCache | None = DEFAULT_CACHE,
              with_streams: bool = True,
              with_kernel_views: bool | None = None,
              pack_backend: str = "numpy") -> PackedTree:
    """Quantize + plan + pack a parameter tree in one call.

    The front door the ISSUE's consumers share: serving
    (``launch.serve --packed``), checkpointing
    (``checkpoint.save_packed``) and the examples all call this instead
    of wiring quantize→plan→pack by hand.  Planning goes through
    :func:`repro.api.plan_layer_stack`, so a uniform stack costs one
    scheduler run (or zero on a warm cache) and N-1 rebinds.

    ``with_streams=False`` skips building the unified stream buffers
    (serving-only use; such a tree cannot be checkpointed packed, and
    cannot serve stream-direct).

    ``with_kernel_views`` controls the lane-packed uint32 views
    (``.packed``) consumed by the legacy two-pass ``packed_matmul``
    path.  ``None`` (default) builds them exactly when the bit width
    lane-packs (``32 % bits == 0``); other widths — int3, int5, ... —
    serve through :meth:`PackedTree.matmul_direct`, which reads the
    stream buffers directly, so the whole 2..8-bit range is end-to-end
    servable.  Forcing ``True`` for a non-lane width raises.

    ``pack_backend`` selects how the per-layer stream rows are packed:
    ``"numpy"`` (default) is the vectorized host
    :func:`~repro.core.exec_plan.pack_compiled`; ``"pallas"`` the fused
    device kernel (:func:`~repro.kernels.layout_pack.pack_layout_fused`)
    — bit-identical, so ``save_packed`` checkpoints are byte-equal
    either way.
    """
    from repro import api  # deferred: repro.api lazy-loads this module
    from repro.models.quantized import quantizable  # deferred: no cycle

    lane_packable = spec.bits in SUPPORTED_BITS
    if with_kernel_views is None:
        with_kernel_views = lane_packable
    if with_kernel_views and not lane_packable:
        raise ValueError(
            f"lane-packed kernel views need bits in "
            f"{sorted(SUPPORTED_BITS)}; got {spec.bits} — serve it "
            "stream-direct (with_kernel_views=False)"
        )
    if not with_kernel_views and not with_streams:
        raise ValueError(
            "with_kernel_views=False and with_streams=False leaves "
            "nothing servable"
        )
    if not quantizable(cfg):
        raise NotImplementedError(
            f"pack_tree covers dense-family archs; {cfg.name} is not"
        )

    # -- quantize every large matrix of the (uniform) decoder stack ----
    blocks = params["blocks"][0]
    codes: dict[str, np.ndarray] = {}     # param key -> (L, K, N) uint8
    packed: dict[str, Any] = {}
    scales: dict[str, Any] = {}
    shapes: dict[str, tuple[int, int]] = {}
    other: dict[str, Any] = {
        "embed": params["embed"],
        "final_norm": params["final_norm"],
        "norm1": blocks["norm1"],
        "norm2": blocks["norm2"],
    }
    if "unembed" in params:
        other["unembed"] = params["unembed"]
    for sub in ("attn", "mlp"):
        for name, w in blocks[sub].items():
            if name not in _QUANT_NAMES:
                other[f"{sub}/{name}"] = w      # biases stay dense
                continue
            k = f"{sub}/{name}"
            qt = jax.vmap(lambda wl: quantize(wl, spec))(w)
            if with_kernel_views:
                packed[k] = jax.vmap(
                    lambda c: pack_codes_u32(c, spec.bits))(qt.codes)
            scales[k] = qt.scales
            shapes[k] = tuple(int(d) for d in w.shape[1:])
            if with_streams:
                codes[k] = np.asarray(qt.codes)

    # -- plan the per-layer stream layout through the façade -----------
    stack = api.plan_layer_stack(cfg, spec, m=m, strategy=strategy,
                                 cache=cache)
    lay = stack.plans[0].layout
    manifest = LayoutManifest(
        arch=cfg.name,
        spec=spec,
        shapes=tuple(sorted(shapes.items())),
        n_layers=stack.n_layers,
        m=m,
        c_max=lay.c_max,
        row_bytes=m // 8,
        bundle=stack.bundle,
        signature=lay.problem.canonical_signature(),
        intervals=lay.count_intervals,
        strategy=strategy,
    )
    # "scheduled" / "cache-hit" for iris, "closed-form" for baselines
    provenance = stack.plans[0].provenance

    # -- pack the unified per-layer HBM streams ------------------------
    streams = None
    if with_streams:
        if spec.scale_dtype not in ("bfloat16", "float16"):
            raise ValueError(
                f"stream packing stores 16-bit scale slots; scale_dtype "
                f"{spec.scale_dtype!r} is not 16-bit"
            )
        prog = stack.exec_program()
        if pack_backend == "pallas":
            from repro.kernels.layout_pack import pack_layout_fused

            def _pack_row(data):
                return pack_layout_fused(lay, data, program=prog)
        elif pack_backend == "numpy":
            def _pack_row(data):
                return pack_compiled(lay, data, program=prog)
        else:
            raise NotImplementedError(
                f"pack_backend {pack_backend!r}; use 'numpy' or 'pallas'"
            )
        scales16 = {k[len("attn/"):] if k.startswith("attn/")
                    else k[len("mlp/"):]: _bits16(v)
                    for k, v in scales.items()}
        norms16 = {name: _bits16(other[key]["scale"])
                   for name, key in _BUNDLE_NORMS.items()}
        rows = []
        for layer in range(stack.n_layers):
            data = _layer_element_data(stack.bundle, codes, scales16,
                                       norms16, layer)
            padded = pad_bundle_elements(stack.problem, prog, data)
            rows.append(_pack_row(padded))
        streams = jnp.asarray(np.stack(rows))

    pt = PackedTree(packed=packed, scales=scales, other=other,
                    streams=streams, manifest=manifest,
                    provenance=provenance)
    pt._layout = lay
    return pt


# ----------------------------------------------------------------------
# inverse: streams -> kernel views (checkpoint restore)
# ----------------------------------------------------------------------
def unpack_streams(manifest: LayoutManifest, streams: Any, other: dict, *,
                   cache: LayoutCache | None = DEFAULT_CACHE) -> PackedTree:
    """Rebuild a :class:`PackedTree` from its stream buffers.

    The checkpoint-restore path: the layout is *rebound* from the cache
    (or rebuilt from the manifest's count-intervals) — the scheduler
    never runs — and the lane-packed kernel views are regenerated from
    the stream bytes **bit-identically** (codes and scale bit patterns
    round-trip exactly; dense weights are never materialized).
    """
    lay, provenance = manifest.resolve_layout(cache)
    prog = lower_exec(lay, elem_widths=manifest.elem_widths())
    streams = np.asarray(streams)
    n_layers = manifest.n_layers
    if streams.shape[0] != n_layers:
        raise ValueError(
            f"streams has {streams.shape[0]} layers, manifest says {n_layers}"
        )
    names = [a.name for a in lay.problem.arrays]
    idx = {n: i for i, n in enumerate(names)}
    shapes = dict(manifest.shapes)
    spec = manifest.spec
    g = spec.group_size

    # one vectorized unpack per layer, then slice per tensor
    per_layer = [prog.unpack_indexed(streams[layer])
                 for layer in range(n_layers)]

    # lane-packed kernel views only exist for widths pack_codes_u32 can
    # represent; other widths serve stream-direct off the buffers
    lane_packable = spec.bits in SUPPORTED_BITS
    packed: dict[str, Any] = {}
    scales: dict[str, Any] = {}
    for key, (kk, nn) in shapes.items():
        bname = key.split("/", 1)[1]
        ci, si = idx[bname], idx[f"{bname}_scales"]
        layer_scales = np.stack([
            per_layer[la][si][:(kk // g) * nn]
            .astype(np.uint16).reshape(kk // g, nn)
            for la in range(n_layers)])
        if lane_packable:
            layer_codes = np.stack([
                per_layer[la][ci][:kk * nn].reshape(kk, nn).astype(np.uint8)
                for la in range(n_layers)])
            packed[key] = jax.vmap(
                lambda c: pack_codes_u32(c, spec.bits))(
                    jnp.asarray(layer_codes))
        scales[key] = jax.lax.bitcast_convert_type(
            jnp.asarray(layer_scales), jnp.dtype(spec.scale_dtype))
    pt = PackedTree(packed=packed, scales=scales, other=other,
                    streams=jnp.asarray(streams), manifest=manifest,
                    provenance=provenance)
    pt._layout = lay
    pt._program = prog
    return pt


# ----------------------------------------------------------------------
# deprecated alias support (models.quantized re-exports this)
# ----------------------------------------------------------------------
def _warn_packed_params() -> type[PackedTree]:
    warnings.warn(
        "PackedParams is deprecated; it is now an alias of "
        "repro.api.PackedTree — build one with repro.api.pack_tree()",
        DeprecationWarning, stacklevel=3,
    )
    return PackedTree
