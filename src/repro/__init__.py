"""Iris reproduction: automatic data layouts for high bandwidth utilization.

``import repro`` is intentionally light (numpy only) and exposes the one
thing consumers need: the :mod:`repro.api` pipeline façade (including
the pytree-level ``api.pack_tree`` / ``api.PackedTree`` front door).
The JAX/Pallas kernels, model zoo and launchers load lazily on first use
(e.g. ``plan.decode(buf, backend="pallas")``).

The pre-façade top-level re-exports (``repro.schedule``,
``repro.Layout``, ...) are kept alive for compatibility but emit a
``DeprecationWarning`` naming the :mod:`repro.api` replacement; deeper
module paths (``repro.core.iris.schedule`` etc.) remain stable,
warning-free import targets.
"""
from __future__ import annotations

import importlib
import warnings

from . import api

#: deprecated top-level aliases: name -> (defining module, replacement)
_DEPRECATED = {
    # problem spec
    "ArraySpec": ("repro.core.task", "repro.api.ArraySpec"),
    "LayoutProblem": ("repro.core.task", "repro.api.LayoutProblem"),
    "make_problem": ("repro.core.task", "repro.api.make_problem"),
    "PAPER_EXAMPLE": ("repro.core.task", "repro.api.PAPER_EXAMPLE"),
    "INV_HELMHOLTZ": ("repro.core.task", "repro.api.INV_HELMHOLTZ"),
    "matmul_problem": ("repro.core.task", "repro.api.matmul_problem"),
    # scheduler + cache
    "schedule": ("repro.core.iris", "repro.api.plan(problem).layout"),
    "schedule_many": ("repro.core.iris", "repro.api.plan_many"),
    "LayoutCache": ("repro.core.iris", "repro.core.iris.LayoutCache"),
    "DEFAULT_CACHE": ("repro.core.iris", "repro.core.iris.DEFAULT_CACHE"),
    # layout IR & baselines
    "Layout": ("repro.core.layout", "repro.core.layout.Layout"),
    "LayoutMetrics": ("repro.core.layout",
                      "repro.core.layout.LayoutMetrics"),
    "naive_layout": ("repro.core.baselines",
                     "repro.api.plan(problem, strategy='naive')"),
    "homogeneous_layout": ("repro.core.baselines",
                           "repro.api.plan(problem, "
                           "strategy='homogeneous')"),
    "hls_padded_layout": ("repro.core.baselines",
                          "repro.api.plan(problem, "
                          "strategy='hls_padded')"),
    "ALL_BASELINES": ("repro.core.baselines", "repro.api.STRATEGIES"),
}


def __getattr__(name: str):
    """Serve (and deprecate) the pre-façade compat aliases lazily."""
    try:
        mod_path, repl = _DEPRECATED[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}"
        ) from None
    warnings.warn(
        f"repro.{name} is deprecated; use {repl}",
        DeprecationWarning, stacklevel=2,
    )
    return getattr(importlib.import_module(mod_path), name)


def _find_version() -> str:
    """Package version, sourced from installed metadata or pyproject.toml.

    Running from a source tree (``PYTHONPATH=src``) has no installed
    distribution, so fall back to parsing the adjacent pyproject.toml.
    """
    import contextlib

    with contextlib.suppress(Exception):
        from importlib.metadata import version
        return version("iris-repro")
    import pathlib
    import re
    pyproject = pathlib.Path(__file__).resolve().parents[2] / "pyproject.toml"
    with contextlib.suppress(OSError):
        m = re.search(r'^version\s*=\s*"([^"]+)"', pyproject.read_text(),
                      re.MULTILINE)
        if m:
            return m.group(1)
    return "0.0.0+unknown"


__version__ = _find_version()

__all__ = [
    "__version__", "api",
    # deprecated compat aliases (DeprecationWarning on access)
    *sorted(_DEPRECATED),
]
