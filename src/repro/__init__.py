"""Iris reproduction: automatic data layouts for high bandwidth utilization.

``import repro`` is intentionally light (numpy only) and exposes the two
things most consumers need: the :mod:`repro.api` pipeline façade and the
curated core types.  The JAX/Pallas kernels, model zoo and launchers
load lazily on first use (e.g. ``plan.decode(buf, backend="pallas")``).
"""
from __future__ import annotations

from . import api
from .core import (
    ALL_BASELINES,
    DEFAULT_CACHE,
    INV_HELMHOLTZ,
    PAPER_EXAMPLE,
    ArraySpec,
    Layout,
    LayoutCache,
    LayoutMetrics,
    LayoutProblem,
    hls_padded_layout,
    homogeneous_layout,
    make_problem,
    matmul_problem,
    naive_layout,
    schedule,
    schedule_many,
)


def _find_version() -> str:
    """Package version, sourced from installed metadata or pyproject.toml.

    Running from a source tree (``PYTHONPATH=src``) has no installed
    distribution, so fall back to parsing the adjacent pyproject.toml.
    """
    try:
        from importlib.metadata import version
        return version("iris-repro")
    except Exception:
        pass
    import pathlib
    import re
    pyproject = pathlib.Path(__file__).resolve().parents[2] / "pyproject.toml"
    try:
        m = re.search(r'^version\s*=\s*"([^"]+)"', pyproject.read_text(),
                      re.MULTILINE)
        if m:
            return m.group(1)
    except OSError:
        pass
    return "0.0.0+unknown"


__version__ = _find_version()

__all__ = [
    "__version__", "api",
    # problem spec
    "ArraySpec", "LayoutProblem", "make_problem",
    "PAPER_EXAMPLE", "INV_HELMHOLTZ", "matmul_problem",
    # scheduler + cache
    "schedule", "schedule_many", "LayoutCache", "DEFAULT_CACHE",
    # layout IR & baselines
    "Layout", "LayoutMetrics",
    "naive_layout", "homogeneous_layout", "hls_padded_layout",
    "ALL_BASELINES",
]
