"""One front door for the Iris layout pipeline.

The paper's pitch is that Iris *automates* the layout workflow; this
module is that workflow as a single call.  :func:`plan` turns a
:class:`~repro.core.task.LayoutProblem` into a lazy :class:`Plan` that
carries the schedule, metrics, decode program and packed buffers behind
one uniform surface:

    import repro.api as iris

    p = iris.plan(iris.PAPER_EXAMPLE)            # strategy="iris"
    p.metrics.row()                              # C_max / L_max / B_eff
    buf = p.pack(codes)                          # host-side organization
    out = p.decode(buf, backend="pallas")        # accelerator-side read
    src = p.emit(target="c")                     # HLS read_data module

Two registries make the pipeline pluggable:

* **strategies** (:data:`STRATEGIES`) map a problem to a
  :class:`~repro.core.layout.Layout` — ``"iris"`` (the scheduler) plus
  the paper's baselines ``"naive"``, ``"homogeneous"``,
  ``"hls_padded"``.  Sweeps and comparisons iterate the registry
  (:func:`compare`) instead of importing one function per family.
* **backends** (:data:`BACKENDS`) execute a plan — ``"numpy"`` is the
  reference bit-gatherer, ``"pallas"`` the TPU kernel path (interpret
  mode off-TPU), ``"c"`` emits the paper's Listing 1/2 HLS source.
  ``plan.decode`` normalizes every backend's output to uint64 numpy
  arrays, so cross-backend equivalence is plain ``np.array_equal``.

Scheduling routes through the content-addressed
:class:`~repro.core.iris.LayoutCache` (the process-wide
``DEFAULT_CACHE``) by default: repeated problems — every layer of a
uniform stack, every repeated serving request — never re-run the
scheduler.  Only the ``"iris"`` strategy consults the cache; baselines
are closed-form and cheaper than a lookup.

Everything here is importable without JAX; the ``"pallas"`` backend
loads the kernel package on first use.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from .core.baselines import ALL_BASELINES
from .core.codegen import (
    DecodePlan,
    decode_plan,
    emit_c_decode,
    emit_c_pack,
    pack_arrays,
    random_codes,
    unpack_arrays,
)
from .core.exec_plan import (
    ExecProgram,
    StreamTables,
    lower_exec,
    pack_compiled,
    stream_matmul_tables,
    unpack_compiled,
)
from .core.iris import DEFAULT_CACHE, LayoutCache, schedule, schedule_many
from .core.layout import Layout, LayoutMetrics
from .core.registry import Registry
from .core.task import (
    INV_HELMHOLTZ,
    PAPER_EXAMPLE,
    ArraySpec,
    LayoutProblem,
    make_problem,
    matmul_problem,
)

__all__ = [
    "ArraySpec", "LayoutProblem", "make_problem", "random_codes",
    "PAPER_EXAMPLE", "INV_HELMHOLTZ", "matmul_problem",
    "Backend", "Plan", "LayerStackPlan",
    "STRATEGIES", "BACKENDS", "strategies", "backends",
    "plan", "plan_many", "compare", "plan_layer_stack",
    "ExecProgram", "lower_exec", "pack_compiled", "unpack_compiled",
    "StreamTables", "stream_matmul_tables",
    # pytree-level front door (loads JAX lazily on first access)
    "PackedTree", "pack_tree", "unpack_streams", "LayoutManifest",
]

#: attributes served lazily from repro.tree so that ``import repro.api``
#: stays numpy-only; the PackedTree machinery needs JAX (pytree
#: registration, device placement)
_TREE_EXPORTS = ("PackedTree", "pack_tree", "unpack_streams",
                 "LayoutManifest")


def __getattr__(name: str):
    if name in _TREE_EXPORTS:
        from . import tree as _tree
        return getattr(_tree, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ----------------------------------------------------------------------
# strategy registry: name -> (problem, **knobs) -> Layout
# ----------------------------------------------------------------------
#: Layout strategies.  A strategy is ``fn(problem, *, mode,
#: fill_residual, cache) -> Layout``; closed-form baselines ignore the
#: scheduling knobs.
STRATEGIES: Registry[Callable[..., Layout]] = Registry("strategy")


def _register_baseline(name: str, fn: Callable[[LayoutProblem], Layout]):
    def run(problem: LayoutProblem, *, mode: str = "auto",
            fill_residual: bool = False,
            cache: LayoutCache | None = None) -> Layout:
        # closed-form baseline: the scheduling knobs don't apply, and it
        # is cheaper than a cache lookup
        return fn(problem)

    run.__name__ = f"strategy_{name}"
    run.__doc__ = fn.__doc__
    STRATEGIES.register(name, run)


for _name, _fn in ALL_BASELINES.items():
    _register_baseline(_name, _fn)
STRATEGIES.register("iris", schedule)


# ----------------------------------------------------------------------
# backend registry: execution targets for a Plan
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Backend:
    """One execution target for a :class:`Plan`.

    ``decode(plan, buf, **kw)`` reverses the packed buffer into per-array
    code streams; ``emit(plan, **kw)`` renders source code.  A backend
    may support either or both; unset capabilities raise
    ``NotImplementedError`` with the backends that do support them.
    """

    name: str
    decode: Callable[..., dict[str, np.ndarray]] | None = None
    emit: Callable[..., str] | None = None


def _as_u64(out: dict[str, Any]) -> dict[str, np.ndarray]:
    """Normalize backend output to uint64 numpy arrays (cross-backend
    equality is then plain ``np.array_equal``)."""
    return {k: np.asarray(v).astype(np.uint64) for k, v in out.items()}


# backend callables take explicit keywords only — a misspelled option
# must raise TypeError, not silently fall back to a default
def _decode_numpy(pl: "Plan", buf: np.ndarray, *,
                  compiled: bool = True) -> dict[str, np.ndarray]:
    if compiled:
        return _as_u64(unpack_compiled(pl.layout, np.asarray(buf),
                                       program=pl.exec_program))
    return _as_u64(unpack_arrays(pl.layout, np.asarray(buf)))


def _decode_pallas(pl: "Plan", buf: np.ndarray, *,
                   interpret: bool = True,
                   fused: bool = True) -> dict[str, np.ndarray]:
    from .kernels.ops import decode_layout  # lazy: pulls in JAX

    if fused:
        return _as_u64(decode_layout(pl.layout, buf, interpret=interpret,
                                     fused=True, program=pl.exec_program))
    return _as_u64(decode_layout(pl.layout, buf, interpret=interpret,
                                 fused=False, plan=pl.decode_plan))


def _emit_c(pl: "Plan", *, artifact: str = "decode",
            word_bits: int = 64) -> str:
    # no **kw passthrough: a misspelled option must fail, not silently
    # emit default-width source
    if artifact == "decode":
        return emit_c_decode(pl.layout)
    if artifact == "pack":
        return emit_c_pack(pl.layout, word_bits=word_bits)
    if artifact == "both":
        return (emit_c_pack(pl.layout, word_bits=word_bits)
                + "\n\n" + emit_c_decode(pl.layout))
    raise ValueError(
        f"unknown C artifact {artifact!r}; expected 'pack', 'decode' or 'both'"
    )


#: Execution backends.
BACKENDS: Registry[Backend] = Registry("backend")
BACKENDS.register("numpy", Backend("numpy", decode=_decode_numpy))
BACKENDS.register("pallas", Backend("pallas", decode=_decode_pallas))
BACKENDS.register("c", Backend("c", emit=_emit_c))


def strategies() -> list[str]:
    """Registered strategy names, registration order (iris last)."""
    return STRATEGIES.names()


def backends() -> list[str]:
    """Registered backend names."""
    return BACKENDS.names()


# ----------------------------------------------------------------------
# the Plan object
# ----------------------------------------------------------------------
class Plan:
    """Lazy handle over one (problem, strategy) layout pipeline.

    Nothing is scheduled at construction (the strategy name is validated
    eagerly so typos fail fast); the layout materializes on first access
    to :attr:`layout` / :attr:`metrics` / :attr:`decode_plan` and is
    memoized, as are the derived artifacts.  ``cache`` defaults to the
    process-wide :data:`~repro.core.iris.DEFAULT_CACHE`, so identical
    problems across Plans share one scheduler run.
    """

    def __init__(self, problem: LayoutProblem, strategy: str = "iris", *,
                 mode: str = "auto", fill_residual: bool = False,
                 cache: LayoutCache | None = DEFAULT_CACHE) -> None:
        self._strategy_fn = STRATEGIES.get(strategy)   # fail fast on typos
        self.problem = problem
        self.strategy = strategy
        self.mode = mode
        self.fill_residual = fill_residual
        self.cache = cache
        self._layout: Layout | None = None
        self._metrics: LayoutMetrics | None = None
        self._decode_plan: DecodePlan | None = None
        self._exec_program: ExecProgram | None = None
        self._provenance: str | None = None
        self._stream_tables: dict = {}

    # -- lazy pipeline stages ------------------------------------------
    @property
    def layout(self) -> Layout:
        """The scheduled :class:`Layout` (computed on first access)."""
        if self._layout is None:
            hits0 = self.cache.hits if self.cache is not None else 0
            self._layout = self._strategy_fn(
                self.problem, mode=self.mode,
                fill_residual=self.fill_residual, cache=self.cache,
            )
            if self.strategy != "iris":
                self._provenance = "closed-form"
            elif self.cache is not None and self.cache.hits > hits0:
                self._provenance = "cache-hit"
            else:
                self._provenance = "scheduled"
        return self._layout

    @property
    def provenance(self) -> str:
        """Where the layout came from: ``"scheduled"``, ``"cache-hit"``
        or ``"closed-form"`` (``"unscheduled"`` before first access)."""
        return self._provenance or "unscheduled"

    @property
    def metrics(self) -> LayoutMetrics:
        """Paper metrics (C_max, L_max, B_eff, FIFO depths) of the layout."""
        if self._metrics is None:
            self._metrics = self.layout.metrics()
        return self._metrics

    @property
    def decode_plan(self) -> DecodePlan:
        """Static decode program (paper Listing 2 as a table)."""
        if self._decode_plan is None:
            self._decode_plan = decode_plan(self.layout)
        return self._decode_plan

    @property
    def exec_program(self) -> ExecProgram:
        """Compiled execution plan (flat pack/unpack tables + the fused
        Pallas kernel's slot table).  Lowered once per layout signature:
        the program cache lives on the layout and is shared across
        :class:`~repro.core.iris.LayoutCache` rebinds, so a cache hit
        returns a plan whose program is already built."""
        if self._exec_program is None:
            self._exec_program = lower_exec(self.layout)
        return self._exec_program

    @property
    def c_max(self) -> int:
        return self.layout.c_max

    @property
    def stream_bytes(self) -> int:
        """Size of the packed unified buffer in bytes."""
        return self.layout.c_max * self.problem.m // 8

    # -- uniform execution surface -------------------------------------
    def pack(self, arrays: dict[str, np.ndarray], *,
             compiled: bool = True, backend: str = "numpy") -> np.ndarray:
        """Pack per-array codes into the unified ``(c_max, m/8)`` buffer
        (paper Listing 1).

        ``backend="numpy"`` (default) packs host-side: the vectorized
        :class:`~repro.core.exec_plan.ExecProgram` when ``compiled=True``,
        the legacy per-slot reference path otherwise.
        ``backend="pallas"`` runs the fused device pack kernel
        (:func:`~repro.kernels.layout_pack.pack_layout_fused`, imported
        lazily so this module stays importable without JAX).  All paths
        are bit-identical.
        """
        if backend == "pallas":
            from repro.kernels.layout_pack import pack_layout_fused

            return pack_layout_fused(self.layout, arrays,
                                     program=self.exec_program)
        if backend != "numpy":
            raise NotImplementedError(
                f"backend {backend!r} cannot pack; use 'numpy' or 'pallas'"
            )
        if compiled:
            return pack_compiled(self.layout, arrays,
                                 program=self.exec_program)
        return pack_arrays(self.layout, arrays)

    def decode(self, buf: np.ndarray, backend: str = "numpy",
               **kw: Any) -> dict[str, np.ndarray]:
        """Decode a packed buffer through a registered backend.

        Returns ``{name: uint64 ndarray}`` regardless of backend, so
        outputs compare bit-for-bit across backends.
        """
        b = BACKENDS.get(backend)
        if b.decode is None:
            can = [n for n in BACKENDS if BACKENDS.get(n).decode is not None]
            raise NotImplementedError(
                f"backend {backend!r} cannot decode; use one of {can}"
            )
        return b.decode(self, buf, **kw)

    def emit(self, target: str = "c", **kw: Any) -> str:
        """Emit source for a registered backend (e.g. the HLS C module).

        ``target="c"`` accepts ``artifact="decode" | "pack" | "both"``.
        """
        b = BACKENDS.get(target)
        if b.emit is None:
            can = [n for n in BACKENDS if BACKENDS.get(n).emit is not None]
            raise NotImplementedError(
                f"backend {target!r} cannot emit source; use one of {can}"
            )
        return b.emit(self, **kw)

    # -- stream-direct execution ----------------------------------------
    def stream_tables(self, weights: int | str, shape: tuple[int, int], *,
                      scales: int | str, group_size: int,
                      elem_widths: tuple[int, ...] | None = None,
                      ) -> StreamTables:
        """Bit-offset tables for one ``(K, N)`` stream-direct matmul.

        Memoized per (operands, shape, granularity) — serving calls hit
        the table once per weight matrix, not per token.
        """
        key = (weights, scales, shape, group_size, elem_widths)
        tabs = self._stream_tables.get(key)
        if tabs is None:
            prog = self.exec_program if elem_widths is None \
                else lower_exec(self.layout, elem_widths=elem_widths)
            tabs = stream_matmul_tables(
                self.layout, weights, shape, scales=scales,
                group_size=group_size, program=prog)
            self._stream_tables[key] = tabs
        return tabs

    def matmul_direct(self, x, buf, weights: int | str,
                      shape: tuple[int, int], *, scales: int | str,
                      group_size: int,
                      elem_widths: tuple[int, ...] | None = None,
                      interpret: bool = True, **block_kw):
        """``x @ dequant(weights)`` straight out of the packed stream.

        The stream-direct exec surface: no dense intermediate ever
        materializes — the Pallas matmul prologue gathers packed words
        from ``buf`` against this plan's slot tables
        (:mod:`repro.kernels.stream_matmul`).  ``buf`` is the packed
        ``(c_max, m/8)`` uint8 buffer (or a precomputed uint32 stream
        from :func:`repro.kernels.stream_matmul.stream_words`).
        """
        import jax.numpy as jnp  # lazy: pulls in JAX

        from .kernels.stream_matmul import stream_matmul, stream_words

        tabs = self.stream_tables(weights, shape, scales=scales,
                                  group_size=group_size,
                                  elem_widths=elem_widths)
        buf = np.asarray(buf) if not hasattr(buf, "dtype") else buf
        if buf.dtype == np.uint8:
            prog = self.exec_program if elem_widths is None \
                else lower_exec(self.layout, elem_widths=elem_widths)
            buf = stream_words(prog, np.asarray(buf))
        return stream_matmul(x, buf, jnp.asarray(tabs.w_tab),
                             jnp.asarray(tabs.s_tab), bits=tabs.bits,
                             group_size=group_size, interpret=interpret,
                             **block_kw)

    # -- conveniences ---------------------------------------------------
    def validate(self) -> "Plan":
        """Validate the layout (legal, complete transfer plan); chainable."""
        self.layout.validate()
        return self

    def verify(self, *, raise_on_error: bool = True, passes=None):
        """Run the static layout analyzer over this plan's layout and
        lowered tables (:mod:`repro.analysis`).

        Returns the :class:`~repro.analysis.Report`; with
        ``raise_on_error=True`` (default) any error-severity finding
        raises :class:`~repro.analysis.AnalysisError` naming the rule —
        "verify before you serve".
        """
        from .analysis import verify_layout  # lazy: keep api import lean

        report = verify_layout(
            self.layout, program=self.exec_program, passes=passes,
            subject=f"Plan[{self.strategy}]")
        return report.raise_if_errors() if raise_on_error else report

    def render(self, max_cycles: int = 64) -> str:
        """ASCII rendering in the style of the paper's Figs. 3-5."""
        return self.layout.render(max_cycles=max_cycles)

    def summary(self) -> str:
        """One-line report: strategy, size, B_eff, buffer bytes and cache
        provenance (forces scheduling).  Used by serve.py's reporting."""
        m = self.metrics
        return (
            f"Plan[{self.strategy}] m={self.problem.m}"
            f" arrays={len(self.problem.arrays)}"
            f" C_max={m.c_max} B_eff={m.efficiency:.4f}"
            f" stream={self.stream_bytes / 2**10:.1f} KiB"
            f" cache={self.provenance}"
        )

    def __repr__(self) -> str:
        if self._layout is None:
            return (
                f"Plan({self.strategy!r}, m={self.problem.m}, "
                f"n_arrays={len(self.problem.arrays)}, unscheduled)"
            )
        return f"<{self.summary()}>"


def plan(problem: LayoutProblem, strategy: str = "iris", *,
         mode: str = "auto", fill_residual: bool = False,
         cache: LayoutCache | None = DEFAULT_CACHE) -> Plan:
    """Build a lazy :class:`Plan` for ``problem`` under ``strategy``.

    The one front door: every consumer — examples, sweeps, serving,
    benchmarks — goes through here.  Unknown strategies raise a
    ``KeyError`` listing the registered names.
    """
    return Plan(problem, strategy, mode=mode, fill_residual=fill_residual,
                cache=cache)


def plan_many(problems: Sequence[LayoutProblem], strategy: str = "iris", *,
              mode: str = "auto", fill_residual: bool = False,
              cache: LayoutCache | None = DEFAULT_CACHE) -> list[Plan]:
    """Batch :func:`plan`: problems sharing a canonical signature are
    scheduled once (``cache=None`` still dedupes within the batch via an
    ephemeral cache, mirroring :func:`~repro.core.iris.schedule_many`)."""
    if cache is None:
        cache = LayoutCache(maxsize=max(1, len(problems)))
    return [
        Plan(p, strategy, mode=mode, fill_residual=fill_residual, cache=cache)
        for p in problems
    ]


def compare(problem: LayoutProblem,
            strategies: Sequence[str] | None = None, *,
            mode: str = "auto", fill_residual: bool = False,
            cache: LayoutCache | None = DEFAULT_CACHE,
            ) -> dict[str, LayoutMetrics]:
    """Metrics per strategy — the paper's Figs. 3-5 / Tables 6-7 columns.

    Iterates the whole strategy registry unless ``strategies`` narrows it.
    """
    names = list(strategies) if strategies is not None else STRATEGIES.names()
    return {
        name: plan(problem, name, mode=mode, fill_residual=fill_residual,
                   cache=cache).metrics
        for name in names
    }


# ----------------------------------------------------------------------
# layer-stack planning (the serving hot path)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayerStackPlan:
    """Per-layer Iris stream plans for a uniform decoder stack.

    Every layer of a uniform stack poses the same scheduling instance, so
    the scheduler runs at most once; further layers are cache rebinds.
    ``scheduler_runs`` / ``cache_hits`` are the deltas incurred by this
    call (a warm cache yields ``scheduler_runs == 0``).
    """

    problem: LayoutProblem          # one layer's bundle problem
    bundle: tuple                   # the BundleTensors the problem encodes
    plans: tuple[Plan, ...]         # one resolved Plan per layer

    scheduler_runs: int
    cache_hits: int

    @property
    def n_layers(self) -> int:
        return len(self.plans)

    @property
    def c_max_per_layer(self) -> int:
        return self.plans[0].c_max

    @property
    def b_eff(self) -> float:
        return self.plans[0].metrics.efficiency

    @property
    def stream_bytes_per_layer(self) -> int:
        return self.plans[0].stream_bytes

    def exec_program(self) -> ExecProgram:
        """Compiled execution plan at *bundle-element* granularity.

        Lowered with each tensor's ``width_bits`` as the piece width, so
        bundle data packs/decodes at element granularity even when the
        scheduled unit width exceeds 64 bits.  All layers share one
        layout signature, hence one program (cached on the layout)."""
        ew = tuple(b.width_bits for b in self.bundle)
        return lower_exec(self.plans[0].layout, elem_widths=ew)

    def stream_tables(self, name: str,
                      shape: tuple[int, int]) -> StreamTables:
        """Stream-direct matmul tables for bundle tensor ``name``.

        Resolves the paired ``{name}_scales`` tensor and derives the
        quantization group size from the bundle element counts, so
        callers hand in only the weight name and its ``(K, N)`` shape.
        All layers share the tables (one layout signature).
        """
        by_name = {b.name: b for b in self.bundle}
        if name not in by_name:
            raise KeyError(f"no bundle tensor named {name!r}")
        sname = f"{name}_scales"
        if sname not in by_name:
            raise KeyError(f"bundle tensor {name!r} has no paired scales")
        w, s = by_name[name], by_name[sname]
        k, n = shape
        if k * n != w.n_elems:
            raise ValueError(
                f"{name}: shape {shape} has {k * n} elements, bundle "
                f"holds {w.n_elems}"
            )
        if w.n_elems % s.n_elems:
            raise ValueError(
                f"{name}: scale count {s.n_elems} does not divide "
                f"weight count {w.n_elems}"
            )
        group_size = w.n_elems // s.n_elems
        ew = tuple(b.width_bits for b in self.bundle)
        return self.plans[0].stream_tables(
            name, shape, scales=sname, group_size=group_size,
            elem_widths=ew)

    def matmul_direct(self, x, buf, name: str, shape: tuple[int, int], *,
                      interpret: bool = True, **block_kw):
        """Stream-direct ``x @ dequant(name)`` against one layer's buffer.

        ``buf`` is that layer's packed stream (uint8 rows or a
        precomputed uint32 word stream).  Any bundle element width <= 32
        works — including the widths ``packed_matmul`` cannot lane-pack.
        """
        tabs = self.stream_tables(name, shape)
        group_size = tabs.group_size
        ew = tuple(b.width_bits for b in self.bundle)
        return self.plans[0].matmul_direct(
            x, buf, name, shape, scales=f"{name}_scales",
            group_size=group_size, elem_widths=ew, interpret=interpret,
            **block_kw)


def plan_layer_stack(cfg, qspec, *, m: int = 4096,
                     n_layers: int | None = None, mode: str = "auto",
                     strategy: str = "iris",
                     cache: LayoutCache | None = DEFAULT_CACHE,
                     bundle=None,
                     ) -> LayerStackPlan:
    """Plan the per-layer weight-stream layouts for a model config.

    ``cfg`` is any object with ``d_model / d_ff / n_heads / n_kv_heads /
    head_dim`` (and ``n_layers`` unless passed explicitly); ``qspec`` is
    the weight :class:`~repro.quant.qtypes.QuantSpec`.  The internal
    engine of :func:`pack_tree`, and shared by
    ``repro.launch.serve --packed`` and
    :func:`repro.core.packing.serving_stream_report`.  Every layer of a
    uniform stack poses the same scheduling instance: ``"iris"`` costs
    one scheduler run (or zero on a warm cache) plus N-1 rebinds;
    baseline strategies are closed-form and computed once outright.

    ``bundle`` overrides the scheduled tensor set: any sequence of
    :class:`~repro.core.packing.BundleTensor` replaces the default
    per-layer weight bundle while keeping the shared planning/cache
    path — how ``repro.kvcache`` plans its per-page KV stream once and
    rebinds it across every layer's pages.
    """
    from .core.packing import bundle_problem, layer_bundle_spec  # lazy

    if bundle is None:
        bundle = layer_bundle_spec(cfg.d_model, cfg.d_ff, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim, qspec)
    prob = bundle_problem(bundle, m=m)
    n = int(cfg.n_layers if n_layers is None else n_layers)
    if n <= 0:
        raise ValueError(f"n_layers must be positive, got {n}")
    local = cache if cache is not None else LayoutCache(maxsize=1)
    hits0, misses0 = local.hits, local.misses
    if strategy == "iris":
        layouts = schedule_many([prob] * n, mode=mode, cache=local)
    else:
        lay0 = plan(prob, strategy, mode=mode, cache=None).layout
        layouts = [lay0] * n
    plans = []
    for i, lay in enumerate(layouts):
        pl = Plan(prob, strategy, mode=mode, cache=local)
        pl._layout = lay
        if strategy != "iris":
            pl._provenance = "closed-form"
        else:
            pl._provenance = "cache-hit" if (i or local.misses == misses0) \
                else "scheduled"
        plans.append(pl)
    # every layer shares the first layout's count runs; validating one
    # validates the stack (and catches scheduler regressions before any
    # consumer reports metrics off an illegal plan)
    plans[0].validate()
    return LayerStackPlan(
        problem=prob,
        bundle=tuple(bundle),
        plans=tuple(plans),
        scheduler_runs=local.misses - misses0,
        cache_hits=local.hits - hits0,
    )
