"""Elastic scaling: reshard a training state onto a different mesh.

Checkpoints are mesh-free host numpy (see checkpoint.py), so elastic
rescale = restore with the new mesh's shardings.  ``reshard_live`` handles
the in-memory path (planned shrink/grow without a filesystem round-trip):
device_get + re-place, per leaf, using the target shardings.

At 1000+ nodes the flow is: the cluster manager detects a lost pod,
re-forms the mesh from the survivors (e.g. 512 -> 256 chips), calls
``reshard_live`` (or restores the last checkpoint), and training resumes —
the batch shardings, FSDP shards and EP placement all follow the new mesh
because every sharding in this codebase is *derived from the mesh at jit
time*, never hard-coded.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np


def reshard_live(tree: Any, new_shardings: Any) -> Any:
    """Re-place every leaf of ``tree`` with the corresponding sharding."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shards = jax.tree_util.tree_leaves(new_shardings)
    if len(leaves) != len(shards):
        raise ValueError("tree/sharding structure mismatch")
    out = []
    for x, s in zip(leaves, shards):
        host = np.asarray(x)
        out.append(jax.make_array_from_callback(
            host.shape, s, lambda idx, a=host: a[idx]))
    return jax.tree_util.tree_unflatten(treedef, out)


def validate_resharding(old_tree: Any, new_tree: Any) -> None:
    """Bitwise check that a reshard preserved every value."""
    for a, b in zip(jax.tree.leaves(old_tree), jax.tree.leaves(new_tree)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise AssertionError("resharding changed tensor contents")
