"""Pipeline parallelism: GPipe-style microbatching over a 'stage' mesh axis.

Opt-in runtime feature (the production meshes use DP x TP; PP composes on
top for >2-pod deployments where a model's layers exceed one pod's HBM).
The schedule is the classic loop: with S stages and M microbatches, run
S + M - 1 ticks; in tick t, stage s processes microbatch t - s.  The
stage-to-stage handoff is a ``jax.lax.ppermute`` over the 'stage' axis
inside ``shard_map`` — the TPU-native equivalent of NCCL send/recv.

Bubble fraction = (S - 1) / (S + M - 1); the tests assert the schedule
produces exactly that many idle slots and that the pipelined forward
matches the single-device reference bitwise (f32).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    n_microbatches: int

    @property
    def n_ticks(self) -> int:
        return self.n_stages + self.n_microbatches - 1

    @property
    def bubble_fraction(self) -> float:
        return (self.n_stages - 1) / self.n_ticks


def pipeline_forward(stage_fn: Callable, mesh: Mesh, cfg: PipelineConfig,
                     stage_params, x_microbatches: jax.Array) -> jax.Array:
    """Run microbatches through a linear pipeline of stages.

    stage_fn(params_for_stage, x) -> x           (same shape)
    stage_params: pytree with leading dim n_stages (sharded over 'stage')
    x_microbatches: (M, mb, ...) microbatched input (replicated)
    Returns (M, mb, ...) outputs after all stages.
    """
    s, m = cfg.n_stages, cfg.n_microbatches
    assert x_microbatches.shape[0] == m

    def per_stage(params, xs):
        # params: stage-local (leading dim 1); xs: (M, mb, ...) replicated
        params = jax.tree.map(lambda a: a[0], params)
        stage_id = jax.lax.axis_index("stage")
        mb_shape = xs.shape[1:]
        # carries must be 'stage'-varying from the start (shard_map vma typing)
        buf = jax.lax.pvary(jnp.zeros(mb_shape, xs.dtype), ("stage",))
        outs = jax.lax.pvary(jnp.zeros_like(xs), ("stage",))

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others use the carry
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0,
                                                  keepdims=False)
            cur = jnp.where(stage_id == 0,
                            jnp.where(t < m, inject, jnp.zeros_like(buf)),
                            buf)
            active = (t >= stage_id) & (t - stage_id < m)
            y = stage_fn(params, cur)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # the last stage writes finished microbatch t - (S-1)
            out_idx = jnp.clip(t - (s - 1), 0, m - 1)
            done = (stage_id == s - 1) & (t >= s - 1)
            updated = jax.lax.dynamic_update_index_in_dim(
                outs, y, out_idx, 0)
            outs = jnp.where(done, updated, outs)
            # hand off to the next stage (ring permute; last->first unused)
            nxt = jax.lax.ppermute(
                y, "stage", [(i, (i + 1) % s) for i in range(s)])
            return nxt, outs

        buf, outs = jax.lax.fori_loop(0, cfg.n_ticks, tick, (buf, outs))
        # only the last stage holds real outputs; share them back
        return jax.lax.psum(
            jnp.where(stage_id == s - 1, outs, jnp.zeros_like(outs)),
            "stage")

    fn = jax.jit(
        jax.shard_map(
            per_stage, mesh=mesh,
            in_specs=(P("stage"), P()),
            out_specs=P(),
        ))
    return fn(stage_params, x_microbatches)


def schedule_table(cfg: PipelineConfig) -> list[list[int | None]]:
    """tick x stage table of microbatch ids (None = bubble) — for tests
    and the DESIGN.md illustration."""
    table = []
    for t in range(cfg.n_ticks):
        row = []
        for stg in range(cfg.n_stages):
            mb = t - stg
            row.append(mb if 0 <= mb < cfg.n_microbatches else None)
        table.append(row)
    return table
