"""Fault-tolerant training loop.

Production concerns handled here (and unit-tested in
tests/test_runtime.py):

* **checkpoint/restart** — async CheckpointManager every
  ``ckpt_interval`` steps, data-pipeline state inside the checkpoint,
  automatic resume from the latest complete step on (re)start;
* **node-failure recovery** — a step that raises is retried from the last
  checkpoint up to ``max_restarts`` times (the same path a rescheduled
  pod takes after a hardware failure);
* **straggler mitigation** — per-step wall-time EWMA; steps slower than
  ``straggler_factor`` x the EWMA are logged and counted, and a pluggable
  ``on_straggler`` hook lets the cluster layer replace the slow host
  (here: the hook is invoked; in tests we assert it fires);
* **NaN/overflow guard** — non-finite loss skips the update (the state
  from the previous step is kept) rather than poisoning the run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticLMPipeline


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_interval: int = 25
    keep_n: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    log_interval: int = 10


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_loss: float
    losses: list
    restarts: int
    stragglers: int
    skipped_nonfinite: int
    resumed_from: int | None


def run_training(
    step_fn: Callable[[Any, dict], tuple[Any, dict]],
    init_state_fn: Callable[[], Any],
    pipeline: SyntheticLMPipeline,
    ckpt_dir: str,
    cfg: TrainLoopConfig = TrainLoopConfig(),
    on_straggler: Callable[[int, float], None] | None = None,
    fail_injector: Callable[[int], None] | None = None,
    to_batch: Callable[[dict], dict] | None = None,
) -> TrainReport:
    """Drive ``step_fn`` to ``total_steps`` with full fault handling.

    ``fail_injector(step)`` (tests only) may raise to simulate node loss.
    """
    mgr = CheckpointManager(ckpt_dir, keep_n=cfg.keep_n)
    state = init_state_fn()
    resumed_from = None
    latest = mgr.latest_step()
    if latest is not None:
        state, extra = mgr.restore(state, step=latest)
        pipeline.load_state_dict(extra["pipeline"])
        resumed_from = latest

    losses: list[float] = []
    restarts = stragglers = skipped = 0
    ewma: float | None = None
    step = pipeline.state.step

    while step < cfg.total_steps:
        t0 = time.monotonic()
        try:
            if fail_injector is not None:
                fail_injector(step)
            batch = pipeline.next_batch()
            if to_batch is not None:
                batch = to_batch(batch)
            new_state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                skipped += 1
                step += 1
                continue                      # keep previous state
            state = new_state
            losses.append(loss)
        except KeyboardInterrupt:             # pragma: no cover
            raise
        except Exception:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            # node failure path: reload last good checkpoint + data state
            latest = mgr.latest_step()
            state = init_state_fn()
            if latest is not None:
                state, extra = mgr.restore(state, step=latest)
                pipeline.load_state_dict(extra["pipeline"])
            else:
                pipeline.load_state_dict({"seed": pipeline.state.seed,
                                          "step": 0})
            step = pipeline.state.step
            continue

        dt = time.monotonic() - t0
        if ewma is not None and dt > cfg.straggler_factor * ewma:
            stragglers += 1
            if on_straggler is not None:
                on_straggler(step, dt)
        if len(losses) >= 2:
            # seed the EWMA from the second step on: step 1 carries jit
            # compilation and would mask real stragglers for many steps
            ewma = dt if ewma is None else (
                cfg.ewma_alpha * dt + (1 - cfg.ewma_alpha) * ewma)

        step += 1
        if step % cfg.ckpt_interval == 0 or step == cfg.total_steps:
            mgr.save_async(step, state,
                           extra={"pipeline": pipeline.state_dict()})
    mgr.wait()
    return TrainReport(
        steps_run=len(losses),
        final_loss=losses[-1] if losses else float("nan"),
        losses=losses,
        restarts=restarts,
        stragglers=stragglers,
        skipped_nonfinite=skipped,
        resumed_from=resumed_from,
    )
