"""Batched serving loop with continuous batching and Iris-packed weights.

The serving runtime drives ``Model.decode_step`` over a slot-based request
batch: finished sequences release their slot, queued requests are admitted
into free slots (continuous batching), and the KV/SSM state is reused
in place.  With ``packed_weights=True`` the parameters are int-quantized,
laid out by the Iris scheduler into unified per-layer stream buffers, and
decoded on the fly — the paper's technique as a first-class serving
feature (see core/packing.py; bytes-moved accounting is reported by the
benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    steps: int = 0
    tokens_generated: int = 0
    completed: int = 0
    admitted: int = 0


class ServeLoop:
    """Slot-based continuous batching over a fixed decode batch."""

    def __init__(self, model, params, batch_size: int, max_seq: int,
                 eos_token: int | None = None,
                 sample: Callable[[jax.Array, int], int] | None = None):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.eos = eos_token
        self.sample = sample or (lambda logits, uid: int(jnp.argmax(logits)))
        self.state = model.init_decode_state(batch_size, max_seq)
        self.slots: list[Request | None] = [None] * batch_size
        self.slot_pos = np.zeros(batch_size, dtype=np.int64)
        self.queue: list[Request] = []
        self.stats = ServeStats()
        self._step = jax.jit(model.decode_step)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.batch_size):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.slot_pos[i] = 0
                self._reset_slot(i)
                self.stats.admitted += 1

    def _reset_slot(self, i: int) -> None:
        """Zero slot i's clock and recurrent state (KV needs no clearing:
        the per-row position mask hides stale entries)."""
        st = self.state
        st["pos"] = st["pos"].at[i].set(0)
        if "ssm" in st:
            st["ssm"] = st["ssm"].at[:, :, i].set(0.0)
        if "rwkv" in st:
            st["rwkv"] = st["rwkv"].at[:, i].set(0.0)
        for k in ("shift_t", "shift_c"):
            if k in st:
                st[k] = st[k].at[:, i].set(0.0)

    def _next_tokens(self) -> np.ndarray:
        toks = np.zeros(self.batch_size, dtype=np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            p = int(self.slot_pos[i])
            if p < len(req.prompt):
                toks[i] = req.prompt[p]
            elif req.generated:
                toks[i] = req.generated[-1]
        return toks

    def step(self) -> None:
        """One decode step across all active slots."""
        self._admit()
        toks = jnp.asarray(self._next_tokens())
        logits, self.state = self._step(self.params, self.state, toks, None)
        self.stats.steps += 1
        logits_np = np.asarray(logits, dtype=np.float32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slot_pos[i] += 1
            p = int(self.slot_pos[i])
            if p < len(req.prompt):
                continue                      # still consuming the prompt
            tok = self.sample(logits_np[i], req.uid)
            req.generated.append(tok)
            self.stats.tokens_generated += 1
            if (len(req.generated) >= req.max_new_tokens
                    or (self.eos is not None and tok == self.eos)
                    or p >= self.max_seq - 1):
                req.done = True
                self.stats.completed += 1
                self.slots[i] = None

    def run_until_drained(self, max_steps: int = 10_000) -> ServeStats:
        while (any(s is not None for s in self.slots) or self.queue):
            if self.stats.steps >= max_steps:
                break
            self.step()
        return self.stats
