"""Deprecated: the serving loop moved to :mod:`repro.engine`.

``ServeLoop`` was the pre-engine slot-based continuous-batching loop.
Its whole surface now lives in the engine subsystem — bounded admission
queue with priorities/deadlines (:mod:`repro.engine.queue`), stage-
decoupled scheduler with swappable policies
(:mod:`repro.engine.scheduler`), async stream uploads
(:mod:`repro.engine.streams`) and per-request metrics
(:mod:`repro.engine.metrics`).

This module keeps the legacy names importable as thin wrappers:

* ``Request``  -> :class:`repro.engine.EngineRequest` (field-compatible:
  the first five fields are identical)
* ``ServeStats`` -> :class:`repro.engine.ServeStats`
* ``ServeLoop`` -> a shim over :class:`repro.engine.Engine` +
  :class:`repro.engine.DenseAdapter` with the legacy contract
  (unbounded queue, ``sample(logits_row, uid)`` callback)

Every access emits a :class:`DeprecationWarning` naming the
replacement.  New code should construct the engine directly.
"""
from __future__ import annotations

import warnings
from typing import Callable

__all__ = ["Request", "ServeLoop", "ServeStats"]

_MOVED = {
    "Request": "repro.engine.EngineRequest",
    "ServeStats": "repro.engine.ServeStats",
    "ServeLoop": "repro.engine.Engine (with repro.engine.DenseAdapter)",
}


class _ServeLoop:
    """Legacy-contract shim over :class:`repro.engine.Engine`.

    Continuous batching over a fixed decode batch, unbounded queue,
    per-row ``sample(logits_row, uid)`` callback — exactly the old
    ``ServeLoop`` semantics (token streams are bit-identical), executed
    by the engine's admit/prefill/decode/retire stages.
    """

    def __init__(self, model, params, batch_size: int, max_seq: int,
                 eos_token: int | None = None,
                 sample: Callable | None = None):
        from repro.engine import DenseAdapter, Engine, EngineConfig
        from repro.engine import greedy_sampler

        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.eos = eos_token
        if sample is None:
            sampler = greedy_sampler
        else:
            def sampler(row, req, _sample=sample):
                return int(_sample(row, req.uid))
        self.engine = Engine(
            DenseAdapter(model, params),
            EngineConfig(batch_size=batch_size, max_seq=max_seq,
                         max_backlog=None, eos_token=eos_token),
            sampler=sampler)

    # -- legacy surface, delegated --------------------------------------
    @property
    def state(self) -> dict:
        return self.engine.state

    @property
    def slots(self) -> list:
        return self.engine.slots

    @property
    def stats(self):
        return self.engine.stats

    def submit(self, req) -> None:
        self.engine.submit(req)

    def step(self) -> None:
        self.engine.step()

    def run_until_drained(self, max_steps: int = 10_000):
        return self.engine.run_until_drained(max_steps)


def __getattr__(name: str):
    if name in _MOVED:
        warnings.warn(
            f"repro.runtime.serve_loop.{name} is deprecated; use "
            f"{_MOVED[name]}", DeprecationWarning, stacklevel=2,
        )
        if name == "ServeLoop":
            return _ServeLoop
        from repro import engine

        return engine.EngineRequest if name == "Request" \
            else engine.ServeStats
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
