"""Gradient compression for cross-pod all-reduce: int8 + error feedback.

At multi-pod scale the gradient all-reduce crosses the slow inter-pod
links; quantizing gradients to int8 with per-tensor-block scales cuts
those bytes 4x (vs f32) / 2x (vs bf16).  Error feedback (residual
accumulation) keeps the compression unbiased over time — SGD/Adam-style
convergence is preserved (1-bit Adam / EF-SGD literature).

Usage: wrap the train step's gradient tree:

    comp = GradCompressor(block=256)
    grads, state = comp.compress_decompress(grads, state)

The compress->decompress round trip is what the wire would carry; under
pjit the quantized representation is what crosses the 'pod' axis when the
tree is reduced (the decompressed values are produced on the far side).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GradCompressor:
    block: int = 256          # elements per scale block

    def init_state(self, grads: Any) -> Any:
        """Error-feedback residual, same structure as grads (f32)."""
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def _quantize(self, g: jax.Array) -> tuple[jax.Array, jax.Array, int]:
        flat = g.astype(jnp.float32).reshape(-1)
        n = flat.shape[0]
        pad = (-n) % self.block
        flat = jnp.pad(flat, (0, pad)).reshape(-1, self.block)
        amax = jnp.max(jnp.abs(flat), axis=1, keepdims=True)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
        return q, scale, n

    def _dequantize(self, q: jax.Array, scale: jax.Array, n: int,
                    shape) -> jax.Array:
        out = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
        return out.reshape(shape)

    def compress_decompress(self, grads: Any, ef_state: Any
                            ) -> tuple[Any, Any]:
        """Returns (decompressed grads, new error-feedback state)."""
        def per_leaf(g, ef):
            corrected = g.astype(jnp.float32) + ef
            q, scale, n = self._quantize(corrected)
            deq = self._dequantize(q, scale, n, g.shape)
            new_ef = corrected - deq
            return deq.astype(g.dtype), new_ef
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_leaves(ef_state)
        outs = [per_leaf(g, e) for g, e in zip(flat_g, flat_e)]
        deqs = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        efs = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return deqs, efs

    def wire_bytes(self, grads: Any) -> tuple[int, int]:
        """(compressed, uncompressed-f32) bytes for one reduction."""
        n = sum(int(g.size) for g in jax.tree.leaves(grads))
        blocks = sum(-(-int(g.size) // self.block)
                     for g in jax.tree.leaves(grads))
        return n + 4 * blocks, 4 * n
