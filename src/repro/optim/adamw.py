"""AdamW with decoupled weight decay, global-norm clipping and LR schedule.

Self-contained (no optax in the container).  State is two f32 moment trees
plus the step counter; params may be bf16 (updates are computed in f32 and
cast back — the memory-light recipe; see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any, moment_dtype: str = "float32") -> dict:
    """``moment_dtype='bfloat16'`` halves optimizer HBM (the 8-bit-Adam
    family of tricks; update math still runs in f32 — see §Perf)."""
    dt = jnp.dtype(moment_dtype)
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, dt)
        if hasattr(p, "shape") else jnp.zeros((), dt), params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


_NO_DECAY_SUBSTRINGS = ("norm", "bias", "scale", "mix", "bonus", "dt_bias",
                        "a_log", "decay_w0", "d_skip")


def _decay_mask(path: tuple, leaf) -> bool:
    keys = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
    joined = "/".join(str(k) for k in keys).lower()
    if getattr(leaf, "ndim", 0) <= 1:
        return False
    return not any(s in joined for s in _NO_DECAY_SUBSTRINGS)


def adamw_update(cfg: AdamWConfig, grads: Any, opt_state: dict,
                 params: Any,
                 transform_grads: Callable[[Any], Any] | None = None
                 ) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    if transform_grads is not None:
        grads = transform_grads(grads)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(path, p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m.astype(mdt), v.astype(mdt)

    flat = jax.tree_util.tree_flatten_with_path(params)
    paths = [p for p, _ in flat[0]]
    p_leaves = [x for _, x in flat[0]]
    g_leaves = jax.tree.leaves(grads)
    m_leaves = jax.tree.leaves(opt_state["m"])
    v_leaves = jax.tree.leaves(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for path, p, g, m, v in zip(paths, p_leaves, g_leaves, m_leaves,
                                v_leaves):
        a, b, c = upd(path, p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    treedef = flat[1]
    params_out = jax.tree_util.tree_unflatten(treedef, new_p)
    opt_out = {
        "m": jax.tree_util.tree_unflatten(treedef, new_m),
        "v": jax.tree_util.tree_unflatten(treedef, new_v),
        "step": step,
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params_out, opt_out, metrics
