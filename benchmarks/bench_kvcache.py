"""Packed KV-cache bench: stream-direct decode attention vs dense KV.

The ISSUE-10 acceptance measurement, on the reduced smollm geometry:

* **bit-identity gate** — engine decode on the Iris-packed KV cache
  with the stream-direct attention kernel must emit, bit for bit, the
  tokens of the materialized dense-dequant oracle over the same pages
  (int3 and int4, ragged admission).  The bench exits nonzero on any
  mismatch.
* **planner accounting** — the per-page layout is planned once; every
  append across layers / slots / pages / steps reuses it (scheduler-run
  and cache-hit counters recorded, re-plans are a hard failure).
* **bandwidth model** — resident KV bytes and per-token decode-read
  bytes for the packed pages vs a bf16 dense cache, plus the planned
  layout's bus efficiency ``B_eff`` (the paper's figure of merit).
* **microbench** — interpret-mode wall clock for append and for
  stream-kernel vs dense-oracle attention (functional sanity numbers,
  not device truth).

Written into ``BENCH_kvcache.json`` at the repo root.

CLI:  PYTHONPATH=src python benchmarks/bench_kvcache.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time


def _mean_us(fn, repeats: int) -> float:
    fn()                                    # warm (trace + lower)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6


def run(quick: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.configs import get_config
    from repro.core.iris import DEFAULT_CACHE
    from repro.engine import Engine, EngineConfig, EngineRequest, \
        PackedAdapter
    from repro.kvcache import PackedKVCache
    from repro.models.attention import decode_attention
    from repro.models.model import Model
    from repro.quant import QuantSpec

    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab_size=128)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    trees = {bits: api.pack_tree(cfg, params,
                                 QuantSpec(bits=bits, group_size=32), m=512)
             for bits in (3, 4)}
    batch, max_seq, page_tokens = 2, 32, 8

    # -- bit-identity gate: stream kernel vs dense-dequant oracle --------
    def serve(tree, kv_attention):
        reqs = [EngineRequest(uid=0, prompt=[5, 9], max_new_tokens=2),
                EngineRequest(uid=1, prompt=[17, 3, 8], max_new_tokens=3),
                EngineRequest(uid=2, prompt=[40], max_new_tokens=2)]
        eng = Engine(PackedAdapter(cfg, tree, kv="packed",
                                   kv_attention=kv_attention,
                                   page_tokens=page_tokens),
                     EngineConfig(batch_size=batch, max_seq=max_seq))
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        return [r.generated for r in reqs], eng

    identity = {}
    for bits, tree in trees.items():
        stream_toks, eng = serve(tree, "stream")
        misses0 = DEFAULT_CACHE.misses
        dense_toks, _ = serve(tree, "dense")
        ok = stream_toks == dense_toks
        kvc = eng.state["packed_kv"]
        identity[f"int{bits}"] = {
            "tokens": sum(len(t) for t in stream_toks),
            "identical": bool(ok),
            "plan_stats": dict(kvc.plan_stats),
            "appends_replanned": DEFAULT_CACHE.misses != misses0,
        }
        print(f"kvcache/bit_identity_int{bits},0.0,"
              f"tokens={identity[f'int{bits}']['tokens']};identical={ok};"
              f"scheduler_runs={kvc.plan_stats.get('scheduler_runs')}",
              flush=True)

    # -- bandwidth model: packed pages vs dense bf16 cache ---------------
    bandwidth = {}
    for bits in (3, 4):
        kvc = PackedKVCache.create(cfg, bits=bits, page_tokens=page_tokens,
                                   n_slots=batch, max_seq=max_seq)
        eff = float(kvc.layout.metrics().efficiency)
        packed_bytes = kvc.stream_bytes()
        dense_bytes = (cfg.n_layers * batch * max_seq * cfg.n_kv_heads
                       * cfg.head_dim * 2 * 2)        # bf16, K and V
        # one decode step reads every resident token's K and V once
        per_tok_packed = packed_bytes / (cfg.n_layers * batch * kvc.smax)
        per_tok_dense = dense_bytes / (cfg.n_layers * batch * max_seq)
        bandwidth[f"int{bits}"] = {
            "b_eff": eff,
            "resident_bytes_packed": packed_bytes,
            "resident_bytes_dense_bf16": dense_bytes,
            "decode_read_bytes_per_token_packed": per_tok_packed,
            "decode_read_bytes_per_token_dense_bf16": per_tok_dense,
            "bytes_ratio": dense_bytes / packed_bytes,
        }
        print(f"kvcache/bandwidth_int{bits},0.0,"
              f"B_eff={eff:.3f};ratio={dense_bytes / packed_bytes:.2f};"
              f"packed_B={packed_bytes};dense_B={dense_bytes}", flush=True)

    # -- microbench: append + attention paths ----------------------------
    from repro.kvcache.kernels import stream_attention_cache

    reps = 2 if quick else 5
    rng = np.random.default_rng(0)
    kvc = PackedKVCache.create(cfg, bits=4, page_tokens=page_tokens,
                               n_slots=batch, max_seq=max_seq)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = jnp.asarray(rng.normal(size=(batch, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(batch, hkv, hd)), jnp.float32)
    slots = jnp.arange(batch)
    for t in range(6):
        kvc = kvc.append(k, v, jnp.full((batch,), t, jnp.int32), slots,
                         layer=0)
    us_append = _mean_us(
        lambda: jax.block_until_ready(kvc.append(
            k, v, jnp.full((batch,), 6, jnp.int32), slots, layer=0).pages),
        reps)

    pos = jnp.full((batch,), 5, jnp.int32)
    q = jnp.asarray(rng.normal(size=(batch, 1, cfg.n_heads, hd)),
                    jnp.bfloat16)
    us_stream = _mean_us(
        lambda: jax.block_until_ready(stream_attention_cache(
            kvc, q, pos, slots, layer=0)), reps)
    us_dense = _mean_us(
        lambda: jax.block_until_ready(decode_attention(
            q, *kvc.dense_kv(0, slots), pos)), reps)
    got = stream_attention_cache(kvc, q, pos, slots, layer=0)
    want = decode_attention(q, *kvc.dense_kv(0, slots), pos)
    kernel_identical = bool(
        (np.asarray(got).view(np.uint16) ==
         np.asarray(want).view(np.uint16)).all())
    micro = {
        "interpret": True,
        "append_us": us_append,
        "stream_attention_us": us_stream,
        "dense_oracle_attention_us": us_dense,
        "kernel_bit_identical": kernel_identical,
    }
    print(f"kvcache/append,{us_append:.1f},interpret=True", flush=True)
    print(f"kvcache/stream_attention,{us_stream:.1f},"
          f"dense_oracle_us={us_dense:.1f};identical={kernel_identical}",
          flush=True)

    out = {
        "quick": quick,
        "config": {
            "arch": cfg.name, "batch_size": batch, "max_seq": max_seq,
            "page_tokens": page_tokens, "n_layers": cfg.n_layers,
            "n_kv_heads": cfg.n_kv_heads, "head_dim": cfg.head_dim,
        },
        "bit_identity": identity,
        "bandwidth": bandwidth,
        "microbench": micro,
    }
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_kvcache.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    if not all(v["identical"] for v in identity.values()) \
            or not kernel_identical:
        raise SystemExit(
            "kvcache bench: stream-direct attention is NOT bit-identical "
            "to the dense-KV oracle")
    if any(v["appends_replanned"] for v in identity.values()):
        raise SystemExit("kvcache bench: an append re-planned the layout")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
