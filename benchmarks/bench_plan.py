"""Planner scale-out bench: cold vs parallel vs incremental vs persistent
planning, plus host vs device pack — and the compiled-exec bench that
used to live inline in run.py.

Two entry points, both gated on bit-equivalence (SystemExit(1) on any
mismatch — CI runs them as correctness checks, not just timers):

* :func:`run_exec` — compiled execution plans vs the per-slot legacy
  paths on the §4 LM layer bundle (the old ``bench_exec``); writes
  ``BENCH_exec.json``.
* :func:`run` — the ISSUE-9 acceptance measurement; writes
  ``BENCH_plan.json``:

  - **parallel**: a 16-unique-signature mixed-precision stack (the LM
    bundle with a per-layer ``attn_norm`` depth delta) through
    ``schedule_many(workers=8)`` vs per-problem cold ``schedule()``.
    On a multi-core box the speedup is pool fan-out; on a small
    container ``_effective_workers`` clamps to the core count and the
    speedup comes from warm-start chaining — ``workers_effective`` is
    recorded so the number can be read in context.
  - **incremental**: warm-start re-plan of a single-parameter-delta
    neighbor vs a cold run of the same problem.
  - **persistent**: a fresh ``LayoutCache(cache_dir=...)`` process-start
    load (analysis-verified) per signature vs re-scheduling.
  - **pack**: host ``pack_compiled`` vs the fused Pallas device pack
    (``kernels.layout_pack``), same buffer bit-for-bit.

All speedups are machine-relative: the absolute GB/s and wall-clocks
move with the container, the equivalence flags must not.

CLI:  PYTHONPATH=src python benchmarks/bench_plan.py [--quick]
"""
from __future__ import annotations

import argparse
import gc
import json
import pathlib
import tempfile
import time

import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _timeit_min(fn, repeats: int = 5, warmup: int = 1) -> float:
    """Best-of-N in us — robust to container scheduler noise."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def _bundle_problem(quick: bool):
    from repro.core.packing import bundle_problem, layer_bundle_spec
    from repro.quant import QuantSpec

    if quick:
        dims = 256, 512, 4, 2, 64
    else:
        dims = 576, 1536, 9, 3, 64              # smollm-135m
    bundle = layer_bundle_spec(*dims, QuantSpec(bits=3, group_size=128))
    return bundle, bundle_problem(bundle, m=512)


# ----------------------------------------------------------------------
# compiled exec plans vs per-slot legacy (formerly run.py bench_exec)
# ----------------------------------------------------------------------
def run_exec(quick: bool = False) -> dict:
    """Compiled exec plans vs per-slot legacy paths (ISSUE-4 acceptance).

    The §4 LM layer bundle (decoder-layer weight stream of an LM config,
    3-bit weights + 16-bit scales/norms — the paper's custom-width
    regime) on a 512-bit bus: scheduling units land on 30/32 bits, so
    *every* path, legacy and compiled, applies and can be cross-checked
    bit-for-bit, and the odd widths produce the interval-rich,
    word-straddling layouts the per-slot paths are worst at:

    * host pack: ``pack_arrays`` (one Python loop per interval/slot/lane)
      vs ``pack_compiled`` (argsort'd OR-reduction, no Python loops);
    * decode: per-unit ``decode_layout(fused=False)`` (one pallas_call +
      dynamic_update_slice per unit) vs the fused single-kernel path;
    * scheduler: fresh run vs LayoutCache hit (context for the JSON).

    Writes BENCH_exec.json at the repo root; raises SystemExit(1) if the
    compiled paths are not bit-identical to the legacy ones.
    """
    from repro import api
    from repro.core.codegen import decode_plan, pack_arrays, random_codes
    from repro.core.exec_plan import lower_exec
    from repro.core.iris import LayoutCache, schedule
    from repro.kernels.ops import decode_layout

    _bundle, prob = _bundle_problem(quick)

    # scheduler + cache context
    t0 = time.perf_counter()
    lay = schedule(prob, cache=None)
    sched_us = (time.perf_counter() - t0) * 1e6
    cache = LayoutCache()
    schedule(prob, cache=cache)
    t0 = time.perf_counter()
    schedule(prob, cache=cache)
    hit_us = (time.perf_counter() - t0) * 1e6

    codes = random_codes(prob, seed=0)
    useful_bytes = prob.p_tot / 8

    # pack: legacy per-slot loop vs compiled (best-of-N: the container
    # scheduler is noisy and the mean punishes the fast path most)
    reps = 2 if quick else 3
    pack_legacy_us = _timeit_min(lambda: pack_arrays(lay, codes),
                                 repeats=reps, warmup=1)
    t0 = time.perf_counter()
    prog = lower_exec(lay)
    lower_us = (time.perf_counter() - t0) * 1e6
    pack_us = _timeit_min(lambda: api.pack_compiled(lay, codes, program=prog),
                          repeats=5 * reps, warmup=1)
    buf_legacy = pack_arrays(lay, codes)
    buf = api.pack_compiled(lay, codes, program=prog)
    pack_ok = bool(np.array_equal(buf_legacy, buf))

    # decode: per-unit kernels vs one fused kernel (both interpret mode)
    n_units = decode_plan(lay).n_units
    t0 = time.perf_counter()
    legacy_out = decode_layout(lay, buf, fused=False, interpret=True)
    decode_legacy_us = (time.perf_counter() - t0) * 1e6
    fused_out = decode_layout(lay, buf, fused=True, interpret=True,
                              program=prog)              # trace + check
    decode_us = _timeit_min(
        lambda: decode_layout(lay, buf, fused=True, interpret=True,
                              program=prog),
        repeats=3, warmup=0)
    decode_ok = all(
        np.array_equal(np.asarray(fused_out[k]).astype(np.uint64), v)
        and np.array_equal(np.asarray(legacy_out[k]).astype(np.uint64), v)
        for k, v in codes.items()
    )

    _row("exec/pack_compiled", pack_us,
         f"legacy_us={pack_legacy_us:.0f};speedup={pack_legacy_us/pack_us:.1f}x;"
         f"GBps={useful_bytes/1e3/pack_us:.2f};identical={pack_ok}")
    _row("exec/decode_fused", decode_us,
         f"legacy_us={decode_legacy_us:.0f};"
         f"speedup={decode_legacy_us/decode_us:.1f}x;"
         f"rows_per_s={lay.c_max/(decode_us/1e6):.0f};"
         f"units_fused={n_units}->1;identical={decode_ok}")

    out = {
        "quick": quick,
        "problem": {
            "name": "lm_layer_bundle_int3_m512",
            "m": prob.m, "n_arrays": len(prob.arrays),
            "p_tot_bits": prob.p_tot, "c_max": lay.c_max,
            "decode_units_legacy": n_units,
            "pieces": prog.n_pieces,
            "kernel_lanes": prog.kernel.lanes,
            "pallas_calls_fused": prog.n_pallas_calls,
        },
        "scheduler": {"schedule_us": sched_us, "cache_hit_us": hit_us},
        "pack": {
            "legacy_us": pack_legacy_us,
            "compiled_us": pack_us,
            "lower_us": lower_us,
            "speedup": pack_legacy_us / pack_us,
            "compiled_GBps": useful_bytes / 1e3 / pack_us,
            "legacy_GBps": useful_bytes / 1e3 / pack_legacy_us,
        },
        "decode": {
            "legacy_us": decode_legacy_us,
            "fused_us": decode_us,
            "speedup": decode_legacy_us / decode_us,
            "fused_rows_per_s": lay.c_max / (decode_us / 1e6),
            "legacy_rows_per_s": lay.c_max / (decode_legacy_us / 1e6),
        },
        "equivalence": {"pack_ok": pack_ok, "decode_ok": decode_ok},
    }
    (_ROOT / "BENCH_exec.json").write_text(json.dumps(out, indent=2) + "\n")
    if not (pack_ok and decode_ok):
        raise SystemExit(
            "exec bench: compiled paths are NOT bit-identical to legacy"
        )
    return out


# ----------------------------------------------------------------------
# planner scale-out (ISSUE-9 acceptance)
# ----------------------------------------------------------------------
def _signature_stack(base, n: int):
    """``n`` unique-signature variants of ``base``: per-layer attn_norm
    depth deltas, each one scheduling-unit step from its neighbor (the
    mixed-precision / per-layer-unique regime the ROADMAP targets)."""
    from repro.core.task import ArraySpec, LayoutProblem

    out = []
    for i in range(n):
        arrays = tuple(
            ArraySpec(name=a.name, width=a.width, depth=a.depth + i,
                      due=a.due, max_lanes=a.max_lanes)
            if a.name == "attn_norm" else a
            for a in base.arrays)
        out.append(LayoutProblem(m=base.m, arrays=arrays))
    return out


def run(quick: bool = False) -> dict:
    import repro.core.iris as iris_mod
    from repro.core.exec_plan import lower_exec, pack_compiled
    from repro.core.codegen import random_codes
    from repro.core.iris import LayoutCache, schedule, schedule_many
    from repro.kernels.layout_pack import pack_layout_fused

    bundle, base = _bundle_problem(quick)
    n_sigs = 16
    stack = _signature_stack(base, n_sigs)
    equiv: dict[str, bool] = {}

    # (a) serial cold baseline: every signature from scratch, no cache
    t0 = time.perf_counter()
    cold = [schedule(p, cache=None, warm_start=False) for p in stack]
    t_serial = time.perf_counter() - t0

    # (b) schedule_many with 8 requested workers (pool fan-out where the
    # container has cores; warm-start chaining either way)
    par_cache = LayoutCache()
    t0 = time.perf_counter()
    par = schedule_many(stack, cache=par_cache, workers=8)
    t_par = time.perf_counter() - t0
    workers_eff = iris_mod._effective_workers(8, n_sigs)
    equiv["parallel_ok"] = all(
        a.count_intervals == b.count_intervals for a, b in zip(cold, par))
    _row("plan/parallel_16sig", t_par * 1e6,
         f"serial_us={t_serial*1e6:.0f};speedup={t_serial/t_par:.1f}x;"
         f"workers_eff={workers_eff};warm_starts={par_cache.warm_starts};"
         f"identical={equiv['parallel_ok']}")

    # (c) incremental: one-parameter-delta neighbor, warm vs cold
    neighbor = stack[1]
    reps = 2 if quick else 3
    t_cold = _timeit_min(
        lambda: schedule(neighbor, cache=None, warm_start=False),
        repeats=reps, warmup=0) / 1e6

    def _warm():
        c = LayoutCache()
        c.insert(base, False, cold[0])
        return schedule(neighbor, cache=c)

    warm_lay = _warm()
    t_warm = _timeit_min(_warm, repeats=reps, warmup=0) / 1e6
    c_chk = LayoutCache()
    c_chk.insert(base, False, cold[0])
    schedule(neighbor, cache=c_chk)
    equiv["incremental_ok"] = bool(
        warm_lay.count_intervals == cold[1].count_intervals
        and c_chk.warm_starts == 1)
    _row("plan/incremental", t_warm * 1e6,
         f"cold_us={t_cold*1e6:.0f};speedup={t_cold/t_warm:.1f}x;"
         f"identical={equiv['incremental_ok']}")

    # (d) persistent: fresh-cache load of analysis-verified entries
    # (one untimed pass first so the one-off lazy analysis import is not
    # billed to every signature; then best-of-N fresh readers, same
    # noise convention as _timeit_min)
    with tempfile.TemporaryDirectory() as d:
        writer = LayoutCache(cache_dir=d)
        for p, lay in zip(stack, cold):
            writer.insert(p, False, lay)
        warm_reader = LayoutCache(cache_dir=d)
        warm_reader.lookup(stack[0])
        # GC disabled during the timed region (the timeit convention):
        # with JAX and the pool results live, gen0 collections otherwise
        # bill the whole process heap to the load path
        gc.collect()
        gc.disable()
        try:
            t_load = float("inf")
            for _ in range(reps + 1):
                reader = LayoutCache(cache_dir=d)
                t0 = time.perf_counter()
                loaded = [reader.lookup(p) for p in stack]
                t_load = min(t_load, time.perf_counter() - t0)
        finally:
            gc.enable()
    equiv["persistent_ok"] = bool(
        all(l is not None for l in loaded)
        and all(l.count_intervals == c.count_intervals
                for l, c in zip(loaded, cold))
        and reader.disk_hits == n_sigs)
    load_ms_per_sig = t_load * 1e3 / n_sigs
    _row("plan/persistent_load", t_load * 1e6 / n_sigs,
         f"ms_per_sig={load_ms_per_sig:.2f};"
         f"vs_cold={t_serial/t_load:.0f}x;"
         f"identical={equiv['persistent_ok']}")

    # (e) pack: host numpy vs fused Pallas device kernel (unit
    # granularity — every piece width <= 32, single pallas_call)
    lay = cold[0]
    codes = random_codes(base, seed=0)
    prog = lower_exec(lay)
    useful_bytes = base.p_tot / 8
    host_us = _timeit_min(
        lambda: pack_compiled(lay, codes, program=prog),
        repeats=5 * reps, warmup=1)
    buf_host = pack_compiled(lay, codes, program=prog)
    buf_dev = pack_layout_fused(lay, codes, program=prog)   # trace + check
    dev_us = _timeit_min(
        lambda: pack_layout_fused(lay, codes, program=prog),
        repeats=5 * reps, warmup=0)
    equiv["pack_ok"] = bool(np.array_equal(buf_host, buf_dev))
    _row("plan/pack_device", dev_us,
         f"host_us={host_us:.0f};speedup={host_us/dev_us:.1f}x;"
         f"GBps={useful_bytes/1e3/dev_us:.2f};"
         f"host_GBps={useful_bytes/1e3/host_us:.2f};"
         f"identical={equiv['pack_ok']}")

    out = {
        "quick": quick,
        "stack": {
            "n_signatures": n_sigs, "m": base.m,
            "n_arrays": len(base.arrays), "c_max": lay.c_max,
        },
        "parallel": {
            "serial_cold_s": t_serial, "schedule_many_s": t_par,
            "speedup": t_serial / t_par,
            "workers_requested": 8, "workers_effective": workers_eff,
            "warm_starts": par_cache.warm_starts,
        },
        "incremental": {
            "cold_s": t_cold, "warm_s": t_warm,
            "speedup": t_cold / t_warm,
        },
        "persistent": {
            "load_ms_per_signature": load_ms_per_sig,
            "total_load_s": t_load,
            "speedup_vs_cold": t_serial / t_load,
        },
        "pack": {
            "host_us": host_us, "device_us": dev_us,
            "speedup": host_us / dev_us,
            "host_GBps": useful_bytes / 1e3 / host_us,
            "device_GBps": useful_bytes / 1e3 / dev_us,
        },
        "equivalence": equiv,
    }
    (_ROOT / "BENCH_plan.json").write_text(json.dumps(out, indent=2) + "\n")
    if not all(equiv.values()):
        bad = [k for k, v in equiv.items() if not v]
        raise SystemExit(f"plan bench: bit-equivalence FAILED: {bad}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--exec-only", action="store_true",
                    help="run only the compiled-exec half")
    args = ap.parse_args()
    run_exec(quick=args.quick)
    if not args.exec_only:
        run(quick=args.quick)
