"""Stream-direct vs two-pass serving bench on the int3 LM layer bundle.

The ISSUE-6 acceptance measurement: one decode token's worth of weight
matmuls (all seven projections of a dense decoder layer) served two ways
from the *same* packed Iris stream:

* **fused** — ``kernels.stream_matmul`` per tensor: the ExecProgram slot
  tables are consulted inside the matmul prologue, weights go
  HBM -> registers -> MXU with no dense intermediate;
* **two-pass** — the legacy path the paper's thesis indicts: one fused
  Pallas layout-decode materializes every element, then each projection
  re-packs its dense codes and runs the lane-packed ``packed_matmul``
  (int3 is not lane-packable, so the dense codes ride 8-bit containers
  — the same re-bias the test-suite oracle uses, value-exact).

Bundle: ``layer_bundle_spec(576, 1536, 9, 3, 64, int3)`` (smollm-135m
geometry) on a 512-bit bus; group_size=64 — the per-column (K//g, N)
scale grid every matmul needs must tile K=576, which 128 does not.

Writes BENCH_stream_mm.json at the repo root (GB/s + rows/s per path)
and raises SystemExit(1) if the two paths are not bit-identical.

CLI:  PYTHONPATH=src python benchmarks/bench_stream_mm.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np


def _timeit_min(fn, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-N in us — robust to container scheduler noise."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(quick: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core.exec_plan import (
        lower_exec,
        pack_compiled,
        stream_matmul_tables,
    )
    from repro.core.iris import schedule
    from repro.core.packing import (
        bundle_problem,
        layer_bundle_spec,
        pad_bundle_elements,
    )
    from repro.kernels.layout_decode import decode_layout_fused
    from repro.kernels.packed_matmul import packed_matmul
    from repro.kernels.stream_matmul import stream_matmul, stream_words
    from repro.quant import QuantSpec, pack_codes_u32, quantize

    if quick:
        d_model, d_ff, heads, kv, hd, reps = 256, 512, 4, 2, 64, 2
    else:
        d_model, d_ff, heads, kv, hd, reps = 576, 1536, 9, 3, 64, 3
    bits, g, batch = 3, 64, 8
    spec = QuantSpec(bits=bits, group_size=g)
    bundle = layer_bundle_spec(d_model, d_ff, heads, kv, hd, spec)
    shapes = {
        "wq": (d_model, heads * hd),
        "wk": (d_model, kv * hd),
        "wv": (d_model, kv * hd),
        "wo": (heads * hd, d_model),
        "w_gate": (d_model, d_ff),
        "w_up": (d_model, d_ff),
        "w_down": (d_ff, d_model),
    }

    # quantized data for every bundle tensor (weights + scales + norms)
    key = jax.random.PRNGKey(0)
    data: dict[str, np.ndarray] = {}
    for name, (k_, n_) in shapes.items():
        key, sub = jax.random.split(key)
        qt = quantize(jax.random.normal(sub, (k_, n_), jnp.float32), spec)
        data[name] = np.asarray(qt.codes).reshape(-1).astype(np.uint64)
        data[f"{name}_scales"] = np.asarray(jax.lax.bitcast_convert_type(
            qt.scales, jnp.uint16)).reshape(-1).astype(np.uint64)
    for b in bundle:
        if b.name not in data:                        # the norm vectors
            key, sub = jax.random.split(key)
            data[b.name] = np.asarray(jax.lax.bitcast_convert_type(
                jax.random.normal(sub, (b.n_elems,), jnp.float32)
                .astype(jnp.bfloat16), jnp.uint16)).astype(np.uint64)

    # schedule + lower + pack the unified stream once (load-time work)
    prob = bundle_problem(bundle, m=512)
    lay = schedule(prob)
    prog = lower_exec(lay, elem_widths=tuple(b.width_bits for b in bundle))
    buf = pack_compiled(lay, pad_bundle_elements(prob, prog, data),
                        program=prog)
    sw = stream_words(prog, buf)
    tabs = {name: stream_matmul_tables(lay, name, shp,
                                       scales=f"{name}_scales",
                                       group_size=g, program=prog)
            for name, shp in shapes.items()}
    xs = {}
    for name, (k_, _) in shapes.items():
        key, sub = jax.random.split(key)
        xs[name] = jax.random.normal(sub, (batch, k_), jnp.float32)

    def _bk(k_):
        # largest K block <= 512 that tiles K in whole groups — the SAME
        # split must go to both kernels so the accumulation order (and
        # hence bit-identity) matches
        return max(x for x in range(g, min(512, k_) + 1, g) if k_ % x == 0)

    def _bn(n_):
        return max(x for x in range(1, min(128, n_) + 1) if n_ % x == 0)

    def fused_token():
        outs = {}
        for name, (k_, n_) in shapes.items():
            t = tabs[name]
            outs[name] = stream_matmul(
                xs[name], sw, t.w_tab, t.s_tab, bits=bits, group_size=g,
                block_k=_bk(k_), block_n=_bn(n_), interpret=True)
        jax.block_until_ready(list(outs.values()))
        return outs

    rebias = 128 - (1 << (bits - 1))

    def two_pass_token():
        dec = decode_layout_fused(lay, buf, program=prog, interpret=True)
        outs = {}
        for name, (k_, n_) in shapes.items():
            codes = (jnp.asarray(dec[name])[:k_ * n_].reshape(k_, n_)
                     .astype(jnp.uint8) + rebias)
            scales = jax.lax.bitcast_convert_type(
                jnp.asarray(dec[f"{name}_scales"])[:(k_ // g) * n_]
                .astype(jnp.uint16).reshape(k_ // g, n_), jnp.bfloat16)
            pw = pack_codes_u32(codes, 8)
            outs[name] = packed_matmul(
                xs[name], pw, scales, bits=8, group_size=g,
                block_k=_bk(k_), block_n=_bn(n_), interpret=True)
        jax.block_until_ready(list(outs.values()))
        return outs

    fused_out = fused_token()                    # trace + equivalence ref
    two_out = two_pass_token()
    identical = all(
        np.array_equal(np.asarray(fused_out[n]), np.asarray(two_out[n]))
        for n in shapes)

    fused_us = _timeit_min(fused_token, repeats=reps, warmup=0)
    two_us = _timeit_min(two_pass_token, repeats=reps, warmup=0)

    stream_bytes = int(np.asarray(buf).nbytes)
    row = ("stream_mm/fused,{:.1f},two_pass_us={:.0f};speedup={:.2f}x;"
           "GBps={:.3f};rows_per_s={:.0f};identical={}")
    print(row.format(fused_us, two_us, two_us / fused_us,
                     stream_bytes / 1e3 / fused_us,
                     lay.c_max / (fused_us / 1e6), identical), flush=True)

    out = {
        "quick": quick,
        "problem": {
            "name": "lm_layer_bundle_int3_m512",
            "bits": bits, "group_size": g, "batch": batch,
            "d_model": d_model, "d_ff": d_ff,
            "m": prob.m, "c_max": lay.c_max,
            "stream_bytes": stream_bytes,
            "matmuls_per_token": len(shapes),
        },
        "fused": {
            "us_per_token": fused_us,
            "GBps": stream_bytes / 1e3 / fused_us,
            "rows_per_s": lay.c_max / (fused_us / 1e6),
        },
        "two_pass": {
            "us_per_token": two_us,
            "GBps": stream_bytes / 1e3 / two_us,
            "rows_per_s": lay.c_max / (two_us / 1e6),
        },
        "speedup": two_us / fused_us,
        "fused_below_two_pass": bool(fused_us < two_us),
        "equivalence": {"outputs_identical": bool(identical)},
    }
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_stream_mm.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    if not identical:
        raise SystemExit(
            "stream-mm bench: fused path is NOT bit-identical to two-pass")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
