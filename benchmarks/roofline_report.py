"""Aggregate artifacts/dryrun/*.json into the EXPERIMENTS.md roofline
tables, plus the layout-strategy comparison table driven by the
repro.api registry.

Usage:  PYTHONPATH=src python -m benchmarks.roofline_report [--dir artifacts/dryrun]
Prints markdown; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import json
import pathlib


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if x >= scale:
            return f"{x / scale:.2f}{unit}"
    return f"{x:.1e}s"


def fmt_gib(b) -> str:
    return f"{(b or 0) / 2**30:.2f}"


def load(dirpath: str) -> list[dict]:
    rows = []
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        rows.append(json.loads(p.read_text()))
    return rows


ARCH_ORDER = [
    "whisper-medium", "command-r-plus-104b", "mistral-large-123b",
    "stablelm-3b", "smollm-135m", "arctic-480b", "moonshot-v1-16b-a3b",
    "rwkv6-3b", "jamba-1.5-large-398b", "qwen2-vl-2b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def table(rows: list[dict], mesh: str) -> str:
    out = [
        "| arch | shape | peak GiB/dev | compute | memory | collective | "
        "bottleneck | MODEL/HLO | coll ops |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    sel = [r for r in rows if r.get("mesh") == mesh]
    sel.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]),
                            SHAPE_ORDER.index(r["shape"])))
    for r in sel:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{fmt_gib(r['memory']['peak_bytes'])} | "
            f"{fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
            f"{fmt_s(t['collective_s'])} | **{t['bottleneck']}** | "
            f"{t['useful_flops_ratio']:.2f} | "
            f"{r['collectives']['count_by_kind']} |")
    return "\n".join(out)


def summary(rows: list[dict]) -> str:
    ok = [r for r in rows if r.get("status") == "ok"]
    by_mesh: dict[str, int] = {}
    for r in ok:
        by_mesh[r["mesh"]] = by_mesh.get(r["mesh"], 0) + 1
    lines = [f"- compiled cells: " + ", ".join(
        f"{k}: {v}" for k, v in sorted(by_mesh.items()))]
    bn: dict[str, int] = {}
    for r in ok:
        b = r["roofline"]["bottleneck"]
        bn[b] = bn.get(b, 0) + 1
    lines.append(f"- bottleneck distribution: {bn}")
    worst = sorted(
        (r for r in ok if r["mesh"] == "pod16x16"),
        key=lambda r: r["roofline"]["useful_flops_ratio"])[:3]
    lines.append("- worst MODEL/HLO ratios (single pod): " + ", ".join(
        f"{r['arch']}×{r['shape']}={r['roofline']['useful_flops_ratio']:.2f}"
        for r in worst))
    return "\n".join(lines)


def layout_strategy_table() -> str:
    """Paper-problem metrics for every registered layout strategy.

    Iterates the :mod:`repro.api` strategy registry, so a newly
    registered strategy shows up in the report without edits here.
    """
    from repro import api

    probs = (
        ("paper_example", api.PAPER_EXAMPLE),
        ("inv_helmholtz", api.INV_HELMHOLTZ),
        ("matmul_33x31", api.matmul_problem(33, 31)),
    )
    out = [
        "| problem | strategy | C_max | L_max | B_eff | FIFO bits |",
        "|---|---|---|---|---|---|",
    ]
    for pname, prob in probs:
        for sname, m in api.compare(prob).items():
            out.append(
                f"| {pname} | {sname} | {m.c_max} | {m.l_max} | "
                f"{m.efficiency:.3f} | {sum(m.fifo_depth.values())} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--no-layouts", action="store_true",
                    help="skip the layout-strategy table")
    args = ap.parse_args()
    rows = load(args.dir)
    print("## Roofline — single pod (16x16 = 256 chips)\n")
    print(table(rows, "pod16x16"))
    print("\n## Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(table(rows, "pod2x16x16"))
    print("\n## Summary\n")
    print(summary(rows))
    if not args.no_layouts:
        print("\n## Layout strategies (repro.api registry)\n")
        print(layout_strategy_table())


if __name__ == "__main__":
    main()
