"""Serving-engine latency/goodput bench: static vs continuous batching.

The ISSUE-7 acceptance measurement, on the int3 smollm-geometry packed
tree (stream-direct — int3 has no lane-packed kernel views):

* **bit-identity gate** — every token the continuous-batching engine
  emits must equal, bit for bit, what an *independent* single-stream
  loop (one request at a time, batch=1, straight ``packed_decode_step``
  calls) produces for the same request.  Checked for int3 and int4;
  the bench exits nonzero on any mismatch.
* **closed loop** — submit everything, drain; wall-clock tokens/s and
  step counts per admission policy.
* **open loop** — requests arrive at a swept offered load and the
  engine runs on a *virtual clock* (1 tick = 1 engine step), so the
  p50/p99-vs-load curves are deterministic and hardware-independent:
  latency is measured in decode steps, goodput in completed tokens per
  step.  Heterogeneous ``max_new_tokens`` makes the static policy pay
  for slot idling — the effect continuous batching exists to remove.

Acceptance: at equal p99 (budget = the worst p99 the static policy
posts anywhere in the sweep), continuous batching sustains strictly
higher goodput.  Written into ``BENCH_serve.json`` at the repo root.

CLI:  PYTHONPATH=src python benchmarks/bench_serve.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time


class StepClock:
    """Virtual engine clock: 1.0 per engine step, advanced by the driver."""

    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def tick(self, dt: float = 1.0) -> None:
        self.t += dt


def _make_requests(n: int, vocab: int, seed: int):
    """Deterministic request set with heterogeneous lengths: short and
    long generations interleave, so a static batch idles slots."""
    import numpy as np

    from repro.engine import EngineRequest

    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        prompt = rng.integers(1, vocab, int(rng.integers(2, 5))).tolist()
        max_new = 3 if uid % 2 == 0 else 9
        reqs.append(EngineRequest(uid=uid, prompt=prompt,
                                  max_new_tokens=max_new))
    return reqs


def _single_stream_oracle(cfg, tree, model, req):
    """Independent oracle: serve one request alone, batch=1, plain
    ``packed_decode_step`` calls — no engine, no ragged slots."""
    import jax.numpy as jnp
    import numpy as np

    from repro.models.quantized import packed_decode_step

    state = model.init_decode_state(1, 64)
    generated: list[int] = []
    pos = 0
    while len(generated) < req.max_new_tokens and pos < 63:
        tok = req.prompt[pos] if pos < len(req.prompt) \
            else generated[-1]
        logits, state = packed_decode_step(
            cfg, tree, state, jnp.asarray([tok], jnp.int32), interpret=True)
        pos += 1
        if pos >= len(req.prompt):
            generated.append(int(np.asarray(logits[0]).argmax()))
    return generated


def _run_open_loop(engine, clock, arrivals, max_steps: int) -> None:
    """Feed ``(t, req)`` arrivals while stepping on the virtual clock."""
    pending = list(arrivals)
    steps = 0
    while pending or engine.has_work():
        while pending and pending[0][0] <= clock.t:
            engine.submit(pending.pop(0)[1])
        if engine.has_work():
            engine.step()
            steps += 1
            if steps >= max_steps:
                break
        clock.tick(1.0)


def run(quick: bool = False) -> dict:
    import copy

    import jax

    from repro import api
    from repro.configs import get_config
    from repro.engine import Engine, EngineConfig, PackedAdapter
    from repro.models.model import Model
    from repro.quant import QuantSpec

    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab_size=128)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    trees = {bits: api.pack_tree(cfg, params,
                                 QuantSpec(bits=bits, group_size=32), m=512)
             for bits in (3, 4)}
    batch, max_seq = 4, 64

    # -- bit-identity gate: engine (continuous) vs single-stream oracle --
    n_ident = 3 if quick else 5
    identity = {}
    for bits, tree in trees.items():
        reqs = _make_requests(n_ident, cfg.vocab_size, seed=bits)
        eng = Engine(PackedAdapter(cfg, tree),
                     EngineConfig(batch_size=batch, max_seq=max_seq))
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        oracle = {r.uid: _single_stream_oracle(cfg, tree, model,
                                               copy.deepcopy(r))
                  for r in reqs}
        ok = all(r.generated == oracle[r.uid] for r in reqs)
        identity[f"int{bits}"] = {
            "requests": n_ident,
            "tokens": sum(len(r.generated) for r in reqs),
            "identical": bool(ok),
        }
        print(f"serve/bit_identity_int{bits},0.0,"
              f"tokens={identity[f'int{bits}']['tokens']};identical={ok}",
              flush=True)

    tree = trees[3]                       # the acceptance config: int3

    # -- closed loop: wall-clock throughput per policy -------------------
    n_closed = 6 if quick else 10
    closed = {}
    for policy in ("static", "continuous"):
        reqs = _make_requests(n_closed, cfg.vocab_size, seed=7)
        eng = Engine(PackedAdapter(cfg, tree),
                     EngineConfig(batch_size=batch, max_seq=max_seq,
                                  policy=policy))
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        stats = eng.run_until_drained()
        wall = time.perf_counter() - t0
        snap = eng.metrics.snapshot()
        closed[policy] = {
            "steps": stats.steps,
            "tokens": stats.tokens_generated,
            "completed": stats.completed,
            "wall_s": wall,
            "tokens_per_s": stats.tokens_generated / wall,
            "mean_batch_occupancy":
                snap["throughput"]["mean_batch_occupancy"],
        }
        print(f"serve/closed_{policy},{wall * 1e6 / stats.steps:.1f},"
              f"steps={stats.steps};tokens={stats.tokens_generated};"
              f"occupancy={closed[policy]['mean_batch_occupancy']:.2f}",
              flush=True)

    # -- open loop: p50/p99 and goodput vs offered load ------------------
    # loads in requests per engine step; capacity for batch=4 and ~9
    # steps mean service time is ~0.44 req/step continuous
    loads = (0.2, 0.45) if quick else (0.12, 0.25, 0.45)
    n_open = 8 if quick else 14
    sweep = []
    for policy in ("static", "continuous"):
        for load in loads:
            clock = StepClock()
            reqs = _make_requests(n_open, cfg.vocab_size, seed=11)
            arrivals = [(i / load, r) for i, r in enumerate(reqs)]
            eng = Engine(PackedAdapter(cfg, tree),
                         EngineConfig(batch_size=batch, max_seq=max_seq,
                                      policy=policy, max_backlog=None),
                         clock=clock)
            _run_open_loop(eng, clock, arrivals, max_steps=2000)
            snap = eng.metrics.snapshot()
            lat = snap["latency"]["total"]
            thr = snap["throughput"]
            point = {
                "policy": policy,
                "offered_load_req_per_step": load,
                "completed": snap["requests"]["completed"],
                "p50_steps": lat["p50_s"],
                "p99_steps": lat["p99_s"],
                "goodput_tokens_per_step": thr["goodput_tokens_per_s"],
                "mean_batch_occupancy": thr["mean_batch_occupancy"],
            }
            sweep.append(point)
            print(f"serve/open_{policy}_load{load},0.0,"
                  f"p50={lat['p50_s']:.1f};p99={lat['p99_s']:.1f};"
                  f"goodput={point['goodput_tokens_per_step']:.3f}",
                  flush=True)

    # -- acceptance: goodput at equal p99 --------------------------------
    static_pts = [p for p in sweep if p["policy"] == "static"]
    cont_pts = [p for p in sweep if p["policy"] == "continuous"]
    p99_budget = max(p["p99_steps"] for p in static_pts)
    static_goodput = max(p["goodput_tokens_per_step"] for p in static_pts)
    cont_under = [p["goodput_tokens_per_step"] for p in cont_pts
                  if p["p99_steps"] <= p99_budget]
    cont_goodput = max(cont_under) if cont_under else 0.0
    acceptance = {
        "p99_budget_steps": p99_budget,
        "static_goodput_tokens_per_step": static_goodput,
        "continuous_goodput_tokens_per_step": cont_goodput,
        "continuous_gt_static_at_equal_p99":
            bool(cont_goodput > static_goodput),
    }
    print(f"serve/acceptance,0.0,"
          f"static={static_goodput:.3f};continuous={cont_goodput:.3f};"
          f"p99_budget={p99_budget:.1f};"
          f"continuous_gt_static={acceptance['continuous_gt_static_at_equal_p99']}",
          flush=True)

    out = {
        "quick": quick,
        "config": {
            "arch": cfg.name, "bits": 3, "group_size": 32,
            "batch_size": batch, "max_seq": max_seq,
            "n_layers": cfg.n_layers, "d_model": cfg.d_model,
            "weights": "stream-direct",
        },
        "bit_identity": identity,
        "closed_loop": closed,
        "open_loop_sweep": sweep,
        "acceptance": acceptance,
    }
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_serve.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    if not all(v["identical"] for v in identity.values()):
        raise SystemExit(
            "serve bench: engine tokens are NOT bit-identical to the "
            "single-stream loop")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
