"""Benchmark harness: one function per paper table/figure + framework
benches.  Prints ``name,us_per_call,derived`` CSV rows.

  bench_example_layout   — paper §4 worked example (Figs. 3-5)
  bench_inv_helmholtz    — paper Table 6 (delta/W sweep)
  bench_matmul_widths    — paper Table 7 (custom-width sweep)
  bench_decode_module    — paper Listing 2 / §5 (decode-unit resources)
  bench_pack_throughput  — paper Listing 1 (host-side organization)
  bench_decode_kernel    — Pallas decode kernel vs numpy oracle
  bench_packed_matmul    — dequant-on-load matmul kernel vs oracle
  bench_model_packing    — Iris parameter streaming per architecture
  bench_scheduler_scale  — Iris runtime scaling (interval mode)
  bench_scheduler_throughput — unified engine: interval vs cycle on a
                           1M-cycle problem (bit-identical), layout-cache
                           hit vs miss, schedule_many batch dedupe
  bench_exec             — compiled execution plans vs the per-slot
                           legacy paths on the §4 LM layer bundle; also
                           writes machine-readable BENCH_exec.json at the
                           repo root and exits nonzero if the compiled
                           paths are not bit-identical to the legacy ones
                           (see bench_plan.py)
  bench_plan             — planner scale-out: cold vs parallel vs
                           incremental vs persistent planning on a
                           16-unique-signature stack + host vs device
                           pack; writes BENCH_plan.json and exits
                           nonzero on any bit-equivalence mismatch
                           (see bench_plan.py)
  bench_stream_matmul    — stream-direct matmul (decode fused into the
                           compute prologue) vs the two-pass path on the
                           int3 LM layer bundle; writes
                           BENCH_stream_mm.json (see bench_stream_mm.py)
  bench_serve            — serving engine: static vs continuous batching
                           latency/goodput sweep + bit-identity vs the
                           single-stream loop on the int3 smollm tree;
                           writes BENCH_serve.json (see bench_serve.py)
  bench_kvcache          — packed KV-cache streams: stream-direct decode
                           attention vs the dense-dequant oracle
                           (bit-identity gated), append-never-replans
                           accounting, KV bandwidth model; writes
                           BENCH_kvcache.json (see bench_kvcache.py)

CLI:  python benchmarks/run.py [--quick] [--only SUBSTR]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

#: set by --quick: smaller problem sizes, fewer repeats (CI smoke mode)
QUICK = False


def _timeit(fn, repeats: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def _timeit_min(fn, repeats: int = 5, warmup: int = 1) -> float:
    """Best-of-N in us — robust to the scheduler noise mean-of-N absorbs."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


# ----------------------------------------------------------------------
# Paper tables/figures — everything below drives the repro.api façade;
# the strategy loop iterates the registry instead of importing one
# function per layout family.  ``cache=None`` keeps the timings honest
# (a warm DEFAULT_CACHE would turn re-schedules into lookups).
# ----------------------------------------------------------------------
def bench_example_layout() -> None:
    from repro import api

    for label in api.strategies():
        us = _timeit(lambda label=label:
                     api.plan(api.PAPER_EXAMPLE, label, cache=None).layout)
        m = api.plan(api.PAPER_EXAMPLE, label, cache=None).metrics
        _row(f"example/{label}", us,
             f"C_max={m.c_max};L_max={m.l_max};B_eff={m.efficiency:.3f}")


def bench_inv_helmholtz() -> None:
    from repro import api
    from repro.api import INV_HELMHOLTZ, make_problem

    m = api.plan(INV_HELMHOLTZ, "homogeneous").metrics
    us = _timeit(lambda:
                 api.plan(INV_HELMHOLTZ, "homogeneous", cache=None).layout)
    fifo = sum(m.fifo_depth.values())
    _row("helmholtz/naive", us,
         f"C_max={m.c_max};L_max={m.l_max};B_eff={m.efficiency:.3f};"
         f"fifo={fifo}")
    for dw in (4, 3, 2, 1):
        p = make_problem(256, [(a.name, a.width, a.depth, a.due)
                               for a in INV_HELMHOLTZ.arrays], max_lanes=dw)
        us = _timeit(lambda p=p: api.plan(p, cache=None).layout)
        m = api.plan(p, cache=None).metrics
        fifo = sum(m.fifo_depth.values())
        _row(f"helmholtz/iris_dw{dw}", us,
             f"C_max={m.c_max};L_max={m.l_max};B_eff={m.efficiency:.3f};"
             f"fifo={fifo}")


def bench_matmul_widths() -> None:
    from repro import api
    from repro.api import matmul_problem

    for wa, wb in ((64, 64), (33, 31), (30, 19)):
        p = matmul_problem(wa, wb)
        for label, strat in (("naive", "homogeneous"), ("iris", "iris")):
            us = _timeit(lambda p=p, s=strat:
                         api.plan(p, s, cache=None).layout)
            m = api.plan(p, strat, cache=None).metrics
            fifo = sum(m.fifo_depth.values())
            _row(f"matmul_w{wa}x{wb}/{label}", us,
                 f"C_max={m.c_max};L_max={m.l_max};"
                 f"B_eff={m.efficiency:.3f};fifo={fifo}")


def bench_decode_module() -> None:
    """Listing 2 analogue: decode units, staging and ports per layout."""
    from repro import api
    from repro.api import PAPER_EXAMPLE, matmul_problem
    from repro.core.codegen import decode_plan

    for label, prob in (("example", PAPER_EXAMPLE),
                        ("matmul_33x31", matmul_problem(33, 31))):
        for kind, strat in (("iris", "iris"), ("naive", "homogeneous")):
            pl = api.plan(prob, strat)
            us = _timeit(lambda lay=pl.layout: decode_plan(lay))
            c_lines = len(pl.emit(target="c").splitlines())
            _row(f"decode_module/{label}/{kind}", us,
                 f"units={pl.decode_plan.n_units};"
                 f"fifo={sum(pl.decode_plan.fifo_depths.values())};"
                 f"ports={sum(pl.decode_plan.write_ports.values())};"
                 f"c_lines={c_lines}")


def bench_pack_throughput() -> None:
    from repro import api

    p = api.make_problem(256, [("w", 4, 65536, 10), ("s", 16, 4096, 10),
                               ("n", 16, 1024, 0), ("b", 32, 512, 20)])
    pl = api.plan(p)
    codes = api.random_codes(p)
    us = _timeit(lambda: pl.pack(codes), repeats=3)
    total_bytes = p.p_tot / 8
    _row("pack/host_throughput", us,
         f"MBps={total_bytes / us:.1f};bytes={int(total_bytes)}")


def bench_decode_kernel() -> None:
    from repro import api

    p = api.make_problem(128, [("q", 4, 8192, 4), ("s", 16, 512, 4),
                               ("b", 32, 128, 8)])
    pl = api.plan(p)
    buf = pl.pack(api.random_codes(p))
    us_k = _timeit(lambda: pl.decode(buf, backend="pallas", interpret=True),
                   repeats=2)
    us_r = _timeit(lambda: pl.decode(buf, backend="numpy"), repeats=2)
    _row("decode_kernel/pallas_interpret", us_k, f"oracle_us={us_r:.1f}")


def bench_packed_matmul() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels.packed_matmul import packed_matmul
    from repro.kernels.ref import packed_matmul_ref
    from repro.quant import QuantSpec, pack_codes_u32, quantize

    for bits in (4, 8):
        m, k, n = 64, 1024, 256
        w = jax.random.normal(jax.random.PRNGKey(0), (k, n), jnp.float32)
        qt = quantize(w, QuantSpec(bits=bits, group_size=128))
        pw = pack_codes_u32(qt.codes, bits)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32)

        def run(bits=bits, pw=pw, qt=qt, x=x):
            packed_matmul(x, pw, qt.scales, bits=bits, group_size=128,
                          block_m=64, block_k=256,
                          interpret=True).block_until_ready()

        us = _timeit(run, repeats=2)
        ref = packed_matmul_ref(x, pw, qt.scales, bits=bits, group_size=128)
        got = packed_matmul(x, pw, qt.scales, bits=bits, group_size=128,
                            block_m=64, block_k=256, interpret=True)
        err = float(jnp.abs(got - ref).max())
        packed_bytes = pw.size * 4 + qt.scales.size * 2
        dense_bytes = k * n * 2
        _row(f"packed_matmul/int{bits}", us,
             f"max_err={err:.2e};bytes_ratio={dense_bytes/packed_bytes:.2f}")


def bench_ssd_scan_kernel() -> None:
    """Pallas chunked linear-attention kernel vs the pure-JAX recurrence
    (the §Perf iterD5 lever for SSM/hybrid training memory)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.linear_scan import ssd_scan
    from repro.models.linear_attention import recurrent_scan

    b, t, h, d = 2, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, d), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, d), jnp.float32) * 0.5
    logw = -jax.nn.softplus(
        jax.random.normal(ks[3], (b, t, h), jnp.float32))

    def run_kernel():
        ssd_scan(q, k, v, logw, chunk=128,
                 interpret=True).block_until_ready()

    def run_ref():
        recurrent_scan(q, k, v, logw[..., None],
                       rwkv_mode=False)[0].block_until_ready()

    us_k = _timeit(run_kernel, repeats=2)
    us_r = _timeit(run_ref, repeats=2)
    got = ssd_scan(q, k, v, logw, chunk=128, interpret=True)
    want, _ = recurrent_scan(q, k, v, logw[..., None], rwkv_mode=False)
    err = float(jnp.abs(got - want).max())
    # HBM state traffic per chunk: pure-JAX round-trips the f32 state
    # every mini-chunk; the kernel keeps it in VMEM scratch
    state_traffic_ref = (t // 32) * 2 * b * h * d * d * 4
    _row("ssd_scan/pallas_vs_recurrence", us_k,
         f"ref_us={us_r:.1f};max_err={err:.2e};"
         f"ref_state_hbm_bytes={state_traffic_ref};kernel_state_hbm_bytes=0")


def bench_model_packing() -> None:
    from repro.configs import ARCH_IDS, get_config
    from repro.core.packing import serving_stream_report
    from repro.quant import QuantSpec

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for bits in (3, 4):
            t0 = time.perf_counter()
            r = serving_stream_report(cfg, QuantSpec(bits=bits,
                                                     group_size=128))
            us = (time.perf_counter() - t0) * 1e6
            _row(f"model_packing/{arch}/int{bits}", us,
                 f"iris_MiB={r['iris_MiB_per_layer']:.1f};"
                 f"pad_MiB={r['padded_MiB_per_layer']:.1f};"
                 f"bf16_MiB={r['bf16_MiB_per_layer']:.1f};"
                 f"B_eff={r['iris_efficiency']:.4f};"
                 f"Lmax_iris={r['iris_L_max']};"
                 f"Lmax_hom={r['homogeneous_unit_L_max']};"
                 f"fifo_iris={r['iris_unit_fifo']};"
                 f"fifo_hom={r['homogeneous_unit_fifo']}")


def bench_scheduler_scale() -> None:
    # engine-level microbench: deliberately below the façade
    from repro.api import make_problem
    from repro.core.iris import schedule

    rng = np.random.default_rng(0)
    for n_arrays, depth in ((8, 1000), (16, 10_000), (32, 100_000)):
        specs = [(f"a{i}", int(rng.integers(3, 33)),
                  int(rng.integers(depth // 2, depth)),
                  int(rng.integers(0, 64))) for i in range(n_arrays)]
        p = make_problem(512, specs)
        us = _timeit(lambda p=p: schedule(p, mode="interval"), repeats=2)
        lay = schedule(p, mode="interval")
        _row(f"scheduler/interval_n{n_arrays}_d{depth}", us,
             f"C_max={lay.c_max};intervals={len(lay.intervals())};"
             f"B_eff={lay.metrics().efficiency:.4f}")


def bench_scheduler_throughput() -> None:
    """Unified-engine throughput: the ISSUE-1 acceptance benchmark.

    (a) a 1M-cycle lane-capped problem (paper Table 6's delta/W knob at
        model-packing scale): event-driven interval mode vs per-cycle
        replay, asserting the layouts are bit-identical;
    (b) an LRM-contended multi-release problem: layout-cache miss vs hit
        (the serving hot path — repeated identical problems);
    (c) schedule_many over a uniform 32-layer stack: one scheduler run,
        31 rebinds.
    """
    # engine-level microbench: deliberately below the façade
    from repro.api import make_problem
    from repro.core.iris import LayoutCache, schedule, schedule_many

    # (a) every task runs at its (capped) full rate -> long constant runs
    specs = [(f"a{i}", 8, 7_900_000 + 60_000 * i, 25_000 * i)
             for i in range(8)]
    p_big = make_problem(512, specs, max_lanes=8)
    t0 = time.perf_counter()
    lay_i = schedule(p_big, mode="interval")
    t_interval = time.perf_counter() - t0
    t0 = time.perf_counter()
    lay_c = schedule(p_big, mode="cycle")
    t_cycle = time.perf_counter() - t0
    assert lay_c.count_intervals == lay_i.count_intervals
    _row("scheduler_throughput/1M_interval", t_interval * 1e6,
         f"cycle_us={t_cycle*1e6:.0f};speedup={t_cycle/t_interval:.0f}x;"
         f"C_max={lay_i.c_max};intervals={len(lay_i.intervals())};"
         f"identical=True")

    # (b) contended problem: the expensive case the cache absorbs
    specs = [("a", 7, 15_000_000, 0), ("b", 9, 11_000_000, 120_000),
             ("c", 12, 9_000_000, 300_000), ("d", 17, 6_000_000, 500_000),
             ("e", 23, 4_000_000, 700_000)]
    p_hot = make_problem(512, specs)
    cache = LayoutCache()
    t0 = time.perf_counter()
    schedule(p_hot, mode="interval", cache=cache)
    t_miss = time.perf_counter() - t0
    t0 = time.perf_counter()
    schedule(p_hot, mode="interval", cache=cache)
    t_hit = time.perf_counter() - t0
    _row("scheduler_throughput/cache_hit", t_hit * 1e6,
         f"miss_us={t_miss*1e6:.0f};speedup={t_miss/t_hit:.0f}x;"
         f"C_max={cache.lookup(p_hot).c_max}")

    # (c) uniform stack: every layer is the same scheduling instance
    layers = [make_problem(
        512, [(f"t{j}", 4 + 2 * j, 200_000, 5_000 * j) for j in range(6)])
        for _ in range(32)]
    cache = LayoutCache()
    t0 = time.perf_counter()
    outs = schedule_many(layers, cache=cache)
    t_batch = time.perf_counter() - t0
    _row("scheduler_throughput/batch_32_layers", t_batch * 1e6,
         f"runs={cache.misses};hits={cache.hits};"
         f"C_max={outs[0].c_max}")


def bench_exec() -> None:
    """Compiled exec plans vs per-slot legacy paths + bit-identity gate
    (full bench in bench_plan.py; writes BENCH_exec.json)."""
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    from bench_plan import run_exec as _exec_run

    _exec_run(quick=QUICK)


def bench_plan() -> None:
    """Planner scale-out: cold vs parallel vs incremental vs persistent
    planning + host vs device pack, all bit-equivalence gated (full
    bench in bench_plan.py; writes BENCH_plan.json)."""
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    from bench_plan import run as _plan_run

    _plan_run(quick=QUICK)


def bench_stream_matmul() -> None:
    """Stream-direct vs two-pass serving on the int3 LM layer bundle
    (full bench in bench_stream_mm.py; writes BENCH_stream_mm.json)."""
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    from bench_stream_mm import run as _stream_mm_run

    _stream_mm_run(quick=QUICK)


def bench_serve() -> None:
    """Serving engine: static vs continuous batching + bit-identity gate
    (full bench in bench_serve.py; writes BENCH_serve.json)."""
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    from bench_serve import run as _serve_run

    _serve_run(quick=QUICK)


def bench_kvcache() -> None:
    """Packed KV-cache streams: stream-direct attention vs dense oracle
    + append-never-replans gate (full bench in bench_kvcache.py; writes
    BENCH_kvcache.json)."""
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    from bench_kvcache import run as _kvcache_run

    _kvcache_run(quick=QUICK)


ALL = [
    bench_example_layout,
    bench_inv_helmholtz,
    bench_matmul_widths,
    bench_decode_module,
    bench_pack_throughput,
    bench_decode_kernel,
    bench_packed_matmul,
    bench_ssd_scan_kernel,
    bench_model_packing,
    bench_scheduler_scale,
    bench_scheduler_throughput,
    bench_exec,
    bench_plan,
    bench_stream_matmul,
    bench_serve,
    bench_kvcache,
]


def main() -> None:
    global QUICK
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes / fewer repeats (CI smoke)")
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="run only benches whose name contains SUBSTR")
    args = ap.parse_args()
    QUICK = args.quick
    fns = [f for f in ALL
           if args.only is None or args.only in f.__name__]
    if not fns:
        raise SystemExit(f"no bench matches {args.only!r}")
    print("name,us_per_call,derived")
    for fn in fns:
        fn()


if __name__ == "__main__":
    main()
