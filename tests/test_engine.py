"""repro.engine: queue admission, scheduler invariants, stream uploads,
metrics, and the bit-identity contract vs single-stream serving.

The expensive model-backed tests (packed trees, interpret-mode Pallas)
share one session fixture and keep request counts tiny; everything else
runs on a no-JAX stub adapter so queue/scheduler/metrics semantics are
exercised at Python speed (including the hypothesis fairness property).
"""
from __future__ import annotations

import copy

import numpy as np
import pytest

from repro.engine import (
    REJECT_BACKLOG_FULL,
    REJECT_DEADLINE_EXPIRED,
    AdmissionQueue,
    BufferRing,
    Engine,
    EngineConfig,
    EngineMetrics,
    EngineRequest,
    greedy_sampler,
    percentile,
)

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


# ----------------------------------------------------------------------
# stub adapter: scheduler semantics without a model
# ----------------------------------------------------------------------
class StubAdapter:
    """Deterministic no-JAX adapter: logits one-hot the slot's last
    token + 1 (mod vocab), so generated streams are predictable."""

    vocab = 16

    def __init__(self) -> None:
        self.reset_calls: list[int] = []
        self.step_actives: list[list[int]] = []

    def init_state(self, batch_size: int, max_seq: int) -> dict:
        return {"batch": batch_size}

    def reset_slot(self, state: dict, i: int) -> None:
        self.reset_calls.append(i)

    def step(self, state, tokens, active):
        self.step_actives.append(list(active))
        logits = np.zeros((len(active), self.vocab), np.float32)
        for j, t in enumerate(np.asarray(tokens)):
            logits[j, (int(t) + 1) % self.vocab] = 1.0
        return logits, state

    def stream_bytes_uploaded(self):
        return None


def _stub_engine(batch=2, max_seq=64, **cfg_kw) -> Engine:
    return Engine(StubAdapter(), EngineConfig(batch_size=batch,
                                              max_seq=max_seq, **cfg_kw))


def _reqs(n, *, prompt_len=2, max_new=3, **kw):
    return [EngineRequest(uid=i, prompt=list(range(1, 1 + prompt_len)),
                          max_new_tokens=max_new, **kw) for i in range(n)]


# ----------------------------------------------------------------------
# admission queue
# ----------------------------------------------------------------------
class TestAdmissionQueue:
    def test_backlog_overflow_rejects_with_reason(self):
        q = AdmissionQueue(max_backlog=2, clock=lambda: 0.0)
        assert q.submit(EngineRequest(0, [1], 1))
        assert q.submit(EngineRequest(1, [1], 1))
        adm = q.submit(EngineRequest(2, [1], 1))
        assert not adm
        assert adm.reason == REJECT_BACKLOG_FULL
        assert q.rejected_by_reason == {REJECT_BACKLOG_FULL: 1}
        assert len(q) == 2

    def test_deadline_expiry_at_submit_and_pop(self):
        t = [0.0]
        q = AdmissionQueue(clock=lambda: t[0])
        late = EngineRequest(0, [1], 1, deadline=-1.0)
        adm = q.submit(late)
        assert not adm and adm.reason == REJECT_DEADLINE_EXPIRED
        assert late.status == "rejected"
        # expires while waiting: rejected lazily at pop
        q.submit(EngineRequest(1, [1], 1, deadline=5.0))
        q.submit(EngineRequest(2, [1], 1))
        t[0] = 10.0
        got = q.pop()
        assert got is not None and got.uid == 2
        assert (1, REJECT_DEADLINE_EXPIRED) in q.rejections

    def test_priority_then_fifo(self):
        q = AdmissionQueue(clock=lambda: 0.0)
        for uid, pri in [(0, 0), (1, 5), (2, 0), (3, 5)]:
            q.submit(EngineRequest(uid, [1], 1, priority=pri))
        assert [q.pop().uid for _ in range(4)] == [1, 3, 0, 2]

    def test_drain_expired(self):
        t = [0.0]
        q = AdmissionQueue(clock=lambda: t[0])
        q.submit(EngineRequest(0, [1], 1, deadline=1.0))
        q.submit(EngineRequest(1, [1], 1))
        t[0] = 2.0
        assert q.drain_expired() == 1
        assert len(q) == 1 and q.pop().uid == 1

    if HAVE_HYPOTHESIS:
        @hypothesis.given(st.lists(st.integers(0, 3), min_size=1,
                                   max_size=30))
        def test_fairness_priority_then_arrival_order(self, priorities):
            """Admission (pop) order is exactly (priority desc, arrival
            asc) — equal-priority requests are never reordered."""
            q = AdmissionQueue(max_backlog=None, clock=lambda: 0.0)
            for uid, pri in enumerate(priorities):
                q.submit(EngineRequest(uid, [1], 1, priority=pri))
            popped = [q.pop().uid for _ in range(len(priorities))]
            expect = [uid for _, uid in
                      sorted(((-p, uid) for uid, p in enumerate(priorities)))]
            assert popped == expect


# ----------------------------------------------------------------------
# scheduler semantics (stub adapter)
# ----------------------------------------------------------------------
class TestEngineScheduler:
    def test_slot_reuse_and_completion(self):
        eng = _stub_engine(batch=2)
        reqs = _reqs(5)
        for r in reqs:
            assert eng.submit(r)
        stats = eng.run_until_drained()
        assert stats.completed == 5 and stats.admitted == 5
        assert stats.tokens_generated == sum(r.max_new_tokens for r in reqs)
        assert eng.slots == [None, None] and not eng.queue
        assert all(r.done and r.status == "done" for r in reqs)
        # both slots were reused (5 admissions into 2 slots)
        assert len(eng.adapter.reset_calls) == 5
        assert set(eng.adapter.reset_calls) == {0, 1}

    def test_fifo_admission_order(self):
        eng = _stub_engine(batch=2)
        for r in _reqs(6):
            eng.submit(r)
        eng.run_until_drained()
        assert eng.admission_order == list(range(6))

    def test_active_set_never_exceeds_batch(self):
        eng = _stub_engine(batch=3)
        for r in _reqs(8, max_new=2):
            eng.submit(r)
        eng.run_until_drained()
        assert all(len(a) <= 3 for a in eng.adapter.step_actives)
        assert max(len(a) for a in eng.adapter.step_actives) == 3

    def test_static_policy_drains_batch_before_admitting(self):
        eng = _stub_engine(batch=2, policy="static")
        for r in _reqs(4):
            eng.submit(r)
        admits_when_busy = []
        eng.add_hook("admit", lambda e, s, ctx:
                     admits_when_busy.append((len(ctx.get("admitted", [])),
                                              e.n_active)))
        eng.run_until_drained()
        assert eng.stats.completed == 4
        # whenever the batch held leftover actives, nothing was admitted
        for n_admitted, n_active in admits_when_busy:
            if n_admitted:
                assert n_active == n_admitted  # only into an empty batch

    def test_continuous_policy_backfills_freed_slots(self):
        eng = _stub_engine(batch=2)
        short = EngineRequest(0, [1], 1)
        long = EngineRequest(1, [1], 8)
        queued = EngineRequest(2, [1], 1)
        for r in (short, long, queued):
            eng.submit(r)
        eng.run_until_drained()
        # uid 2 backfilled uid 0's freed slot while uid 1 still ran:
        # it finished before uid 1 and shared at least one step with it
        assert eng.completion_order == [0, 2, 1]
        assert any(len(a) == 2 for a in eng.adapter.step_actives[1:])

    def test_engine_rejects_feed_metrics(self):
        eng = _stub_engine(batch=1, max_backlog=1)
        eng.submit(EngineRequest(0, [1], 4))
        eng.step()                         # uid 0 occupies the only slot
        eng.submit(EngineRequest(1, [1], 1))
        adm = eng.submit(EngineRequest(2, [1], 1))
        assert not adm and adm.reason == REJECT_BACKLOG_FULL
        eng.run_until_drained()
        snap = eng.metrics.snapshot()
        assert snap["requests"]["rejected"] == 1
        assert snap["requests"]["rejected_by_reason"] == {
            REJECT_BACKLOG_FULL: 1}
        assert snap["requests"]["completed"] == 2

    def test_max_seq_guard_completes_request(self):
        eng = _stub_engine(batch=1, max_seq=4)
        r = EngineRequest(0, [1, 2], max_new_tokens=50)
        eng.submit(r)
        eng.run_until_drained()
        assert r.done and len(r.generated) < 50

    def test_eos_token_stops_generation(self):
        # stub emits (last_token + 1) % 16; prompt [1] -> 2, 3, 4, ...
        eng = Engine(StubAdapter(), EngineConfig(batch_size=1, max_seq=64,
                                                 eos_token=4))
        r = EngineRequest(0, [1], max_new_tokens=50)
        eng.submit(r)
        eng.run_until_drained()
        assert r.generated[-1] == 4 and len(r.generated) == 3


class TestSampler:
    def test_greedy_sampler_requires_single_row(self):
        """The per-slot contract: a batched logits matrix must be
        refused, not argmax'd across slots (which would return an index
        into B*V — another slot's token scaled out of vocab range)."""
        with pytest.raises(ValueError, match="one slot's logits row"):
            greedy_sampler(np.zeros((2, 16), np.float32),
                           EngineRequest(0, [1], 1))

    def test_engine_samples_per_slot(self):
        """Every sampler call sees exactly one 1-D row and its own
        request, and every sampled token is in vocab range."""
        seen = []

        def sampler(row, req):
            row = np.asarray(row)
            assert row.ndim == 1 and row.shape[0] == StubAdapter.vocab
            seen.append(req.uid)
            return int(row.argmax())

        eng = Engine(StubAdapter(), EngineConfig(batch_size=2, max_seq=64),
                     sampler=sampler)
        reqs = _reqs(4)
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        assert set(seen) == {0, 1, 2, 3}
        for r in reqs:
            assert all(0 <= t < StubAdapter.vocab for t in r.generated)

    def test_stub_streams_are_per_slot_not_flattened(self):
        """Two concurrent slots generate their own deterministic
        streams: (tok+1) mod vocab chains from each request's prompt."""
        eng = _stub_engine(batch=2)
        a = EngineRequest(0, [3], max_new_tokens=3)
        b = EngineRequest(1, [9], max_new_tokens=3)
        eng.submit(a)
        eng.submit(b)
        eng.run_until_drained()
        assert a.generated == [4, 5, 6]
        assert b.generated == [10, 11, 12]


# ----------------------------------------------------------------------
# metrics
# ----------------------------------------------------------------------
class TestMetrics:
    def test_percentile_matches_numpy(self):
        xs = [5.0, 1.0, 9.0, 3.0, 7.5, 2.2]
        for p in (0, 25, 50, 90, 99, 100):
            assert percentile(xs, p) == pytest.approx(
                float(np.percentile(xs, p)))

    def test_snapshot_schema_and_phases(self):
        t = [0.0]
        m = EngineMetrics(clock=lambda: t[0])
        m.record_submit(0)
        t[0] = 1.0
        m.record_admit(0)
        t[0] = 3.0
        m.record_first_token(0)
        m.record_token(0)
        t[0] = 6.0
        m.record_token(0)
        m.record_complete(0)
        m.record_step(2)
        snap = m.snapshot()
        assert set(snap) == {"requests", "latency", "throughput"}
        assert set(snap["latency"]) == {"queue", "prefill", "decode",
                                        "total"}
        assert snap["latency"]["queue"]["p50_s"] == 1.0
        assert snap["latency"]["prefill"]["p50_s"] == 2.0
        assert snap["latency"]["decode"]["p50_s"] == 3.0
        assert snap["latency"]["total"]["p50_s"] == 6.0
        thr = snap["throughput"]
        assert thr["tokens_generated"] == 2
        assert thr["mean_batch_occupancy"] == 2.0
        assert thr["goodput_tokens_per_s"] == pytest.approx(2 / 6.0)

    def test_to_json_roundtrip(self, tmp_path):
        import json

        m = EngineMetrics()
        m.record_submit(0)
        p = tmp_path / "m.json"
        m.to_json(str(p))
        assert json.loads(p.read_text())["requests"]["submitted"] == 1


# ----------------------------------------------------------------------
# buffer ring / uploader (model-free parts)
# ----------------------------------------------------------------------
class TestBufferRing:
    def test_fifo_eviction_at_depth(self):
        r = BufferRing(depth=2)
        r.put("a", 1)
        r.put("b", 2)
        r.put("c", 3)
        assert r.keys() == ["b", "c"] and r.evictions == 1
        assert r.get("a") is None and r.get("c") == 3

    def test_reput_moves_to_end_without_eviction(self):
        r = BufferRing(depth=2)
        r.put("a", 1)
        r.put("b", 2)
        r.put("a", 10)
        assert r.keys() == ["b", "a"] and r.evictions == 0


# ----------------------------------------------------------------------
# legacy wrapper
# ----------------------------------------------------------------------
class TestServeLoopDeprecation:
    def test_names_warn_and_resolve(self):
        import repro.runtime.serve_loop as sl

        with pytest.warns(DeprecationWarning, match="repro.engine.Engine"):
            loop_cls = sl.ServeLoop
        assert loop_cls is sl._ServeLoop
        with pytest.warns(DeprecationWarning,
                          match="repro.engine.EngineRequest"):
            req_cls = sl.Request
        assert req_cls is EngineRequest
        with pytest.raises(AttributeError):
            sl.does_not_exist


# ----------------------------------------------------------------------
# model-backed: packed trees, bit-identity, uploader equivalence
# ----------------------------------------------------------------------
@pytest.fixture(scope="session")
def packed_setup():
    import jax

    from repro import api
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.quant import QuantSpec

    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab_size=128)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    trees = {bits: api.pack_tree(cfg, params,
                                 QuantSpec(bits=bits, group_size=32), m=512)
             for bits in (3, 4)}
    return cfg, model, trees


def _oracle_tokens(cfg, model, tree, req):
    """Single-stream reference: the request served alone, batch=1,
    plain full-batch ``packed_decode_step`` — engine-independent."""
    import jax.numpy as jnp

    from repro.models.quantized import packed_decode_step

    state = model.init_decode_state(1, 32)
    generated: list[int] = []
    pos = 0
    while len(generated) < req.max_new_tokens and pos < 31:
        tok = req.prompt[pos] if pos < len(req.prompt) else generated[-1]
        logits, state = packed_decode_step(
            cfg, tree, state, jnp.asarray([tok], jnp.int32), interpret=True)
        pos += 1
        if pos >= len(req.prompt):
            generated.append(int(np.asarray(logits[0]).argmax()))
    return generated


@pytest.mark.parametrize("bits", [3, 4])
def test_engine_tokens_bit_identical_to_single_stream(packed_setup, bits):
    """Continuous batching must not change a single token: the engine's
    ragged multi-slot decode equals serving each request alone."""
    from repro.engine import PackedAdapter

    cfg, model, trees = packed_setup
    tree = trees[bits]
    reqs = [EngineRequest(uid=0, prompt=[5, 9], max_new_tokens=2),
            EngineRequest(uid=1, prompt=[17, 3, 8], max_new_tokens=3),
            EngineRequest(uid=2, prompt=[40], max_new_tokens=2)]
    eng = Engine(PackedAdapter(cfg, tree),
                 EngineConfig(batch_size=2, max_seq=32))
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == 3
    for r in reqs:
        want = _oracle_tokens(cfg, model, tree,
                              copy.deepcopy(
                                  EngineRequest(r.uid, r.prompt,
                                                r.max_new_tokens)))
        assert r.generated == want, f"uid={r.uid} bits={bits}"


def test_ragged_step_rows_bit_identical_to_full_batch(packed_setup):
    """packed_decode_step(slot_ids=...) computes exactly the full-batch
    values for the selected rows, and only those rows' clocks advance."""
    import jax.numpy as jnp

    from repro.models.quantized import packed_decode_step

    cfg, model, trees = packed_setup
    tree = trees[3]
    state = model.init_decode_state(4, 16)
    full, _ = packed_decode_step(cfg, tree, state,
                                 jnp.asarray([5, 6, 7, 8], jnp.int32),
                                 interpret=True)
    ragged, st = packed_decode_step(cfg, tree, state,
                                    jnp.asarray([6, 8], jnp.int32),
                                    interpret=True,
                                    slot_ids=jnp.asarray([1, 3], jnp.int32))
    assert (np.asarray(full)[[1, 3]] == np.asarray(ragged)).all()
    assert np.asarray(st["pos"]).tolist() == [0, 1, 0, 1]


def test_stream_uploader_matches_resident_buffers(packed_setup):
    """The uploader hands back word-for-word the tree's own stream
    views, and its prefetch/ring counters reflect the double-buffering."""
    from repro.engine import StreamUploader

    cfg, model, trees = packed_setup
    tree = trees[3]
    with StreamUploader(tree) as up:
        for layer in range(tree.n_layers):
            got = np.asarray(up(layer))
            want = np.asarray(tree.layer_stream_words(layer))
            assert (got == want).all()
        # second lap: every fetch is a prefetch hit
        hits0 = up.prefetch_hits
        for layer in range(tree.n_layers):
            up(layer)
        assert up.prefetch_hits >= hits0 + tree.n_layers
        assert up.uploads <= 2 * tree.n_layers
        s = up.stats()
        assert s["bytes_uploaded"] > 0 and s["ring_depth"] == 2


def test_stream_uploader_requires_stream_buffers(packed_setup):
    from repro import api
    from repro.engine import StreamUploader
    from repro.quant import QuantSpec

    import jax

    cfg, model, trees = packed_setup
    params = model.init(jax.random.PRNGKey(0))
    bare = api.pack_tree(cfg, params, QuantSpec(bits=4, group_size=32),
                         m=512, with_streams=False)
    with pytest.raises(ValueError, match="with_streams=False"):
        StreamUploader(bare)


def test_engine_with_uploader_bit_identical(packed_setup):
    """Stream uploads through the ring change nothing about the math."""
    from repro.engine import PackedAdapter, StreamUploader

    cfg, model, trees = packed_setup
    tree = trees[3]

    def run(uploader):
        reqs = [EngineRequest(uid=0, prompt=[5, 9], max_new_tokens=2),
                EngineRequest(uid=1, prompt=[17, 3], max_new_tokens=2)]
        eng = Engine(PackedAdapter(cfg, tree, uploader=uploader),
                     EngineConfig(batch_size=2, max_seq=32))
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        return [r.generated for r in reqs], eng

    base, _ = run(None)
    with StreamUploader(tree) as up:
        uploaded, eng = run(up)
    assert uploaded == base
    # stream-bytes accounting flowed into the metrics
    assert eng.metrics.stream_bytes == up.bytes_uploaded
    snap = eng.metrics.snapshot()
    assert snap["throughput"]["stream_bytes"] > 0
    # the full uploader counter dict rides in the snapshot too
    want = up.stats()
    got = snap["throughput"]["uploader"]
    assert got["uploads"] == want["uploads"] > 0
    assert got["bytes_uploaded"] == want["bytes_uploaded"] == \
        up.bytes_uploaded
    assert got["prefetch_hits"] == want["prefetch_hits"] > 0
    assert got["ring_depth"] == 2


def test_engine_without_uploader_snapshot_has_empty_uploader_dict():
    snap = _stub_engine().metrics.snapshot()
    assert snap["throughput"]["uploader"] == {}


def test_engine_resets_fallback_warning_state():
    """Constructing an Engine clears the once-per-process host-fallback
    warning sets in *both* kernel modules, so a fresh serving run warns
    again instead of inheriting a stale silence."""
    from repro.kernels import layout_decode, layout_pack

    layout_decode._FALLBACK_WARNED.add(("stale", "w"))
    layout_pack._FALLBACK_WARNED.add(("stale", "w"))
    _stub_engine()
    assert not layout_decode._FALLBACK_WARNED
    assert not layout_pack._FALLBACK_WARNED


@pytest.mark.parametrize("bits", [3, 4])
def test_engine_packed_kv_stream_bit_identical_to_dense_oracle(
        packed_setup, bits):
    """Engine-level KV acceptance gate: serving on the packed KV cache
    with the stream-direct attention kernel produces tokens
    bit-identical to the materialized dense-dequant oracle over the same
    pages, across ragged admission (3 requests on 2 slots), and the
    appends never touch the planner."""
    from repro.core.iris import DEFAULT_CACHE
    from repro.engine import PackedAdapter

    cfg, model, trees = packed_setup
    tree = trees[bits]

    def run(kv_attention):
        reqs = [EngineRequest(uid=0, prompt=[5, 9], max_new_tokens=2),
                EngineRequest(uid=1, prompt=[17, 3, 8], max_new_tokens=3),
                EngineRequest(uid=2, prompt=[40], max_new_tokens=2)]
        eng = Engine(PackedAdapter(cfg, tree, kv="packed",
                                   kv_attention=kv_attention,
                                   page_tokens=8),
                     EngineConfig(batch_size=2, max_seq=32))
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert stats.completed == 3
        return [r.generated for r in reqs], eng

    stream, eng = run("stream")
    kvc = eng.state["packed_kv"]
    assert kvc.plan_stats["scheduler_runs"] <= 1
    misses0 = DEFAULT_CACHE.misses
    dense, _ = run("dense")
    assert stream == dense
    # the whole second serve (create + every append) re-used the layout
    assert DEFAULT_CACHE.misses == misses0
