"""Substrate tests: data pipeline, checkpointing, optimizer, compression,
fault-tolerant train loop, serving loop."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLMPipeline
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.compression import GradCompressor
from repro.runtime.serve_loop import Request, ServeLoop
from repro.runtime.train_loop import TrainLoopConfig, run_training


class TestDataPipeline:
    def test_deterministic(self):
        p1 = SyntheticLMPipeline(128, 16, 8, seed=7)
        p2 = SyntheticLMPipeline(128, 16, 8, seed=7)
        b1, b2 = p1.next_batch(), p2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        p = SyntheticLMPipeline(128, 16, 4, seed=0)
        b = p.next_batch()
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_slicing_consistent(self):
        p = SyntheticLMPipeline(128, 16, 8, seed=3)
        full = p.peek_batch(0)
        for host in range(4):
            lo, hi = p.host_slice(host, 4)
            part = p.peek_batch(0, lo, hi)
            np.testing.assert_array_equal(part["tokens"],
                                          full["tokens"][lo:hi])

    def test_checkpoint_resume_stream(self):
        p = SyntheticLMPipeline(128, 16, 4, seed=1)
        p.next_batch()
        p.next_batch()
        saved = p.state_dict()
        b3 = p.next_batch()
        q = SyntheticLMPipeline(128, 16, 4, seed=999)
        q.load_state_dict(saved)
        np.testing.assert_array_equal(q.next_batch()["tokens"],
                                      b3["tokens"])

    def test_learnable_structure(self):
        """Bigram entropy of the stream must be far below uniform."""
        p = SyntheticLMPipeline(64, 512, 4, seed=0, noise=0.02)
        b = p.next_batch()
        t = b["tokens"]
        # next-token accuracy of the generating rule itself
        pred = (np.arange(1, 8)[:, None, None] * t[:, 1:-1]) % 64
        # at least one (a, b=0-ish) rule should predict many transitions
        best = max(float((pred[i] == t[:, 2:]).mean()) for i in range(7))
        assert best > 0.1


class TestCheckpoint:
    def _tree(self, k=0):
        return {"a": jnp.arange(8, dtype=jnp.float32) + k,
                "b": {"c": jnp.ones((3, 4), jnp.bfloat16) * (k + 1)}}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_n=2)
        mgr.save(5, self._tree(1), extra={"pipeline": {"seed": 1, "step": 5}})
        out, extra = mgr.restore(self._tree())
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.arange(8, dtype=np.float32) + 1)
        assert extra["pipeline"]["step"] == 5

    def test_keep_n_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_n=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(s))
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_n=3)
        for s in (1, 2):
            mgr.save_async(s, self._tree(s))
        mgr.wait()
        assert mgr.all_steps() == [1, 2]
        out, _ = mgr.restore(self._tree(), step=2)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.arange(8, dtype=np.float32) + 2)

    def test_atomicity_ignores_partial_dirs(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_n=3)
        mgr.save(1, self._tree(1))
        # simulate a crashed writer: tmp dir with garbage
        crash = tmp_path / "step_00000002.tmp-dead-1"
        crash.mkdir()
        (crash / "arr_00000.npy").write_bytes(b"partial")
        assert mgr.all_steps() == [1]
        mgr.save(3, self._tree(3))           # gc removes stale tmp
        assert not crash.exists()

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, self._tree())
        bad = {"a": jnp.zeros(9), "b": {"c": jnp.zeros((3, 4))}}
        with pytest.raises(ValueError):
            mgr.restore(bad)


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                          weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        opt = init_opt_state(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, opt, m = adamw_update(cfg, grads, opt, params)
        assert float(jnp.abs(params["w"]).max()) < 0.3
        assert m["grad_norm"] >= 0

    def test_no_decay_on_norm_params(self):
        cfg = AdamWConfig(lr=1e-2, weight_decay=1e9, warmup_steps=0)
        params = {"norm_scale": jnp.ones((4,)), "w": jnp.ones((4, 4))}
        opt = init_opt_state(params)
        new, _, _ = adamw_update(cfg, jax.tree.map(jnp.zeros_like, params),
                                 opt, params)
        # zero grads: the only update comes from weight decay, which must
        # hit the 2-D weight but never the norm scale
        np.testing.assert_allclose(np.asarray(new["norm_scale"]), 1.0)
        assert not np.allclose(np.asarray(new["w"]), 1.0)


class TestCompression:
    def test_roundtrip_error_bounded(self):
        comp = GradCompressor(block=64)
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (130,))}
        ef = comp.init_state(g)
        deq, ef = comp.compress_decompress(g, ef)
        err = np.abs(np.asarray(deq["w"] - g["w"]))
        amax = np.abs(np.asarray(g["w"])).max()
        assert err.max() <= amax / 127 + 1e-6

    def test_error_feedback_compensates(self):
        """Summed over steps, EF-compressed grads track the true sum."""
        comp = GradCompressor(block=32)
        key = jax.random.PRNGKey(1)
        g_true = jnp.full((64,), 0.003)       # below one int8 LSB of amax
        ef = comp.init_state({"w": g_true})
        acc = np.zeros(64)
        for i in range(50):
            noise = jax.random.normal(jax.random.fold_in(key, i), (64,))
            g = {"w": g_true + 0.5 * noise}
            deq, ef = comp.compress_decompress(g, ef)
            acc += np.asarray(deq["w"]) - np.asarray(g["w"])
        # residual stays bounded (no drift): EF keeps compression unbiased
        assert np.abs(acc).max() < 0.05

    def test_wire_bytes(self):
        comp = GradCompressor(block=256)
        g = {"w": jnp.zeros((1024,))}
        c, u = comp.wire_bytes(g)
        assert u == 4096 and c == 1024 + 4 * 4


def _tiny_setup(tmp_path, total_steps=12, ckpt_interval=4):
    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
        vocab_size=64, head_dim=32)
    from repro.launch.steps import build_train_step, init_train_state
    from repro.optim.adamw import AdamWConfig as AC
    step_fn = jax.jit(build_train_step(
        cfg, AC(lr=1e-2, warmup_steps=2, total_steps=total_steps)))
    pipeline = SyntheticLMPipeline(64, 32, 4, seed=0)
    init = lambda: init_train_state(cfg, jax.random.PRNGKey(0))
    loop_cfg = TrainLoopConfig(total_steps=total_steps,
                               ckpt_interval=ckpt_interval, max_restarts=3)
    return step_fn, init, pipeline, loop_cfg


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        step_fn, init, pipe, cfg = _tiny_setup(tmp_path, total_steps=25,
                                               ckpt_interval=10)
        rep = run_training(step_fn, init, pipe, str(tmp_path / "ck"), cfg)
        assert rep.steps_run == 25
        assert np.mean(rep.losses[-5:]) < np.mean(rep.losses[:5])

    def test_failure_recovery_resumes_from_checkpoint(self, tmp_path):
        step_fn, init, pipe, cfg = _tiny_setup(tmp_path)
        crashed = {"done": False}

        def injector(step):
            if step == 6 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("simulated node failure")

        rep = run_training(step_fn, init, pipe, str(tmp_path / "ck"), cfg,
                           fail_injector=injector)
        assert rep.restarts == 1
        # steps 4..5 replayed after restoring the step-4 checkpoint
        assert rep.steps_run == 12 + 2

    def test_straggler_hook_fires(self, tmp_path):
        step_fn, init, pipe, cfg = _tiny_setup(tmp_path)
        seen = []
        slow = {"armed": True}
        orig = step_fn

        def wrapped(state, batch):
            if slow["armed"] and pipe.state.step == 9:
                slow["armed"] = False
                time.sleep(1.0)
            return orig(state, batch)

        rep = run_training(wrapped, init, pipe, str(tmp_path / "ck"), cfg,
                           on_straggler=lambda s, dt: seen.append((s, dt)))
        assert rep.stragglers >= 1 and seen

    def test_resume_across_runs(self, tmp_path):
        step_fn, init, pipe, cfg = _tiny_setup(tmp_path, total_steps=8,
                                               ckpt_interval=4)
        run_training(step_fn, init, pipe, str(tmp_path / "ck"), cfg)
        # second invocation: nothing left to do, resumes from step 8
        pipe2 = SyntheticLMPipeline(64, 32, 4, seed=0)
        rep2 = run_training(step_fn, init, pipe2, str(tmp_path / "ck"), cfg)
        assert rep2.resumed_from == 8
        assert rep2.steps_run == 0


class TestServeLoop:
    def test_continuous_batching_completes_all(self):
        cfg = get_config("smollm-135m").reduced(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab_size=64, head_dim=32)
        model = Model(cfg, remat="none")
        params = model.init(jax.random.PRNGKey(0))
        loop = ServeLoop(model, params, batch_size=2, max_seq=32)
        for uid in range(5):
            loop.submit(Request(uid=uid, prompt=[1 + uid, 2, 3],
                                max_new_tokens=4))
        stats = loop.run_until_drained(max_steps=200)
        assert stats.completed == 5
        assert stats.tokens_generated == 5 * 4
        # slot reuse happened: 5 requests through 2 slots
        assert stats.admitted == 5

def test_slot_isolation_outputs_match():
    """Generated tokens for identical prompts agree across slot histories."""
    cfg = get_config("rwkv6-3b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab_size=64)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))

    def run(with_history):
        loop = ServeLoop(model, params, batch_size=1, max_seq=48)
        reqs = []
        if with_history:
            r0 = Request(uid=0, prompt=[31, 17, 5, 23], max_new_tokens=6)
            loop.submit(r0)
            reqs.append(r0)
        r1 = Request(uid=1, prompt=[1, 2, 3], max_new_tokens=5)
        loop.submit(r1)
        reqs.append(r1)
        loop.run_until_drained(max_steps=100)
        return r1.generated

    assert run(False) == run(True)
