"""Property-based tests (hypothesis) for the Iris scheduler's invariants.

Skipped gracefully where hypothesis is not installed (the seeded-random
subset in tests/test_scheduler_engine.py still runs there).
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.baselines import homogeneous_layout, naive_layout
from repro.core.iris import schedule
from repro.core.layout import Layout
from repro.core.task import ArraySpec, LayoutProblem


@st.composite
def problems(draw, max_arrays=6, max_width=12, max_depth=24, max_due=40, m_choices=(8, 16, 32, 64)):
    m = draw(st.sampled_from(m_choices))
    n = draw(st.integers(1, max_arrays))
    arrays = []
    for i in range(n):
        w = draw(st.integers(1, min(max_width, m)))
        d = draw(st.integers(1, max_depth))
        due = draw(st.integers(0, max_due))
        arrays.append(ArraySpec(f"a{i}", w, d, due))
    return LayoutProblem(m=m, arrays=tuple(arrays))


@given(problems())
@settings(max_examples=150, deadline=None)
def test_schedule_is_valid_and_complete(p):
    lay = schedule(p)
    lay.validate()   # no bus overflow, no overlap, every element exactly once


@given(problems())
@settings(max_examples=150, deadline=None)
def test_cmax_lower_bound(p):
    """C_max * m >= p_tot and C_max >= max over arrays of min cycles."""
    lay = schedule(p)
    m = lay.metrics()
    assert m.c_max * p.m >= p.p_tot
    assert 0 < m.efficiency <= 1.0
    for a in p.arrays:
        assert m.c_max >= a.height(p.m)


@given(problems())
@settings(max_examples=100, deadline=None)
def test_iris_never_worse_than_homogeneous_cmax(p):
    """Iris packs at least as densely as the per-array homogeneous layout."""
    iris = schedule(p).metrics()
    homog = homogeneous_layout(p).metrics()
    assert iris.c_max <= homog.c_max


@given(problems())
@settings(max_examples=100, deadline=None)
def test_iris_never_worse_than_naive(p):
    naive = naive_layout(p).metrics()
    iris = schedule(p).metrics()
    assert iris.c_max <= naive.c_max
    assert iris.efficiency >= naive.efficiency - 1e-12


@given(problems())
@settings(max_examples=75, deadline=None)
def test_interval_mode_matches_cycle_mode(p):
    """The unified engine's event-driven mode is *bit-identical* to the
    per-cycle replay: same interval runs, hence same metrics — not merely
    close (the pre-unification engine tolerated O(1)-cycle transients)."""
    cyc = schedule(p, mode="cycle")
    itv = schedule(p, mode="interval")
    itv.validate()
    assert itv.count_intervals == cyc.count_intervals
    assert itv.metrics().row() == cyc.metrics().row()


@given(problems())
@settings(max_examples=75, deadline=None)
def test_interval_mode_bit_identical_with_fill_residual(p):
    cyc = schedule(p, mode="cycle", fill_residual=True)
    itv = schedule(p, mode="interval", fill_residual=True)
    assert itv.count_intervals == cyc.count_intervals


@given(problems())
@settings(max_examples=75, deadline=None)
def test_fill_residual_never_hurts_cmax(p):
    """Beyond-paper refinement: offering LRM leftovers to lower groups."""
    faithful = schedule(p, fill_residual=False).metrics()
    filled = schedule(p, fill_residual=True).metrics()
    assert filled.c_max <= faithful.c_max


@given(problems())
@settings(max_examples=75, deadline=None)
def test_fifo_depth_bounded_by_peak_rate(p):
    """Backlog cannot exceed (peak elems/cycle - 1) * C_max."""
    lay = schedule(p)
    peak = lay.max_concurrent_elems()
    c_max = lay.c_max
    for depth, pk in zip(lay.fifo_depths(), peak):
        assert depth <= max(0, pk - 1) * c_max
        if pk <= 1:
            assert depth == 0


@given(problems())
@settings(max_examples=50, deadline=None)
def test_layout_cycles_view_agrees_with_intervals(p):
    """The lazily materialized per-cycle view must re-merge to the same IR."""
    lay = schedule(p)
    rebuilt = Layout.from_counts(
        p,
        [
            tuple((s.array, s.n_elems) for s in segs)
            for segs in lay.cycles
        ],
    )
    assert rebuilt.c_max == lay.c_max
    assert rebuilt.metrics().row() == lay.metrics().row()


@given(problems())
@settings(max_examples=50, deadline=None)
def test_element_positions_cover_all_elements(p):
    lay = schedule(p)
    for i, a in enumerate(p.arrays):
        pos = lay.element_positions(i)
        assert len(pos) == a.depth
        assert len(set(pos)) == a.depth
        for (t, off) in pos:
            assert 0 <= t < lay.c_max
            assert 0 <= off <= p.m - a.width
