"""End-to-end packed serving: quantize -> Iris layout -> packed decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import get_config
from repro.core.packing import pack_bundle, layer_bundle_spec
from repro.models.model import Model
from repro.models.quantized import (
    bytes_per_token_report,
    packed_decode_step,
    quantizable,
)
from repro.quant import QuantSpec


def quantize_params(cfg, params, spec):
    """All pack/plan wiring goes through the one front door."""
    return api.pack_tree(cfg, params, spec, with_streams=False)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=128, head_dim=32)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_quantizable_families():
    assert quantizable(get_config("smollm-135m").reduced())
    assert quantizable(get_config("mistral-large-123b").reduced())
    assert not quantizable(get_config("rwkv6-3b").reduced())
    assert not quantizable(get_config("whisper-medium").reduced())


def test_packed_decode_matches_dense(dense_setup):
    """int8 packed decode tracks the bf16 dense path closely."""
    cfg, model, params = dense_setup
    pp = quantize_params(cfg, params, QuantSpec(bits=8, group_size=32))
    b = 2
    state = model.init_decode_state(b, max_seq=16)
    toks = jnp.array([3, 77], jnp.int32)
    dense_logits, dense_state = jax.jit(model.decode_step)(
        params, state, toks, None)
    packed_logits, packed_state = packed_decode_step(
        cfg, pp, state, toks, interpret=True)
    # rank agreement on the top prediction + bounded numeric gap
    d = np.asarray(dense_logits, np.float32)
    q = np.asarray(packed_logits, np.float32)
    assert np.abs(q - d).max() < 0.25 * np.abs(d).max() + 0.5
    assert (np.argmax(q, -1) == np.argmax(d, -1)).mean() >= 0.5
    assert (np.asarray(packed_state["pos"]) == 1).all()


def test_multi_step_packed_generation(dense_setup):
    cfg, model, params = dense_setup
    pp = quantize_params(cfg, params, QuantSpec(bits=8, group_size=32))
    state = model.init_decode_state(2, max_seq=16)
    toks = jnp.array([5, 9], jnp.int32)
    for i in range(4):
        logits, state = packed_decode_step(cfg, pp, state, toks,
                                           interpret=True)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
    assert (np.asarray(state["pos"]) == 4).all()


def test_bytes_report_orders(dense_setup):
    cfg, _, params = dense_setup
    pp4 = quantize_params(cfg, params, QuantSpec(bits=4, group_size=32))
    r = bytes_per_token_report(cfg, pp4)
    # packed < padded-int < bf16 weight traffic per decode token
    assert r["packed_MiB"] < r["bf16_MiB"]
    assert r["padded_int_MiB"] <= r["bf16_MiB"]


def test_bundle_layout_for_quantized_layer(dense_setup):
    """The Iris layout over the quantized bundle is valid and dense."""
    cfg, _, _ = dense_setup
    spec = QuantSpec(bits=3, group_size=32)
    bundle = layer_bundle_spec(cfg.d_model, cfg.d_ff, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim, spec)
    pb = pack_bundle(bundle, m=512)
    pb.layout.validate()
    assert pb.metrics_iris["B_eff"] > 0.95
    # dataflow due dates: attention norm precedes mlp down-projection
    comp = pb.layout.metrics().completion
    assert comp["attn_norm"] <= comp["w_down"]
