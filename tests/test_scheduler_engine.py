"""Unified event-driven engine: bit-identity, layout cache, batch API.

These tests are hypothesis-free on purpose — they are the container-safe
half of the engine's property coverage (tests/test_iris_properties.py
carries the hypothesis versions) and must run wherever pytest runs.
"""
import numpy as np
import pytest

from repro.core.iris import (
    DEFAULT_CACHE,
    LayoutCache,
    schedule,
    schedule_many,
)
from repro.core.task import (
    ArraySpec,
    INV_HELMHOLTZ,
    LayoutProblem,
    PAPER_EXAMPLE,
    make_problem,
)


def _random_problem(rng) -> LayoutProblem:
    m = int(rng.choice([8, 16, 32, 64]))
    n = int(rng.integers(1, 8))
    arrays = tuple(
        ArraySpec(
            f"a{i}",
            width=int(rng.integers(1, min(13, m) + 1)),
            depth=int(rng.integers(1, 120)),
            due=int(rng.integers(0, 41)),
            max_lanes=int(rng.integers(1, 9)) if rng.random() < 0.3 else None,
        )
        for i in range(n)
    )
    return LayoutProblem(m=m, arrays=arrays)


# ----------------------------------------------------------------------
# bit-identity: interval mode == cycle-mode replay
# ----------------------------------------------------------------------
def test_interval_bit_identical_to_cycle_randomized():
    rng = np.random.default_rng(0)
    for _ in range(150):
        p = _random_problem(rng)
        for fill_residual in (False, True):
            cyc = schedule(p, mode="cycle", fill_residual=fill_residual)
            itv = schedule(p, mode="interval", fill_residual=fill_residual)
            itv.validate()
            assert itv.count_intervals == cyc.count_intervals, (
                p, fill_residual)


def test_interval_bit_identical_at_depth():
    """Deep problems exercise the jump bounds and the periodic
    fast-forward; identity must hold there too, not just at toy sizes."""
    rng = np.random.default_rng(7)
    for _ in range(8):
        specs = [(f"a{i}", int(rng.integers(2, 17)),
                  int(rng.integers(5000, 30000)),
                  int(rng.integers(0, 300)))
                 for i in range(int(rng.integers(2, 9)))]
        p = make_problem(128, specs)
        cyc = schedule(p, mode="cycle")
        itv = schedule(p, mode="interval")
        assert itv.count_intervals == cyc.count_intervals, p


def test_interval_bit_identical_lane_capped():
    """Full-rate (delta/W-capped) problems take the lockstep jump path."""
    specs = [(f"a{i}", 8, 7_900 + 60 * i, 25 * i) for i in range(8)]
    p = make_problem(512, specs, max_lanes=8)
    cyc = schedule(p, mode="cycle")
    itv = schedule(p, mode="interval")
    assert itv.count_intervals == cyc.count_intervals
    itv.validate()


def test_paper_example_unchanged_by_engine():
    """The unified engine must reproduce the paper's §4 numbers."""
    for mode in ("cycle", "interval"):
        m = schedule(PAPER_EXAMPLE, mode=mode).metrics()
        assert (m.c_max, m.l_max) == (9, 3)
        assert abs(m.efficiency - 0.958) < 1e-3


# ----------------------------------------------------------------------
# layout cache
# ----------------------------------------------------------------------
def test_cache_hit_returns_same_layout_object():
    cache = LayoutCache()
    lay1 = schedule(PAPER_EXAMPLE, cache=cache)
    lay2 = schedule(PAPER_EXAMPLE, cache=cache)
    assert lay2 is lay1
    assert cache.stats == {"hits": 1, "misses": 1, "size": 1,
                           "maxsize": 256, "warm_starts": 0,
                           "disk_hits": 0, "disk_rejects": 0}


def test_cache_is_name_independent_and_rebinds():
    cache = LayoutCache()
    p1 = make_problem(8, [("x", 2, 5, 2), ("y", 3, 5, 6)])
    p2 = make_problem(8, [("u", 2, 5, 2), ("v", 3, 5, 6)])
    lay1 = schedule(p1, cache=cache)
    lay2 = schedule(p2, cache=cache)
    assert cache.misses == 1 and cache.hits == 1
    assert lay2.count_intervals == lay1.count_intervals
    # the rebound layout speaks the caller's names
    assert set(lay2.metrics().lateness) == {"u", "v"}
    assert lay2.count_intervals == schedule(p2).count_intervals


def test_cache_keys_on_fill_residual():
    cache = LayoutCache()
    schedule(PAPER_EXAMPLE, cache=cache, fill_residual=False)
    schedule(PAPER_EXAMPLE, cache=cache, fill_residual=True)
    assert cache.misses == 2 and cache.hits == 0


def test_cache_mode_not_in_key():
    """Bit-identity makes a cycle-mode layout answer interval requests."""
    cache = LayoutCache()
    a = schedule(PAPER_EXAMPLE, mode="cycle", cache=cache)
    b = schedule(PAPER_EXAMPLE, mode="interval", cache=cache)
    assert b is a and cache.hits == 1


def test_cache_lru_eviction():
    cache = LayoutCache(maxsize=2)
    p = [make_problem(8, [("a", 2, d, 0)]) for d in (3, 4, 5)]
    schedule(p[0], cache=cache)
    schedule(p[1], cache=cache)
    schedule(p[0], cache=cache)        # refresh p0 -> p1 becomes LRU
    schedule(p[2], cache=cache)        # evicts p1
    assert len(cache) == 2
    assert cache.lookup(p[1]) is None
    assert cache.lookup(p[0]) is not None


def test_cache_rejects_bad_maxsize():
    with pytest.raises(ValueError):
        LayoutCache(maxsize=0)


def test_cached_layout_matches_fresh_schedule():
    rng = np.random.default_rng(3)
    cache = LayoutCache()
    for _ in range(25):
        p = _random_problem(rng)
        fresh = schedule(p)
        cached_first = schedule(p, cache=cache)
        cached_again = schedule(p, cache=cache)
        assert fresh.count_intervals == cached_first.count_intervals
        assert cached_again.count_intervals == fresh.count_intervals


def test_canonical_signature_orders_and_ignores_names():
    p1 = make_problem(8, [("x", 2, 5, 2), ("y", 3, 5, 6)])
    p2 = make_problem(8, [("a", 2, 5, 2), ("b", 3, 5, 6)])
    p3 = make_problem(8, [("y", 3, 5, 6), ("x", 2, 5, 2)])  # reordered
    assert p1.canonical_signature() == p2.canonical_signature()
    assert p1.canonical_signature() != p3.canonical_signature()


def test_rebind_rejects_different_instance():
    lay = schedule(PAPER_EXAMPLE)
    with pytest.raises(ValueError):
        lay.rebind(INV_HELMHOLTZ)


# ----------------------------------------------------------------------
# batch API
# ----------------------------------------------------------------------
def test_schedule_many_dedupes_identical_instances():
    layers = [make_problem(64, [("w", 4, 500, 10), ("s", 16, 120, 10)])
              for _ in range(6)]
    cache = LayoutCache()
    outs = schedule_many(layers, cache=cache)
    assert len(outs) == 6
    assert cache.misses == 1 and cache.hits == 5
    base = schedule(layers[0])
    for lay in outs:
        assert lay.count_intervals == base.count_intervals


def test_schedule_many_preserves_order_and_handles_mixed_batches():
    p_a = make_problem(8, [("a", 2, 5, 2)])
    p_b = make_problem(8, [("b", 3, 7, 4)])
    outs = schedule_many([p_a, p_b, p_a], cache=None)
    assert outs[0].count_intervals == outs[2].count_intervals
    assert outs[0].problem is p_a and outs[1].problem is p_b
    assert outs[1].count_intervals == schedule(p_b).count_intervals


def test_default_cache_is_shared_and_bounded():
    assert DEFAULT_CACHE.maxsize == 512
    lay = schedule_many([PAPER_EXAMPLE])[0]
    assert DEFAULT_CACHE.lookup(PAPER_EXAMPLE) is lay
