"""Per-kernel tests: shape/dtype sweeps asserting allclose vs ref.py oracles.

All Pallas kernels run in interpret=True mode (CPU container; TPU is the
lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import homogeneous_layout, naive_layout
from repro.core.codegen import pack_arrays, random_codes
from repro.core.iris import schedule
from repro.kernels.layout_decode import decode_slot
from repro.kernels.ops import buffer_to_u32, decode_layout
from repro.kernels.packed_matmul import packed_matmul
from repro.kernels.ref import decode_layout_ref, decode_slot_ref, packed_matmul_ref
from repro.quant import QuantSpec, dequantize, pack_codes_u32, quantize, unpack_codes_u32


# ----------------------------------------------------------------------
# layout_decode
# ----------------------------------------------------------------------
class TestDecodeSlot:
    @pytest.mark.parametrize("width", [1, 3, 4, 7, 8, 12, 16, 17, 31, 32])
    @pytest.mark.parametrize("n_rows", [1, 7, 256, 300])
    def test_width_row_sweep(self, width, n_rows):
        rng = np.random.default_rng(width * 1000 + n_rows)
        words = 6
        rows = rng.integers(0, 1 << 32, size=(n_rows, words), dtype=np.uint64)
        rows = rows.astype(np.uint32)
        # a handful of in-bounds lane offsets (must fit within words-1 words
        # so the funnel shift's second word exists)
        max_off = (words - 1) * 32 - width
        offsets = tuple(sorted(rng.integers(0, max_off, size=3).tolist()))
        got = decode_slot(jnp.asarray(rows), offsets=offsets, width=width,
                          n_rows=n_rows, interpret=True)
        want = decode_slot_ref(rows, offsets, width, n_rows)
        np.testing.assert_array_equal(np.asarray(got), want)

    def test_word_straddling_offsets(self):
        """Elements crossing u32 word boundaries must funnel-shift exactly."""
        rng = np.random.default_rng(0)
        rows = rng.integers(0, 1 << 32, size=(64, 4), dtype=np.uint64)
        rows = rows.astype(np.uint32)
        for width in (17, 24, 31):
            off = 32 - (width // 2)          # deliberately straddles
            got = decode_slot(jnp.asarray(rows), offsets=(off,), width=width,
                              n_rows=64, interpret=True)
            want = decode_slot_ref(rows, (off,), width, 64)
            np.testing.assert_array_equal(np.asarray(got), want)


class TestDecodeLayout:
    # shared with the golden-file suite via conftest
    from conftest import DECODE_PROBLEMS as PROBLEMS

    @pytest.mark.parametrize("prob_idx", range(len(PROBLEMS)))
    @pytest.mark.parametrize("layout_fn", [schedule, homogeneous_layout,
                                           naive_layout])
    def test_roundtrip_through_kernel(self, prob_idx, layout_fn):
        p = self.PROBLEMS[prob_idx]
        lay = layout_fn(p)
        lay.validate()
        codes = random_codes(p, seed=prob_idx)
        buf = pack_arrays(lay, codes)
        ref = decode_layout_ref(lay, buf)
        got = decode_layout(lay, buf, interpret=True)
        for name, want in codes.items():
            np.testing.assert_array_equal(
                np.asarray(got[name], dtype=np.uint64), ref[name])
            np.testing.assert_array_equal(ref[name], want)

    def test_buffer_to_u32_layout(self):
        buf = np.arange(32, dtype=np.uint8).reshape(2, 16)
        w = np.asarray(buffer_to_u32(buf))
        assert w.shape == (2, 6)          # 4 data words + 2 spare
        assert w[0, 0] == 0x03020100      # little-endian
        assert w[1, 0] == 0x13121110
        assert (w[:, 4:] == 0).all()


# ----------------------------------------------------------------------
# packed_matmul
# ----------------------------------------------------------------------
class TestPackedMatmul:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("shape", [(16, 256, 128), (128, 512, 256),
                                       (8, 1024, 128)])
    def test_bits_shape_sweep(self, bits, shape):
        m, k, n = shape
        spec = QuantSpec(bits=bits, group_size=128)
        key = jax.random.PRNGKey(bits)
        w = jax.random.normal(key, (k, n), dtype=jnp.float32)
        qt = quantize(w, spec)
        pw = pack_codes_u32(qt.codes, bits)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32)
        got = packed_matmul(x, pw, qt.scales, bits=bits, group_size=128,
                            block_m=min(128, m), block_k=256, interpret=True)
        want = packed_matmul_ref(x, pw, qt.scales, bits=bits, group_size=128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("x_dtype", [jnp.float32, jnp.bfloat16])
    def test_input_dtypes(self, x_dtype):
        spec = QuantSpec(bits=4, group_size=64)
        w = jax.random.normal(jax.random.PRNGKey(2), (256, 128), jnp.float32)
        qt = quantize(w, spec)
        pw = pack_codes_u32(qt.codes, 4)
        x = jax.random.normal(jax.random.PRNGKey(3), (32, 256)).astype(x_dtype)
        got = packed_matmul(x, pw, qt.scales, bits=4, group_size=64,
                            block_m=32, block_k=128, interpret=True)
        want = packed_matmul_ref(x, pw, qt.scales, bits=4, group_size=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_matches_dense_dequant_matmul(self):
        """End to end: packed path == x @ dequantize(quantize(w))."""
        spec = QuantSpec(bits=4, group_size=128)
        w = jax.random.normal(jax.random.PRNGKey(4), (512, 256), jnp.float32)
        qt = quantize(w, spec)
        x = jax.random.normal(jax.random.PRNGKey(5), (64, 512), jnp.float32)
        got = packed_matmul(x, pack_codes_u32(qt.codes, 4), qt.scales,
                            bits=4, group_size=128, interpret=True)
        want = x @ dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("m", [1, 5, 37, 100])
    def test_ragged_m_padded_internally(self, m):
        """Serving batch sizes are ragged: M need not tile by block_m."""
        spec = QuantSpec(bits=4, group_size=64)
        w = jax.random.normal(jax.random.PRNGKey(6), (256, 128), jnp.float32)
        qt = quantize(w, spec)
        pw = pack_codes_u32(qt.codes, 4)
        x = jax.random.normal(jax.random.PRNGKey(7), (m, 256), jnp.float32)
        got = packed_matmul(x, pw, qt.scales, bits=4, group_size=64,
                            block_m=64, block_k=128, interpret=True)
        want = packed_matmul_ref(x, pw, qt.scales, bits=4, group_size=64)
        assert got.shape == (m, 128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_bad_shapes_rejected(self):
        x = jnp.zeros((32, 256))
        pw = jnp.zeros((256 * 4 // 32, 128), jnp.uint32)
        s = jnp.ones((2, 128))
        with pytest.raises(ValueError):
            packed_matmul(x, pw, s, bits=4, group_size=100, interpret=True)
        with pytest.raises(ValueError):
            packed_matmul(x, jnp.zeros((3, 128), jnp.uint32), s, bits=4,
                          group_size=128, interpret=True)
        # genuinely invalid N tiling still errors
        with pytest.raises(ValueError):
            packed_matmul(x, pw, jnp.ones((2, 128)), bits=4, group_size=128,
                          block_n=96, interpret=True)


# ----------------------------------------------------------------------
# quantization substrate
# ----------------------------------------------------------------------
class TestQuant:
    @pytest.mark.parametrize("bits", [2, 3, 4, 5, 8])
    def test_roundtrip_error_bound(self, bits):
        spec = QuantSpec(bits=bits, group_size=64)
        w = jax.random.normal(jax.random.PRNGKey(bits), (256, 64), jnp.float32)
        qt = quantize(w, spec)
        wd = dequantize(qt)
        # symmetric grid: |err| <= scale/2, plus bf16 scale rounding which
        # perturbs every dequantized value by up to |q| * scale * 2^-8
        g = 256 // 64
        amax = np.abs(np.asarray(w).reshape(g, 64, 64)).max(axis=1)
        bound = (amax / spec.qmax) * 0.5 + amax * 2.0 ** -7 + 1e-6
        err = np.abs(np.asarray(wd - w)).reshape(g, 64, 64).max(axis=1)
        assert (err <= bound).all()

    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_lane_pack_roundtrip(self, bits):
        rng = np.random.default_rng(bits)
        k, n = 128, 32
        codes = rng.integers(0, 1 << bits, size=(k, n)).astype(np.uint8)
        packed = pack_codes_u32(jnp.asarray(codes), bits)
        assert packed.shape == (k * bits // 32, n)
        back = unpack_codes_u32(packed, bits, k)
        np.testing.assert_array_equal(np.asarray(back), codes)

    def test_bad_specs(self):
        with pytest.raises(ValueError):
            QuantSpec(bits=1)
        with pytest.raises(ValueError):
            QuantSpec(bits=9)
        with pytest.raises(ValueError):
            pack_codes_u32(jnp.zeros((128, 8), jnp.uint8), 3)
