"""`PackedTree`: the jit-compatible pytree front door.

Covers the redesign's acceptance criteria: a PackedTree passes through
``jax.jit`` / ``jax.device_put`` / ``NamedSharding`` unchanged; packed
checkpoint save→restore is bit-identical without dense materialization
and rebinds layouts from the manifest (cache-hit counter asserted, the
scheduler provably never runs); and the packed views `pack_tree` builds
are bit-identical to the pre-redesign lane-packing algorithm, so decode
outputs are unchanged.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import get_config
from repro.core.iris import LayoutCache
from repro.models.model import Model
from repro.quant import QuantSpec
from repro.quant.qtypes import pack_codes_u32, quantize

SPEC = QuantSpec(bits=4, group_size=32)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=128, head_dim=32)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    pt = api.pack_tree(cfg, params, SPEC, cache=LayoutCache())
    return cfg, model, params, pt


def _assert_trees_bit_identical(a, b):
    for k in a.packed:
        assert np.array_equal(np.asarray(a.packed[k]),
                              np.asarray(b.packed[k])), k
        assert np.array_equal(np.asarray(a.scales[k]).view(np.uint16),
                              np.asarray(b.scales[k]).view(np.uint16)), k
    assert np.array_equal(np.asarray(a.streams), np.asarray(b.streams))
    assert a.manifest == b.manifest


# ----------------------------------------------------------------------
# the pytree contract
# ----------------------------------------------------------------------
def test_packed_views_match_pre_redesign_algorithm(setup):
    """pack_tree's kernel views == the old hand-rolled quantize+lane-pack
    loop, bit for bit — so packed decode outputs are unchanged."""
    cfg, _, params, pt = setup
    blocks = params["blocks"][0]
    for sub in ("attn", "mlp"):
        for name, w in blocks[sub].items():
            if name not in ("wq", "wk", "wv", "wo",
                            "w_gate", "w_up", "w_down"):
                continue
            qt = jax.vmap(lambda wl: quantize(wl, SPEC))(w)
            pk = jax.vmap(lambda c: pack_codes_u32(c, SPEC.bits))(qt.codes)
            key = f"{sub}/{name}"
            assert np.array_equal(np.asarray(pk),
                                  np.asarray(pt.packed[key]))
            assert np.array_equal(
                np.asarray(qt.scales).view(np.uint16),
                np.asarray(pt.scales[key]).view(np.uint16))


def test_jit_roundtrip_unchanged(setup):
    *_, pt = setup
    out = jax.jit(lambda t: t)(pt)
    assert type(out) is type(pt)
    _assert_trees_bit_identical(pt, out)


def test_tree_map_preserves_structure(setup):
    *_, pt = setup
    doubled = jax.tree.map(lambda x: x, pt)
    assert doubled.manifest == pt.manifest
    assert jax.tree_util.tree_structure(doubled) \
        == jax.tree_util.tree_structure(pt)


def test_device_put_with_named_sharding_roundtrip(setup):
    """Acceptance: device_put with a NamedSharding leaves the tree
    unchanged (single-device mesh in-process; multi-device below)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    *_, pt = setup
    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), pt)
    out = jax.device_put(pt, shardings)
    _assert_trees_bit_identical(pt, out)


def test_decode_step_consumes_packed_tree(setup):
    cfg, model, params, pt = setup
    from repro.models.quantized import packed_decode_step

    state = model.init_decode_state(2, max_seq=16)
    toks = jnp.array([3, 77], jnp.int32)
    logits, new_state = packed_decode_step(cfg, pt, state, toks)
    dense_logits, _ = jax.jit(model.decode_step)(params, state, toks, None)
    d = np.asarray(dense_logits, np.float32)
    q = np.asarray(logits, np.float32)
    assert np.isfinite(q).all()
    assert (np.argmax(q, -1) == np.argmax(d, -1)).mean() >= 0.5
    assert (np.asarray(new_state["pos"]) == 1).all()


def test_pack_tree_non_lane_bits_serve_stream_direct(setup):
    """Widths without a lane-packed kernel view (3/5/6/7) used to be
    rejected outright; the stream-direct matmul made them servable —
    pack_tree now builds a streams-only tree for them."""
    cfg, _, params, _ = setup
    pt = api.pack_tree(cfg, params, QuantSpec(bits=5, group_size=32))
    assert pt.packed == {}                  # no kernel views ...
    assert pt.streams is not None           # ... streams carry the weights
    # forcing kernel views for a non-lane-packable width still errors
    with pytest.raises(ValueError, match=r"\(2, 4, 8\)|\[2, 4, 8\]"):
        api.pack_tree(cfg, params, QuantSpec(bits=5, group_size=32),
                      with_kernel_views=True)


def test_pack_tree_layer_stack_engine_cache(setup):
    """pack_tree drives plan_layer_stack: one scheduler run, then every
    further tree with the same shapes is a pure cache hit."""
    cfg, _, params, _ = setup
    cache = LayoutCache()
    pt1 = api.pack_tree(cfg, params, SPEC, cache=cache)
    assert pt1.provenance == "scheduled"
    assert cache.misses >= 1
    runs0 = cache.misses
    pt2 = api.pack_tree(cfg, params, SPEC, cache=cache)
    assert pt2.provenance == "cache-hit"
    assert cache.misses == runs0            # scheduler never re-ran
    assert pt1.manifest == pt2.manifest


def test_baseline_strategy_tree_isolated_from_iris_cache(setup):
    """A non-iris tree must not resolve to (or poison) the iris layout
    cached under the same problem signature."""
    cfg, _, params, pt_iris = setup
    cache = LayoutCache()
    pt_iris.manifest.resolve_layout(cache)   # warm cache with iris layout
    pt = api.pack_tree(cfg, params, SPEC, strategy="hls_padded",
                       cache=cache)
    assert pt.provenance == "closed-form"
    assert "cache=closed-form" in pt.summary()
    hits0 = cache.hits
    # restore path: warm iris cache present, baseline manifest must
    # rebuild from its own intervals — and round-trip bit-identically
    pt2 = api.unpack_streams(pt.manifest, pt.streams, pt.other,
                             cache=cache)
    assert pt2.provenance == "manifest"
    assert cache.hits == hits0               # iris entry untouched
    _assert_trees_bit_identical(pt, pt2)
    # and the iris signature entry was not overwritten by the baseline
    lay, prov = pt_iris.manifest.resolve_layout(cache)
    assert prov == "cache-hit"
    assert lay.count_intervals == pt_iris.manifest.intervals


# ----------------------------------------------------------------------
# streams <-> kernel views
# ----------------------------------------------------------------------
def test_stream_roundtrip_bit_identical(setup):
    *_, pt = setup
    pt2 = api.unpack_streams(pt.manifest, pt.streams, pt.other,
                             cache=LayoutCache())
    _assert_trees_bit_identical(pt, pt2)


def test_manifest_json_roundtrip_and_hashable(setup):
    *_, pt = setup
    man2 = api.LayoutManifest.from_json(pt.manifest.to_json())
    assert man2 == pt.manifest
    assert hash(man2) == hash(pt.manifest)


# ----------------------------------------------------------------------
# packed checkpoints: the HBM stream is the checkpoint
# ----------------------------------------------------------------------
def test_packed_checkpoint_roundtrip_warm_cache(setup, tmp_path):
    """Restore rebinds the layout through the shared cache — the
    cache-hit counter increments and codes are bit-identical."""
    from repro.checkpoint.checkpoint import CheckpointManager

    *_, pt = setup
    cache = LayoutCache()
    pt.manifest.resolve_layout(cache)       # warm the cache
    mgr = CheckpointManager(tmp_path, keep_n=2)
    mgr.save_packed(7, pt, extra={"tag": "warm"})
    hits0, misses0 = cache.hits, cache.misses
    pt2, extra = mgr.restore_packed(cache=cache)
    assert extra == {"tag": "warm"}
    assert pt2.provenance == "cache-hit"
    assert cache.hits == hits0 + 1          # rebind, not re-schedule
    assert cache.misses == misses0
    _assert_trees_bit_identical(pt, pt2)
    # unquantized leaves survive too (same structure => same leaf order)
    assert jax.tree_util.tree_structure(pt.other) \
        == jax.tree_util.tree_structure(pt2.other)
    for va, vb in zip(jax.tree_util.tree_leaves(pt.other),
                      jax.tree_util.tree_leaves(pt2.other)):
        assert np.array_equal(np.asarray(va), np.asarray(vb))


def test_packed_checkpoint_restore_never_schedules(setup, tmp_path,
                                                   monkeypatch):
    """Cold cache: the layout is rebuilt from the manifest's recorded
    count-intervals; the scheduler provably never runs."""
    import repro.core.iris as iris_mod
    from repro.checkpoint.checkpoint import CheckpointManager

    *_, pt = setup
    mgr = CheckpointManager(tmp_path, keep_n=2)
    mgr.save_packed(3, pt)

    def boom(*a, **kw):
        raise AssertionError("scheduler ran during packed restore")

    monkeypatch.setattr(iris_mod, "schedule", boom)
    monkeypatch.setattr(iris_mod, "schedule_many", boom)
    cold = LayoutCache()
    pt2, _ = mgr.restore_packed(cache=cold)
    assert pt2.provenance == "manifest"
    _assert_trees_bit_identical(pt, pt2)
    # the rebuilt layout was seeded into the cache: a second restore
    # (or any same-shape pack_tree) is now a rebind
    pt3, _ = mgr.restore_packed(cache=cold)
    assert pt3.provenance == "cache-hit"


def test_packed_checkpoint_no_dense_materialization(setup, tmp_path):
    """What hits disk is the packed stream + small leaves — far below
    the dense bf16 checkpoint of the same weights."""
    from repro.checkpoint.checkpoint import CheckpointManager

    cfg, _, params, pt = setup
    mgr = CheckpointManager(tmp_path / "packed", keep_n=1)
    pdir = mgr.save_packed(0, pt)
    packed_bytes = sum(
        f.stat().st_size for f in (tmp_path / "packed").glob("*/arr_*.npy"))
    dense = jax.tree.map(lambda x: jnp.asarray(x, jnp.bfloat16), params)
    mgr2 = CheckpointManager(tmp_path / "dense", keep_n=1)
    mgr2.save(0, dense)
    dense_bytes = sum(
        f.stat().st_size for f in (tmp_path / "dense").glob("*/arr_*.npy"))
    assert packed_bytes < dense_bytes
    # quantized majority of the weights is 4-bit + scales vs 16-bit
    assert "step_00000000" in pdir


def test_restore_packed_on_wrong_step_type(setup, tmp_path):
    from repro.checkpoint.checkpoint import CheckpointManager

    cfg, _, params, pt = setup
    mgr = CheckpointManager(tmp_path, keep_n=2)
    mgr.save(1, {"w": np.zeros(3)})
    with pytest.raises(ValueError, match="not a packed checkpoint"):
        mgr.restore_packed(step=1)


def test_with_streams_false_cannot_checkpoint(setup, tmp_path):
    from repro.checkpoint.checkpoint import CheckpointManager

    cfg, _, params, _ = setup
    pt = api.pack_tree(cfg, params, SPEC, with_streams=False,
                       cache=LayoutCache())
    assert pt.streams is None
    with pytest.raises(ValueError, match="with_streams"):
        CheckpointManager(tmp_path).save_packed(0, pt)


# ----------------------------------------------------------------------
# cross-mesh: save sharded on one mesh, restore on another and on CPU
# ----------------------------------------------------------------------
def _run_sub(body: str, n_devices: int, timeout: int = 560) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROCESS_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SUBPROCESS_OK" in r.stdout
    return r.stdout


_BUILD = """
import jax, numpy as np
from repro import api
from repro.configs import get_config
from repro.models.model import Model
from repro.quant import QuantSpec
cfg = get_config("smollm-135m").reduced(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab_size=128, head_dim=32)
params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
pt = api.pack_tree(cfg, params, QuantSpec(bits=4, group_size=32))
"""


def test_packed_checkpoint_cross_mesh(setup, tmp_path):
    """Save a PackedTree placed on a (2,2) mesh; restore it on a 2-device
    mesh in a different process and on single-device CPU — packed codes
    bit-identical everywhere, zero scheduler runs on restore."""
    root = tmp_path / "xmesh"
    _run_sub(_BUILD + f"""
from repro.checkpoint.checkpoint import CheckpointManager
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import packed_tree_shardings
mesh = make_debug_mesh((2, 2), ("data", "model"))
pt_dev = jax.device_put(pt, packed_tree_shardings(pt, mesh))
assert pt_dev.packed["attn/wq"].sharding.spec[-1] == "model"
CheckpointManager({str(root)!r}).save_packed(5, pt_dev)
""", n_devices=4)
    _run_sub(_BUILD + f"""
import repro.core.iris as iris_mod
from repro.core.iris import LayoutCache
from repro.checkpoint.checkpoint import CheckpointManager
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import packed_tree_shardings
def boom(*a, **kw): raise AssertionError("scheduler ran")
iris_mod.schedule = iris_mod.schedule_many = boom
pt2, _ = CheckpointManager({str(root)!r}).restore_packed(
    cache=LayoutCache())
for k in pt.packed:
    assert np.array_equal(np.asarray(pt.packed[k]),
                          np.asarray(pt2.packed[k])), k
mesh = make_debug_mesh((2,), ("model",))
pt_dev = jax.device_put(pt2, packed_tree_shardings(pt2, mesh))
assert np.array_equal(np.asarray(pt_dev.streams), np.asarray(pt.streams))
""", n_devices=2)
    # and on plain single-device CPU, in-process
    from repro.checkpoint.checkpoint import CheckpointManager

    *_, pt = setup
    pt2, _ = CheckpointManager(root).restore_packed(cache=LayoutCache())
    _assert_trees_bit_identical(pt, pt2)


# ----------------------------------------------------------------------
# ergonomics: one-line summaries
# ----------------------------------------------------------------------
def test_plan_summary_and_repr():
    cache = LayoutCache()
    pl = api.plan(api.PAPER_EXAMPLE, cache=cache)
    assert "unscheduled" in repr(pl)
    s = pl.summary()
    assert "Plan[iris]" in s and "B_eff=" in s and "cache=scheduled" in s
    assert "KiB" in s
    s2 = api.plan(api.PAPER_EXAMPLE, cache=cache).summary()
    assert "cache=cache-hit" in s2
    assert "B_eff=" in repr(pl)             # scheduled repr == summary
    assert "cache=closed-form" in api.plan(
        api.PAPER_EXAMPLE, "naive", cache=cache).summary()


def test_packed_tree_summary(setup):
    *_, pt = setup
    s = pt.summary()
    assert "int4/g32" in s
    assert "strategy=iris" in s
    assert "B_eff=" in s
    assert "MiB" in s
    assert "cache=" in s
    assert repr(pt) == f"<{s}>"


# ----------------------------------------------------------------------
# deprecated pre-PackedTree surface
# ----------------------------------------------------------------------
def test_quantize_params_deprecated_but_equivalent(setup):
    cfg, _, params, pt = setup
    from repro.models.quantized import quantize_params

    with pytest.deprecated_call(match="repro.api.pack_tree"):
        old = quantize_params(cfg, params, SPEC)
    assert isinstance(old, api.PackedTree)
    assert old.streams is None
    for k in pt.packed:
        assert np.array_equal(np.asarray(old.packed[k]),
                              np.asarray(pt.packed[k]))
    assert old.shapes == pt.shapes
    assert old.spec == pt.spec
