"""Compiled execution plans: bit-equivalence to the per-slot legacy
paths, single-pallas_call fused decode, cache-hit program reuse, and
mixed-width end-to-end decode (deterministic suite; the hypothesis
sweep lives in test_exec_plan_properties.py)."""
import numpy as np
import pytest

from repro import api
from repro.core.baselines import homogeneous_layout, naive_layout
from repro.core.codegen import pack_arrays, random_codes, unpack_arrays
from repro.core.exec_plan import lower_exec, pack_compiled, unpack_compiled
from repro.core.iris import LayoutCache, schedule
from repro.core.task import PAPER_EXAMPLE, make_problem

# §4 worked example, non-power-of-two widths/bus, lane-capped, and a
# multi-interval many-release problem — the ISSUE-4 property-test axes
# (shared with the golden-file and stream-matmul suites via conftest)
from conftest import EXEC_PROBLEMS as PROBLEMS
LAYOUT_FNS = [schedule, homogeneous_layout, naive_layout]


@pytest.mark.parametrize("prob_idx", range(len(PROBLEMS)))
@pytest.mark.parametrize("layout_fn", LAYOUT_FNS)
class TestHostEquivalence:
    def test_pack_bit_identical(self, prob_idx, layout_fn):
        p = PROBLEMS[prob_idx]
        lay = layout_fn(p)
        codes = random_codes(p, seed=prob_idx)
        legacy = pack_arrays(lay, codes)
        compiled = pack_compiled(lay, codes)
        assert legacy.shape == compiled.shape
        assert np.array_equal(legacy, compiled)

    def test_unpack_roundtrip(self, prob_idx, layout_fn):
        p = PROBLEMS[prob_idx]
        lay = layout_fn(p)
        codes = random_codes(p, seed=prob_idx)
        buf = pack_compiled(lay, codes)
        got = unpack_compiled(lay, buf)
        legacy = unpack_arrays(lay, buf)
        for name, want in codes.items():
            np.testing.assert_array_equal(got[name], want)
            np.testing.assert_array_equal(got[name], legacy[name])


class TestFusedDecode:
    @pytest.mark.parametrize("prob_idx", range(len(PROBLEMS)))
    def test_fused_equals_legacy_and_codes(self, prob_idx):
        from repro.kernels.ops import decode_layout

        p = PROBLEMS[prob_idx]
        lay = schedule(p)
        codes = random_codes(p, seed=prob_idx)
        buf = pack_compiled(lay, codes)
        fused = decode_layout(lay, buf, interpret=True, fused=True)
        legacy = decode_layout(lay, buf, interpret=True, fused=False)
        for name, want in codes.items():
            np.testing.assert_array_equal(
                np.asarray(fused[name]).astype(np.uint64), want)
            np.testing.assert_array_equal(
                np.asarray(legacy[name]).astype(np.uint64), want)

    def test_single_pallas_call(self, monkeypatch):
        """The fused path launches exactly one Pallas kernel per decode."""
        import repro.kernels.layout_decode as ld

        p = make_problem(64, [("a", 5, 64, 4), ("b", 11, 30, 8),
                              ("c", 16, 12, 8)])
        lay = schedule(p)
        codes = random_codes(p, seed=0)
        buf = pack_compiled(lay, codes)
        prog = lower_exec(lay)
        prog.jit_cache.clear()          # force a fresh trace we can count
        calls = []
        real = ld.pl.pallas_call

        def counting(*a, **kw):
            calls.append(1)
            return real(*a, **kw)

        monkeypatch.setattr(ld.pl, "pallas_call", counting)
        out = ld.decode_layout_fused(lay, buf, interpret=True)
        assert len(calls) == prog.n_pallas_calls == 1
        for name, want in codes.items():
            np.testing.assert_array_equal(
                np.asarray(out[name]).astype(np.uint64), want)

    def test_mixed_width_end_to_end(self):
        """Slots wider than 32 bits route to the host path (both modes)."""
        from repro.kernels.ops import decode_layout

        p = make_problem(128, [("a", 8, 100, 10), ("w", 40, 21, 3),
                               ("z", 64, 9, 20)])
        lay = schedule(p)
        codes = random_codes(p, seed=3)
        buf = pack_arrays(lay, codes)
        prog = lower_exec(lay)
        assert prog.host_arrays == (1, 2)
        for fused in (True, False):
            got = decode_layout(lay, buf, interpret=True, fused=fused)
            for name, want in codes.items():
                np.testing.assert_array_equal(
                    np.asarray(got[name]).astype(np.uint64), want)


class TestProgramCaching:
    def test_cache_hit_returns_prebuilt_program(self):
        """A LayoutCache hit yields a plan whose exec program is already
        built — including across rebinds to renamed problems."""
        cache = LayoutCache()
        p1 = make_problem(64, [("x", 5, 60, 4), ("y", 9, 31, 9)])
        pl1 = api.plan(p1, cache=cache)
        prog1 = pl1.exec_program
        # same scheduling instance, different array names -> rebind path
        p2 = make_problem(64, [("u", 5, 60, 4), ("v", 9, 31, 9)])
        pl2 = api.plan(p2, cache=cache)
        assert pl2.layout._exec_cache is pl1.layout._exec_cache
        assert cache.hits >= 1
        assert pl2.exec_program is prog1

    def test_lowering_runs_once_per_signature(self, monkeypatch):
        import repro.core.exec_plan as ep

        cache = LayoutCache()
        p = make_problem(32, [("x", 3, 50, 5), ("y", 7, 30, 9)])
        calls = []
        real = ep._lower

        def counting(layout, ew):
            calls.append(1)
            return real(layout, ew)

        monkeypatch.setattr(ep, "_lower", counting)
        api.plan(p, cache=cache).exec_program
        api.plan(p, cache=cache).exec_program
        assert len(calls) == 1

    def test_fused_trace_memoized_on_program(self):
        from repro.kernels.ops import decode_layout

        p = make_problem(64, [("a", 4, 64, 4), ("b", 8, 16, 8)])
        lay = schedule(p)
        buf = pack_compiled(lay, random_codes(p, seed=0))
        prog = lower_exec(lay)
        decode_layout(lay, buf, fused=True, program=prog)
        assert len(prog.jit_cache) == 1
        decode_layout(lay, buf, fused=True, program=prog)
        assert len(prog.jit_cache) == 1


class TestFacade:
    def test_plan_pack_compiled_matches_legacy(self):
        pl = api.plan(PAPER_EXAMPLE)
        codes = random_codes(PAPER_EXAMPLE)
        assert np.array_equal(pl.pack(codes),
                              pl.pack(codes, compiled=False))

    def test_decode_backends_agree(self):
        p = make_problem(64, [("a", 5, 64, 4), ("b", 12, 30, 8)])
        pl = api.plan(p)
        codes = random_codes(p, seed=1)
        buf = pl.pack(codes)
        outs = [
            pl.decode(buf, backend="numpy"),
            pl.decode(buf, backend="numpy", compiled=False),
            pl.decode(buf, backend="pallas"),
            pl.decode(buf, backend="pallas", fused=False),
        ]
        for out in outs:
            for name, want in codes.items():
                np.testing.assert_array_equal(out[name], want)

    def test_layer_stack_exec_program_element_granularity(self):
        """Bundle-granular programs pack >64-bit units at element width."""
        from repro.quant import QuantSpec

        class Cfg:
            name = "toy"
            d_model, d_ff = 64, 128
            n_heads, n_kv_heads, head_dim = 4, 2, 16
            n_layers = 2

        stack = api.plan_layer_stack(Cfg, QuantSpec(bits=4, group_size=32),
                                     m=4096)
        assert any(a.width > 64 for a in stack.problem.arrays)
        prog = stack.exec_program()
        assert prog.n_pieces == sum(prog.piece_depths)
        assert stack.exec_program() is prog      # cached on the layout


class TestBundlePacking:
    def test_pack_bundle_matches_legacy_merge_path(self):
        """Element-granular compiled pack == unit merge + pack_arrays."""
        from repro.core.packing import BundleTensor, pack_bundle

        rng = np.random.default_rng(0)
        bundle = [BundleTensor("w", 4, 3000, 1),
                  BundleTensor("s", 16, 200, 1),
                  BundleTensor("n", 16, 64, 0)]
        data = {b.name: rng.integers(0, 1 << b.width_bits, b.n_elems,
                                     dtype=np.uint64) for b in bundle}
        pb = pack_bundle(bundle, m=512, data=data, cache=None)
        assert all(a.width <= 64 for a in pb.problem.arrays)
        # legacy: merge elements into scheduling units, then pack_arrays
        unit_data = {}
        for spec, b in zip(pb.problem.arrays, bundle):
            unit = spec.width // b.width_bits
            vals = np.asarray(data[b.name], dtype=np.uint64)
            vals = np.pad(vals, (0, spec.depth * unit - vals.shape[0]))
            merged = np.zeros(spec.depth, dtype=np.uint64)
            for k in range(unit):
                merged |= vals[k::unit] << np.uint64(k * b.width_bits)
            unit_data[spec.name] = merged
        legacy = pack_arrays(pb.layout, unit_data)
        assert np.array_equal(pb.buffer, legacy)

    def test_wide_unit_bundle_packs_and_unpacks(self):
        """>64-bit scheduling units (m=4096) pack now — was plan-only."""
        from repro.core.packing import BundleTensor, pack_bundle

        rng = np.random.default_rng(1)
        bundle = [BundleTensor("w", 4, 5000, 1),
                  BundleTensor("s", 16, 400, 1)]
        data = {b.name: rng.integers(0, 1 << b.width_bits, b.n_elems,
                                     dtype=np.uint64) for b in bundle}
        pb = pack_bundle(bundle, m=4096, data=data, cache=None)
        assert any(a.width > 64 for a in pb.problem.arrays)
        assert pb.buffer is not None
        back = pb.unpack()
        for b in bundle:
            np.testing.assert_array_equal(back[b.name][:b.n_elems],
                                          data[b.name])
            assert (back[b.name][b.n_elems:] == 0).all()


class TestValidation:
    def test_pack_rejects_bad_inputs(self):
        lay = schedule(PAPER_EXAMPLE)
        codes = random_codes(PAPER_EXAMPLE)
        with pytest.raises(KeyError):
            pack_compiled(lay, {k: v for k, v in codes.items() if k != "A"})
        bad = dict(codes)
        bad["A"] = bad["A"][:-1]
        with pytest.raises(ValueError, match="expected"):
            pack_compiled(lay, bad)
        bad = dict(codes)
        bad["A"] = bad["A"] | np.uint64(1 << 10)     # overflows 2 bits
        with pytest.raises(ValueError, match="overflow"):
            pack_compiled(lay, bad)

    def test_bad_elem_widths_rejected(self):
        lay = schedule(PAPER_EXAMPLE)
        with pytest.raises(ValueError, match="does not divide"):
            lower_exec(lay, elem_widths=(2, 3, 4, 5, 4))
        with pytest.raises(ValueError, match="entries"):
            lower_exec(lay, elem_widths=(2, 3))

    def test_unpack_rejects_bad_buffer_shape(self):
        lay = schedule(PAPER_EXAMPLE)
        with pytest.raises(ValueError, match="buffer shape"):
            unpack_compiled(lay, np.zeros((3, 1), dtype=np.uint8))
