"""Direct unit tests for the shared core helpers (repro.core.util).

``round_up`` and ``pad_bundle_elements`` used to live as private copies
in exec_plan.py / packing.py / kernels; they are now one shared util —
these tests pin the exact semantics every consumer relies on.
"""
import numpy as np
import pytest

from repro.core.iris import schedule
from repro.core.exec_plan import lower_exec
from repro.core.packing import BundleTensor, bundle_problem
from repro.core.util import pad_bundle_elements, round_up


class TestRoundUp:
    @pytest.mark.parametrize("x,to,want", [
        (0, 8, 0), (1, 8, 8), (8, 8, 8), (9, 8, 16),
        (1, 1, 1), (7, 1, 7),
        (127, 128, 128), (128, 128, 128), (129, 128, 256),
        (5, 3, 6), (6, 3, 6),
    ])
    def test_values(self, x, to, want):
        assert round_up(x, to) == want

    def test_result_is_multiple_and_minimal(self):
        for x in range(0, 70):
            for to in (1, 2, 3, 5, 8, 64):
                r = round_up(x, to)
                assert r % to == 0 and r >= x and r - x < to

    @pytest.mark.parametrize("to", [0, -1, -8])
    def test_nonpositive_to_raises(self, to):
        with pytest.raises(ValueError, match="positive"):
            round_up(4, to)

    def test_shared_by_all_consumers(self):
        """exec_plan and the kernels must use the one shared helper."""
        import repro.core.exec_plan as ep
        import repro.kernels.layout_decode as ld
        import repro.kernels.stream_matmul as sm

        assert ep._round_up is round_up
        assert ld._round_up is round_up
        assert sm._round_up is round_up


class TestPadBundleElements:
    def _setup(self, n_elems=100, width=5):
        bundle = [BundleTensor("w", width, n_elems, 1),
                  BundleTensor("w_scales", 16, n_elems // 4, 1)]
        prob = bundle_problem(bundle, m=256)
        lay = schedule(prob)
        prog = lower_exec(lay, elem_widths=(width, 16))
        return bundle, prob, lay, prog

    def test_pads_to_piece_capacity(self):
        bundle, prob, _lay, prog = self._setup()
        data = {"w": np.arange(100, dtype=np.uint64) % 31,
                "w_scales": np.arange(25, dtype=np.uint64)}
        padded = pad_bundle_elements(prob, prog, data)
        for i, a in enumerate(prob.arrays):
            assert padded[a.name].shape[0] == prog.piece_depths[i]
            n = data[a.name].shape[0]
            np.testing.assert_array_equal(padded[a.name][:n], data[a.name])
            assert not padded[a.name][n:].any()   # zero padding

    def test_exact_fit_unchanged(self):
        bundle, prob, _lay, prog = self._setup()
        data = {"w": np.arange(prog.piece_depths[0], dtype=np.uint64) % 31,
                "w_scales": np.zeros(prog.piece_depths[1], dtype=np.uint64)}
        padded = pad_bundle_elements(prob, prog, data)
        np.testing.assert_array_equal(padded["w"], data["w"])
        assert padded["w"].shape[0] == prog.piece_depths[0]

    def test_overfull_raises(self):
        bundle, prob, _lay, prog = self._setup()
        data = {"w": np.zeros(prog.piece_depths[0] + 1, dtype=np.uint64),
                "w_scales": np.zeros(prog.piece_depths[1], dtype=np.uint64)}
        with pytest.raises(ValueError):
            pad_bundle_elements(prob, prog, data)

    def test_packing_reexport_stays(self):
        """repro.core.packing keeps the compat re-export."""
        from repro.core.packing import pad_bundle_elements as via_packing

        assert via_packing is pad_bundle_elements
