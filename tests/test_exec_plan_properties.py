"""Property tests: compiled pack/unpack and the fused decode kernel are
bit-identical to the per-slot legacy paths on randomized problems
(§4-style, non-power-of-two, lane-capped, multi-interval).

Skipped gracefully where hypothesis is not installed (the deterministic
equivalence suite in test_exec_plan.py always runs).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from conftest import problems
from repro.core.baselines import homogeneous_layout
from repro.core.codegen import pack_arrays, random_codes, unpack_arrays
from repro.core.exec_plan import pack_compiled, unpack_compiled
from repro.core.iris import schedule


@given(problems(), st.sampled_from(["iris", "homogeneous"]), st.integers(0, 9))
@settings(max_examples=60, deadline=None)
def test_pack_unpack_bit_identical(problem, strategy, seed):
    lay = schedule(problem) if strategy == "iris" \
        else homogeneous_layout(problem)
    lay.validate()
    codes = random_codes(problem, seed=seed)
    legacy = pack_arrays(lay, codes)
    compiled = pack_compiled(lay, codes)
    assert np.array_equal(legacy, compiled)
    got = unpack_compiled(lay, compiled)
    ref = unpack_arrays(lay, legacy)
    for name, want in codes.items():
        assert np.array_equal(got[name], want)
        assert np.array_equal(ref[name], want)


@given(problems(), st.integers(0, 9))
@settings(max_examples=15, deadline=None)
def test_fused_decode_matches_per_slot(problem, seed):
    from repro.kernels.ops import decode_layout

    lay = schedule(problem)
    codes = random_codes(problem, seed=seed)
    buf = pack_compiled(lay, codes)
    fused = decode_layout(lay, buf, interpret=True, fused=True)
    legacy = decode_layout(lay, buf, interpret=True, fused=False)
    for name, want in codes.items():
        assert np.array_equal(
            np.asarray(fused[name]).astype(np.uint64), want)
        assert np.array_equal(
            np.asarray(legacy[name]).astype(np.uint64), want)
