"""Regenerate tests/golden/exec_plan_small.json.

Run from the repo root after an *intentional* scheduler/lowering change:

    PYTHONPATH=src python tests/golden/regen_exec_plan.py

Commit the resulting JSON diff together with the change that caused it
(test_exec_plan_golden.py enforces this).
"""
import json
import pathlib
import sys

TESTS_DIR = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(TESTS_DIR))

from conftest import GOLDEN_PROBLEM, serialize_exec_program  # noqa: E402


def main() -> None:
    from repro.core.exec_plan import lower_exec
    from repro.core.iris import schedule

    prog = lower_exec(schedule(GOLDEN_PROBLEM))
    out = TESTS_DIR / "golden" / "exec_plan_small.json"
    out.write_text(json.dumps(serialize_exec_program(prog),
                              indent=1, sort_keys=True) + "\n")
    print(f"wrote {out} ({out.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
