"""repro.analysis: the static layout verifier and its mutation harness.

Three claims are tested:

* **Soundness is falsifiable** — for every registered corruption class
  (overlapping pieces, coverage gaps, OOB words, wrong shifts, kernel
  table skew, truncated streams, manifest skew, bit flips) the analyzer
  reports an error finding with the documented rule id.
* **No false positives** — every registered strategy x the shared
  problem suite verifies clean (the same combination the CI
  analysis-gate enforces).
* **The wiring holds** — ``Plan.verify()``, ``PackedTree.verify()``,
  ``restore_packed`` and the ``python -m repro.analysis`` CLI all route
  through the analyzer and surface structured reports.
"""
import json

import numpy as np
import pytest

from conftest import GATE_PROBLEMS
from repro import api
from repro.analysis import (
    AnalysisError,
    Finding,
    Report,
    Severity,
    stream_sha256,
    verify_layout,
    verify_manifest,
    verify_program,
)
from repro.analysis.mutations import (
    CHECKPOINT_MUTATIONS,
    PROGRAM_MUTATIONS,
    corrupt_checkpoint,
    corrupt_program,
)
from repro.core.exec_plan import lower_exec
from repro.core.iris import LayoutCache

STRATEGIES = api.strategies()

#: non-power-of-two, all-kernel-width problem the program mutations use
MUT_PROBLEM = GATE_PROBLEMS[1]


# ----------------------------------------------------------------------
# findings model
# ----------------------------------------------------------------------
class TestFindingsModel:
    def test_severity_ordering_and_str(self):
        assert Severity.INFO < Severity.WARNING < Severity.ERROR
        assert str(Severity.ERROR) == "error"

    def test_report_json_and_render(self):
        r = Report(subject="t")
        r.findings.append(Finding("p/x", Severity.ERROR, "boom",
                                  array="a", locus="piece 3",
                                  fixit_hint="re-lower"))
        r.findings.append(Finding("p/y", Severity.INFO, "fyi"))
        d = r.to_json_dict()
        assert not d["ok"] and d["n_errors"] == 1
        assert d["findings"][0]["severity"] == "error"
        assert json.loads(r.to_json()) == d          # serializable
        txt = r.render()
        assert "p/x" in txt and "piece 3" in txt and "re-lower" in txt
        # min_severity filters info out
        assert "p/y" not in r.render(Severity.WARNING)

    def test_raise_if_errors(self):
        clean = Report()
        assert clean.raise_if_errors() is clean      # chainable
        bad = Report()
        bad.findings.append(Finding("p/x", Severity.ERROR, "boom"))
        with pytest.raises(AnalysisError) as ei:
            bad.raise_if_errors()
        assert ei.value.report is bad
        assert "p/x" in str(ei.value)

    def test_unknown_pass_rejected(self):
        from repro.analysis.passes import AnalysisContext, run_passes

        with pytest.raises(KeyError, match="registered"):
            run_passes(AnalysisContext(), ["no-such-pass"])


# ----------------------------------------------------------------------
# the clean gate: every strategy x the shared suite has zero errors
# ----------------------------------------------------------------------
class TestCleanGate:
    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize(
        "prob", GATE_PROBLEMS,
        ids=[f"m{p.m}-" + "".join(a.name[0] for a in p.arrays)
             for p in GATE_PROBLEMS])
    def test_zero_error_findings(self, strategy, prob):
        lay = api.plan(prob, strategy, cache=None).layout
        report = verify_layout(lay, subject=strategy)
        assert report.ok, report.render()

    def test_plan_verify_chainable_and_raising(self):
        p = api.plan(MUT_PROBLEM, cache=None)
        report = p.verify()                          # no error -> returns
        assert report.ok and "interval" in report.passes
        assert "program" in report.passes

    def test_wide_arrays_report_host_fallback_warning(self):
        # GATE_PROBLEMS[2] has 33/64-bit arrays -> host path findings
        lay = api.plan(GATE_PROBLEMS[2], cache=None).layout
        report = verify_layout(lay)
        assert report.ok
        rules = {f.rule_id for f in report.warnings}
        assert "extraction/host-fallback" in rules

    def test_bandwidth_metric_reported(self):
        lay = api.plan(MUT_PROBLEM, cache=None).layout
        report = verify_layout(lay)
        eff = [f for f in report if f.rule_id == "bandwidth/efficiency"]
        assert len(eff) == 1 and "B_eff" in eff[0].message


# ----------------------------------------------------------------------
# mutation harness: corrupted tables must be caught
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def lowered():
    lay = api.plan(MUT_PROBLEM, cache=None).layout
    return lay, lower_exec(lay)


class TestProgramMutations:
    @pytest.mark.parametrize("kind", sorted(PROGRAM_MUTATIONS))
    def test_corruption_detected(self, kind, lowered):
        lay, prog = lowered
        mut = corrupt_program(prog, kind)
        report = verify_program(mut, layout=lay)
        assert not report.ok, f"{kind} went undetected"
        got = {f.rule_id for f in report.errors}
        want = set(PROGRAM_MUTATIONS[kind])
        assert got & want, f"{kind}: expected one of {want}, got {got}"

    def test_mutation_does_not_touch_original(self, lowered):
        lay, prog = lowered
        for kind in PROGRAM_MUTATIONS:
            corrupt_program(prog, kind)
        assert verify_program(prog, layout=lay).ok

    def test_unknown_kind_rejected(self, lowered):
        _lay, prog = lowered
        with pytest.raises(KeyError):
            corrupt_program(prog, "no-such-mutation")


# ----------------------------------------------------------------------
# checkpoint-grade verification (manifest + streams + digest)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def packed_tree():
    import jax

    from repro.configs import get_config
    from repro.models.model import Model
    from repro.quant import QuantSpec

    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=128, head_dim=32)
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    return api.pack_tree(cfg, params, QuantSpec(bits=4, group_size=32),
                         cache=LayoutCache())


class TestManifestMutations:
    @pytest.mark.parametrize("kind", sorted(CHECKPOINT_MUTATIONS))
    def test_corruption_detected(self, kind, packed_tree):
        from repro.tree import LayoutManifest

        pt = packed_tree
        streams = np.asarray(pt.streams)
        digest = stream_sha256(streams)
        d, s, g = corrupt_checkpoint(
            pt.manifest.to_json_dict(), streams, digest, kind)
        report = verify_manifest(LayoutManifest.from_json_dict(d),
                                 streams=s, stream_digest=g)
        assert not report.ok, f"{kind} went undetected"
        got = {f.rule_id for f in report.errors}
        want = set(CHECKPOINT_MUTATIONS[kind])
        assert got & want, f"{kind}: expected one of {want}, got {got}"

    def test_clean_tree_verifies(self, packed_tree):
        report = packed_tree.verify()                # raises on errors
        assert report.ok
        assert {"interval", "program", "kernel", "stream", "extraction",
                "manifest", "bandwidth"} <= set(report.passes)

    def test_verify_manifest_without_streams(self, packed_tree):
        assert verify_manifest(packed_tree.manifest).ok


class TestRestorePackedCorruption:
    """save_packed -> tamper the bytes on disk -> restore_packed must
    raise the analyzer's structured error, naming the violated rule."""

    def _save(self, tmp_path, pt):
        from repro.checkpoint.checkpoint import CheckpointManager

        mgr = CheckpointManager(tmp_path, keep_n=2)
        path = mgr.save_packed(0, pt)
        d = json.loads((tmp_path / "step_00000000" /
                        "manifest.json").read_text())
        streams_leaf = d["paths"].index("streams")
        return mgr, tmp_path / "step_00000000", \
            f"arr_{streams_leaf:05d}.npy", d

    def _expect_rejection(self, mgr, rule):
        with pytest.raises(AnalysisError) as ei:
            mgr.restore_packed(cache=LayoutCache())
        assert rule in ei.value.report.rule_ids(), \
            ei.value.report.render()

    def test_clean_roundtrip_verifies_and_restores(self, tmp_path,
                                                   packed_tree):
        mgr, _d, _f, _m = self._save(tmp_path, packed_tree)
        assert mgr.verify_packed().ok
        pt2, _extra = mgr.restore_packed(cache=LayoutCache())
        assert np.array_equal(np.asarray(packed_tree.streams),
                              np.asarray(pt2.streams))

    def test_truncated_stream_bytes_rejected(self, tmp_path, packed_tree):
        mgr, d, stream_file, _m = self._save(tmp_path, packed_tree)
        arr = np.load(d / stream_file)
        np.save(d / stream_file, arr[:, :, :-4])
        self._expect_rejection(mgr, "manifest/stream-shape")

    def test_bit_flipped_stream_rejected(self, tmp_path, packed_tree):
        mgr, d, stream_file, _m = self._save(tmp_path, packed_tree)
        arr = np.load(d / stream_file).copy()
        arr.flat[7] ^= np.uint8(0x10)
        np.save(d / stream_file, arr)
        self._expect_rejection(mgr, "manifest/stream-digest")

    def test_tampered_manifest_signature_rejected(self, tmp_path,
                                                  packed_tree):
        mgr, d, _f, meta = self._save(tmp_path, packed_tree)
        sig = meta["extra"]["packed_tree_manifest"]["signature"]
        sig[0] += 8
        (d / "manifest.json").write_text(json.dumps(meta))
        self._expect_rejection(mgr, "manifest/signature")

    def test_verify_false_skips_the_gate(self, tmp_path, packed_tree):
        """Forensics escape hatch: verify=False restores the bytes the
        analyzer would reject (digest mismatch does not break unpack)."""
        mgr, d, stream_file, _m = self._save(tmp_path, packed_tree)
        arr = np.load(d / stream_file).copy()
        arr.flat[7] ^= np.uint8(0x10)
        np.save(d / stream_file, arr)
        pt2, _extra = mgr.restore_packed(cache=LayoutCache(),
                                         verify=False)
        assert not np.array_equal(np.asarray(packed_tree.streams),
                                  np.asarray(pt2.streams))


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------
class TestCli:
    def test_gate_writes_artifact_and_exits_zero(self, tmp_path):
        from repro.analysis.__main__ import main

        out = tmp_path / "gate.json"
        rc = main(["--json", str(out), "gate", "--strategies",
                   "homogeneous", "hls_padded"])
        assert rc == 0
        payload = json.loads(out.read_text())
        assert payload["ok"] and payload["n_errors"] == 0
        assert payload["n_reports"] == 2 * len(GATE_PROBLEMS)
        subjects = [r["subject"] for r in payload["reports"]]
        assert any(s.startswith("homogeneous:") for s in subjects)

    def test_config_subcommand(self, capsys):
        from repro.analysis.__main__ import main

        rc = main(["config", "smollm-135m", "--bits", "4",
                   "--layers", "1"])
        assert rc == 0
        assert "OK" in capsys.readouterr().out
