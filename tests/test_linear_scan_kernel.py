"""Pallas SSD scan kernel vs the pure-JAX recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.linear_scan import ssd_scan
from repro.models.linear_attention import recurrent_scan


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32) * 0.5


@pytest.mark.parametrize("shape", [
    # (B, T, H, dk, dv, chunk)
    (1, 64, 2, 16, 16, 16),
    (2, 128, 3, 32, 32, 32),
    (2, 256, 2, 64, 64, 128),
])
def test_matches_recurrence(shape):
    b, t, h, dk, dv, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q, k = _rand(ks[0], (b, t, h, dk)), _rand(ks[1], (b, t, h, dk))
    v = _rand(ks[2], (b, t, h, dv))
    logw = -jax.nn.softplus(_rand(ks[3], (b, t, h)))      # <= 0
    got = ssd_scan(q, k, v, logw, chunk=chunk, interpret=True)
    want, _ = recurrent_scan(q, k, v, logw[..., None], rwkv_mode=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_state_carries_across_chunks():
    """A distant token must influence outputs many chunks later."""
    b, t, h, d = 1, 128, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (_rand(ks[i], (b, t, h, d)) for i in range(3))
    logw = jnp.full((b, t, h), -0.01)            # slow decay
    base = ssd_scan(q, k, v, logw, chunk=16, interpret=True)
    v2 = v.at[0, 3].add(10.0)                    # perturb token 3
    pert = ssd_scan(q, k, v2, logw, chunk=16, interpret=True)
    # tokens in later chunks see the perturbation through the carry
    assert float(jnp.abs(pert[0, 100] - base[0, 100]).max()) > 1e-3


def test_strong_decay_forgets():
    b, t, h, d = 1, 64, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (_rand(ks[i], (b, t, h, d)) for i in range(3))
    logw = jnp.full((b, t, h), -20.0)            # ~instant forgetting
    out = ssd_scan(q, k, v, logw, chunk=16, interpret=True)
    # each token only sees itself: o_t ~ (q_t . k_t) v_t
    expect = jnp.einsum("bthd,bthd->bth", q, k)[..., None] * v
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-3, atol=1e-3)


def test_bad_chunk_rejected():
    z = jnp.zeros((1, 100, 1, 8))
    with pytest.raises(ValueError):
        ssd_scan(z, z, z, jnp.zeros((1, 100, 1)), chunk=64, interpret=True)
