"""Planner scale-out: warm-start re-planning, pool fan-out, persistent
cache tier, and the LayoutCache internals ISSUE-9 calls out as untested.

Everything here must hold on a 1-core container: the pool path is
exercised by monkeypatching ``os.cpu_count`` (fork start method works
with 1 core; the processes just time-share), and every speed claim is
checked as *bit-equivalence*, never wall-clock.
"""
import json
import warnings

import numpy as np
import pytest

import repro.core.iris as iris_mod
from repro.core.iris import LayoutCache, schedule, schedule_many
from repro.core.task import ArraySpec, LayoutProblem, make_problem


def _dense_problem(m=64, n=5, seed=0):
    """A gap-free scheduling instance (due dates tight enough that the
    trace has no idle cycles), so warm starts are applicable."""
    rng = np.random.default_rng(seed)
    arrays = tuple(
        ArraySpec(f"a{i}", width=int(rng.integers(2, 9)),
                  depth=int(rng.integers(50, 400)),
                  due=int(rng.integers(1, 40)), max_lanes=None)
        for i in range(n))
    return LayoutProblem(m=m, arrays=arrays)


def _with_depth(prob, idx, delta):
    arrays = list(prob.arrays)
    a = arrays[idx]
    arrays[idx] = ArraySpec(a.name, a.width, a.depth + delta, a.due,
                            a.max_lanes)
    return LayoutProblem(m=prob.m, arrays=tuple(arrays))


# ----------------------------------------------------------------------
# incremental warm-start re-planning
# ----------------------------------------------------------------------
def test_warm_start_sub_bit_identical():
    base = _dense_problem(seed=1)
    cache = LayoutCache()
    schedule(base, cache=cache)
    for delta in (1, 7, -3):
        nxt = _with_depth(base, 2, delta)
        warm = schedule(nxt, cache=cache)
        cold = schedule(nxt, cache=None, warm_start=False)
        assert warm.count_intervals == cold.count_intervals, delta


def test_warm_start_ins_del_bit_identical():
    base = _dense_problem(seed=2)
    cold_base = schedule(base, cache=None)

    # insert an array
    cache = LayoutCache()
    cache.insert(base, False, cold_base)
    arrays = list(base.arrays)
    arrays.insert(2, ArraySpec("new", 4, 120, 10, None))
    p_ins = LayoutProblem(m=base.m, arrays=tuple(arrays))
    assert schedule(p_ins, cache=cache).count_intervals == \
        schedule(p_ins, cache=None, warm_start=False).count_intervals

    # delete an array
    cache = LayoutCache()
    cache.insert(base, False, cold_base)
    arrays = list(base.arrays)
    del arrays[3]
    p_del = LayoutProblem(m=base.m, arrays=tuple(arrays))
    assert schedule(p_del, cache=cache).count_intervals == \
        schedule(p_del, cache=None, warm_start=False).count_intervals


def test_warm_start_counter_and_chaining():
    """Consecutive one-delta neighbors warm off each other (MRU chain).

    Constructed so the warm window is provably gap-free: only ``a0``
    (release 0) is ready before the other arrays release at
    ``R = d_max - due = 9``, and its depth alone covers those cycles, so
    the prefix reuse is always applicable (the idle-gap safety check
    cannot bail).
    """
    base = make_problem(64, [("a0", 4, 200, 10), ("a1", 8, 60, 1),
                             ("a2", 2, 150, 1), ("a3", 6, 80, 1)])
    cache = LayoutCache()
    schedule(base, cache=cache)
    for i in range(1, 4):
        p = _with_depth(base, 1, i)
        warm = schedule(p, cache=cache)
        assert warm.count_intervals == \
            schedule(p, cache=None, warm_start=False).count_intervals
    assert cache.warm_starts == 3
    assert cache.stats["warm_starts"] == 3


def test_warm_start_requires_same_bus_width():
    base = _dense_problem(seed=4)
    cache = LayoutCache()
    schedule(base, cache=cache)
    wider = LayoutProblem(m=base.m * 2, arrays=base.arrays)
    lay = schedule(wider, cache=cache)       # cold: no usable neighbor
    assert cache.warm_starts == 0
    assert lay.count_intervals == schedule(wider, cache=None).count_intervals


def test_warm_start_disabled_flag():
    base = _dense_problem(seed=5)
    cache = LayoutCache()
    schedule(base, cache=cache)
    nxt = _with_depth(base, 1, 2)
    schedule(nxt, cache=cache, warm_start=False)
    assert cache.warm_starts == 0


# ----------------------------------------------------------------------
# LayoutCache internals: LRU order, stats counters
# ----------------------------------------------------------------------
def test_lru_eviction_respects_lookup_promotion():
    cache = LayoutCache(maxsize=3)
    probs = [make_problem(8, [("a", 2, d, 0)]) for d in (3, 4, 5, 6, 7)]
    for p in probs[:3]:
        schedule(p, cache=cache)
    cache.lookup(probs[0])                   # promote p0 over p1, p2
    schedule(probs[3], cache=cache)          # evicts p1 (now LRU)
    schedule(probs[4], cache=cache)          # evicts p2
    assert cache.lookup(probs[0]) is not None
    assert cache.lookup(probs[3]) is not None
    assert cache.lookup(probs[4]) is not None
    assert cache.lookup(probs[1]) is None and cache.lookup(probs[2]) is None
    assert len(cache) == 3


def test_stats_counters_across_schedule_many():
    layers = [make_problem(32, [("w", 4, 60, 5)]) for _ in range(4)]
    distinct = make_problem(32, [("w", 4, 61, 5)])
    cache = LayoutCache()
    schedule_many(layers + [distinct], cache=cache, workers=1)
    s = cache.stats
    assert s["misses"] == 2 and s["hits"] == 3 and s["size"] == 2
    # a second pass is all hits
    schedule_many(layers, cache=cache, workers=1)
    assert cache.stats["hits"] == 7 and cache.stats["misses"] == 2


def test_stats_parity_serial_vs_pool(monkeypatch):
    probs = [_dense_problem(seed=s) for s in range(5)] * 2
    serial = LayoutCache()
    outs_s = schedule_many(probs, cache=serial, workers=1)
    monkeypatch.setattr(iris_mod.os, "cpu_count", lambda: 4)
    pooled = LayoutCache()
    outs_p = schedule_many(probs, cache=pooled, workers=2)
    assert all(a.count_intervals == b.count_intervals
               for a, b in zip(outs_s, outs_p))
    assert (serial.stats["hits"], serial.stats["misses"]) == \
        (pooled.stats["hits"], pooled.stats["misses"])


def test_pool_failure_falls_back_to_serial(monkeypatch):
    probs = [_dense_problem(seed=s) for s in range(3)]
    expect = [schedule(p, cache=None).count_intervals for p in probs]
    monkeypatch.setattr(iris_mod.os, "cpu_count", lambda: 4)
    monkeypatch.setattr(iris_mod, "_pool_schedule",
                        lambda *a, **k: None)   # pool unavailable
    outs = schedule_many(probs, cache=LayoutCache(), workers=2)
    assert [o.count_intervals for o in outs] == expect


def test_effective_workers_clamps():
    real = iris_mod.os.cpu_count() or 1
    assert iris_mod._effective_workers(8, 2) <= 2
    assert iris_mod._effective_workers(8, 100) <= real
    assert iris_mod._effective_workers(None, 1) == 1
    assert iris_mod._effective_workers(0, 5) == 1


# ----------------------------------------------------------------------
# persistent tier
# ----------------------------------------------------------------------
def test_persistent_roundtrip_fresh_cache(tmp_path):
    prob = _dense_problem(seed=7)
    writer = LayoutCache(cache_dir=tmp_path)
    lay = schedule(prob, cache=writer)
    reader = LayoutCache(cache_dir=tmp_path)
    hit = reader.lookup(prob)
    assert hit is not None
    assert hit.count_intervals == lay.count_intervals
    assert reader.disk_hits == 1 and reader.hits == 1 and reader.misses == 0
    # promoted to memory: second lookup does not touch disk again
    reader.lookup(prob)
    assert reader.disk_hits == 1 and reader.hits == 2


def test_persistent_keys_on_fill_residual(tmp_path):
    prob = _dense_problem(seed=8)
    writer = LayoutCache(cache_dir=tmp_path)
    schedule(prob, cache=writer, fill_residual=True)
    reader = LayoutCache(cache_dir=tmp_path)
    assert reader.lookup(prob, fill_residual=False) is None
    assert reader.lookup(prob, fill_residual=True) is not None


def _entry_path(tmp_path):
    paths = list(tmp_path.glob("*.json"))
    assert len(paths) == 1
    return paths[0]


def _reject(tmp_path, prob):
    cache = LayoutCache(cache_dir=tmp_path)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        out = cache.lookup(prob)
    assert out is None
    assert cache.disk_rejects == 1 and cache.misses == 1
    return cache


def test_disk_rejects_digest_mismatch(tmp_path):
    prob = _dense_problem(seed=9)
    schedule(prob, cache=LayoutCache(cache_dir=tmp_path))
    path = _entry_path(tmp_path)
    obj = json.loads(path.read_text())
    obj["payload"]["intervals"][0][0] += 1     # digest now stale
    path.write_text(json.dumps(obj))
    _reject(tmp_path, prob)
    assert not path.exists(), "corrupt entry must be unlinked"


def test_disk_rejects_coverage_gap_via_analysis_gate(tmp_path):
    """A consistent-digest entry with the mutation harness's
    ``coverage-gap`` defect must die at the verification gate, not at the
    digest check — the same fault class ``corrupt_checkpoint`` plants."""
    from repro.analysis.mutations import corrupt_checkpoint

    prob = _dense_problem(seed=10)
    schedule(prob, cache=LayoutCache(cache_dir=tmp_path))
    path = _entry_path(tmp_path)
    obj = json.loads(path.read_text())
    mutated, _s, _d = corrupt_checkpoint(
        {"intervals": obj["payload"]["intervals"]},
        np.zeros((1, 1, 8), dtype=np.uint8), "", "coverage-gap")
    obj["payload"]["intervals"] = mutated["intervals"]
    obj["sha256"] = LayoutCache._payload_digest(obj["payload"])
    path.write_text(json.dumps(obj))
    _reject(tmp_path, prob)


def test_disk_rejects_non_canonical_run(tmp_path):
    prob = _dense_problem(seed=11)
    schedule(prob, cache=LayoutCache(cache_dir=tmp_path))
    path = _entry_path(tmp_path)
    obj = json.loads(path.read_text())
    obj["payload"]["intervals"][0][1].append([0, 0])   # zero-count slot
    obj["sha256"] = LayoutCache._payload_digest(obj["payload"])
    path.write_text(json.dumps(obj))
    _reject(tmp_path, prob)


def test_disk_rejects_truncated_json(tmp_path):
    prob = _dense_problem(seed=12)
    schedule(prob, cache=LayoutCache(cache_dir=tmp_path))
    path = _entry_path(tmp_path)
    path.write_text(path.read_text()[:80])
    _reject(tmp_path, prob)
    assert not path.exists()


def test_disk_rejects_signature_mismatch(tmp_path):
    """An entry filed under one key whose payload describes a different
    problem (e.g. a collision or a copied file) is rejected."""
    p1 = _dense_problem(seed=13)
    p2 = _with_depth(p1, 0, 5)
    schedule(p1, cache=LayoutCache(cache_dir=tmp_path))
    schedule(p2, cache=LayoutCache(cache_dir=tmp_path))
    a, b = sorted(tmp_path.glob("*.json"))
    b_text = b.read_text()
    a.write_text(b_text)                       # a's key, b's payload
    cache = LayoutCache(cache_dir=tmp_path)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        one = cache.lookup(p1)
        two = cache.lookup(p2)
    # exactly one of the two keys had the wrong payload under it
    assert cache.disk_rejects == 1
    assert (one is None) != (two is None)


def test_evicted_entry_survives_on_disk(tmp_path):
    """Memory-tier eviction must not forget what the disk knows."""
    cache = LayoutCache(maxsize=1, cache_dir=tmp_path)
    p1 = _dense_problem(seed=14)
    p2 = _with_depth(p1, 1, 3)
    lay1 = schedule(p1, cache=cache)
    schedule(p2, cache=cache)                  # evicts p1 from memory
    assert len(cache) == 1
    hit = cache.lookup(p1)                     # re-promoted from disk
    assert hit is not None
    assert hit.count_intervals == lay1.count_intervals
    assert cache.disk_hits == 1


def test_clear_resets_all_counters(tmp_path):
    cache = LayoutCache(cache_dir=tmp_path)
    prob = _dense_problem(seed=15)
    schedule(prob, cache=cache)
    schedule(prob, cache=cache)
    cache.clear()
    assert cache.stats == {"hits": 0, "misses": 0, "size": 0,
                           "maxsize": 256, "warm_starts": 0,
                           "disk_hits": 0, "disk_rejects": 0}


# ----------------------------------------------------------------------
# DEFAULT_CACHE env configuration
# ----------------------------------------------------------------------
def test_env_default_cache_size(monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_SIZE", "17")
    monkeypatch.delenv("REPRO_CACHE_DIR", raising=False)
    c = iris_mod._env_default_cache()
    assert c.maxsize == 17 and c.cache_dir is None


def test_env_default_cache_dir(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "layouts"))
    monkeypatch.delenv("REPRO_CACHE_SIZE", raising=False)
    c = iris_mod._env_default_cache()
    assert c.maxsize == 512
    assert c.cache_dir is not None
    prob = _dense_problem(seed=16)
    schedule(prob, cache=c)
    assert list(c.cache_dir.glob("*.json")), "persistent tier not active"


def test_env_default_cache_malformed_size(monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_SIZE", "not-a-number")
    monkeypatch.delenv("REPRO_CACHE_DIR", raising=False)
    assert iris_mod._env_default_cache().maxsize == 512


# ----------------------------------------------------------------------
# DSE sweep through the batch scheduler
# ----------------------------------------------------------------------
def test_sweep_strategies_matches_per_problem_compare():
    from repro import api
    from repro.core.dse import sweep_strategies

    probs = [_dense_problem(seed=s) for s in range(3)]
    swept = sweep_strategies(probs, ("iris",), cache=LayoutCache())
    for p, row in zip(probs, swept):
        ref = api.compare(p, strategies=("iris",), cache=None)
        assert row["iris"].c_max == ref["iris"].c_max
        assert row["iris"].efficiency == ref["iris"].efficiency


def test_sweep_strategies_presolves_into_cache():
    from repro.core.dse import sweep_strategies

    probs = [_dense_problem(seed=s) for s in (20, 21)]
    cache = LayoutCache()
    sweep_strategies(probs, ("iris",), cache=cache)
    # the compare loop ran on cache hits: one miss per unique signature
    assert cache.misses == len(probs)
    assert cache.hits >= len(probs)
