"""Golden-file regression test for the ExecProgram lowering.

Pins the complete lowered artifact — destination word/shift tables,
piece bookkeeping, the fused-decode kernel slot table, gathers and the
stream-direct global bit offsets — for one small canonical mixed-width
problem, so *any* change to the scheduler or the lowering that moves
even a single element shows up as a reviewable JSON diff instead of a
silent layout change.

Regenerate (after an intentional lowering change) with:

    PYTHONPATH=src python tests/golden/regen_exec_plan.py

and commit the diff alongside the change that caused it.
"""
import json
import pathlib

from conftest import GOLDEN_PROBLEM, serialize_exec_program
from repro.core.exec_plan import lower_exec
from repro.core.iris import schedule

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden" / "exec_plan_small.json"


def test_lowering_matches_golden_file():
    prog = lower_exec(schedule(GOLDEN_PROBLEM))
    got = serialize_exec_program(prog)
    want = json.loads(GOLDEN_PATH.read_text())
    assert got == want, (
        "ExecProgram lowering drifted from tests/golden/"
        "exec_plan_small.json — if the layout change is intentional, "
        "regenerate with `PYTHONPATH=src python "
        "tests/golden/regen_exec_plan.py` and commit the diff"
    )


def test_serialization_is_lossless_for_stream_offsets():
    """The dumped stream offsets must round-trip to exactly what
    stream_matmul consumes (uint32, element order)."""
    import numpy as np

    prog = lower_exec(schedule(GOLDEN_PROBLEM))
    dumped = serialize_exec_program(prog)["stream_bit_offsets"]
    narrow = [i for i in range(len(prog.piece_depths))
              if prog.elem_widths[i] <= 32]
    assert len(dumped) == len(narrow)
    for js, i in zip(dumped, narrow):
        np.testing.assert_array_equal(
            np.asarray(js, dtype=np.uint32), prog.stream_bit_offsets(i))
