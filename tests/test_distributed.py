"""Distribution tests that need multiple (forced host) devices.

Each test runs in a subprocess with XLA_FLAGS set before jax import, so
the main pytest process keeps its single-device view.
"""
import subprocess
import sys
import textwrap



def run_sub(body: str, n_devices: int = 8, timeout: int = 560) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROCESS_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SUBPROCESS_OK" in r.stdout
    return r.stdout


class TestMesh:
    def test_production_meshes_construct(self):
        run_sub("""
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.shape == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert m2.shape == {"pod": 2, "data": 16, "model": 16}
        assert m2.devices.size == 512
        """, n_devices=512)


class TestShardedTrainStep:
    def test_train_step_runs_on_2x4_mesh(self):
        run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.sharding import (param_shardings,
            opt_state_shardings, batch_sharding)
        from repro.launch.steps import build_train_step, init_train_state
        cfg = get_config("smollm-135m").reduced(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, head_dim=16)
        mesh = make_debug_mesh((2, 4), ("data", "model"))
        with mesh:
            state = init_train_state(cfg, jax.random.PRNGKey(0))
            ps = param_shardings(state["params"], mesh, fsdp=True)
            os_ = opt_state_shardings(state["opt"], ps, mesh)
            state = jax.device_put(state, {"params": ps, "opt": os_})
            batch = {
                "tokens": jnp.zeros((4, 16), jnp.int32),
                "labels": jnp.zeros((4, 16), jnp.int32),
            }
            bs = batch_sharding(batch, mesh)
            batch = jax.device_put(batch, bs)
            step = jax.jit(build_train_step(cfg),
                           in_shardings=({"params": ps, "opt": os_}, bs),
                           donate_argnums=(0,))
            state2, metrics = step(state, batch)
            loss = float(metrics["loss"])
            assert np.isfinite(loss), loss
            state3, m2 = step(state2, batch)
            assert float(m2["loss"]) < loss + 1.0
        """)

    def test_serve_step_runs_on_2x4_mesh(self):
        run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.sharding import (param_shardings,
            decode_state_shardings, batch_sharding)
        from repro.launch.steps import build_serve_step
        from repro.models.model import Model
        cfg = get_config("jamba-1.5-large-398b").reduced()
        model = Model(cfg, remat="none")
        mesh = make_debug_mesh((2, 4), ("data", "model"))
        with mesh:
            params = model.init(jax.random.PRNGKey(0))
            ps = param_shardings(params, mesh, fsdp=False)
            params = jax.device_put(params, ps)
            state = model.init_decode_state(4, max_seq=32)
            ss = decode_state_shardings(state, mesh)
            state = jax.device_put(state, ss)
            toks = jnp.zeros((4,), jnp.int32)
            step = jax.jit(build_serve_step(cfg))
            logits, state = step(params, state, toks)
            assert logits.shape == (4, cfg.vocab_size)
            assert np.isfinite(np.asarray(logits, np.float32)).all()
        """)


class TestElastic:
    def test_reshard_preserves_values(self):
        run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.runtime.elastic import reshard_live, validate_resharding
        mesh8 = make_debug_mesh((2, 4), ("data", "model"))
        mesh4 = make_debug_mesh((1, 4), ("data", "model"))
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones((4,), jnp.bfloat16)}
        sh8 = {"w": NamedSharding(mesh8, P("data", "model")),
               "b": NamedSharding(mesh8, P())}
        placed = jax.device_put(tree, sh8)
        sh4 = {"w": NamedSharding(mesh4, P("data", "model")),
               "b": NamedSharding(mesh4, P())}
        moved = reshard_live(placed, sh4)
        validate_resharding(placed, moved)
        assert moved["w"].sharding.mesh.devices.size == 4
        """)

    def test_checkpoint_restore_onto_mesh(self, tmp_path):
        run_sub(f"""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.checkpoint import CheckpointManager
        from repro.launch.mesh import make_debug_mesh
        tree = {{"w": jnp.arange(32, dtype=jnp.bfloat16).reshape(8, 4)}}
        mgr = CheckpointManager(r"{tmp_path}", keep_n=2)
        mgr.save(1, tree)
        mesh = make_debug_mesh((2, 2), ("data", "model"))
        sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
        out, _ = mgr.restore(tree, shardings=sh)
        np.testing.assert_array_equal(
            np.asarray(out["w"], np.float32),
            np.asarray(tree["w"], np.float32))
        assert out["w"].sharding.mesh.devices.size == 4
        """)


class TestPipelineParallel:
    def test_schedule_table_bubbles(self):
        from repro.runtime.pipeline_par import PipelineConfig, schedule_table
        cfg = PipelineConfig(n_stages=4, n_microbatches=8)
        table = schedule_table(cfg)
        assert len(table) == 11
        bubbles = sum(row.count(None) for row in table)
        assert bubbles == (4 - 1) * 4     # (S-1) ramp-up + ramp-down slots
        assert abs(cfg.bubble_fraction - 3 / 11) < 1e-9

    def test_pipeline_matches_reference(self):
        run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.runtime.pipeline_par import (PipelineConfig,
                                                pipeline_forward)
        mesh = make_debug_mesh((4,), ("stage",))
        cfg = PipelineConfig(n_stages=4, n_microbatches=6)
        key = jax.random.PRNGKey(0)
        d = 16
        ws = jax.random.normal(key, (4, d, d)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (6, 2, d))
        out = pipeline_forward(stage_fn, mesh, cfg, ws, x)
        ref = x
        for s in range(4):
            ref = jnp.tanh(ref @ ws[s])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        """)


class TestMiniDryRun:
    def test_reduced_cell_on_small_production_style_mesh(self):
        """Full dry-run machinery on a (4, 4) mesh with a reduced config."""
        run_sub("""
        import jax
        import numpy as np
        from repro.configs import get_config, SHAPES
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.sharding import (param_shardings,
            opt_state_shardings, batch_sharding)
        from repro.launch.specs import abstract_train_state
        from repro.launch.steps import build_train_step
        from repro.launch import roofline as rl
        import dataclasses, jax.numpy as jnp
        cfg = get_config("moonshot-v1-16b-a3b").reduced()
        mesh = make_debug_mesh((4, 4), ("data", "model"))
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128,
                                    global_batch=8)
        with mesh:
            st = abstract_train_state(cfg)
            ps = param_shardings(st["params"], mesh, fsdp=True)
            os_ = opt_state_shardings(st["opt"], ps, mesh)
            batch = {"tokens": jax.ShapeDtypeStruct((8, 128), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((8, 128), jnp.int32)}
            bs = batch_sharding(batch, mesh)
            lowered = jax.jit(build_train_step(cfg),
                in_shardings=({"params": ps, "opt": os_}, bs),
                donate_argnums=(0,)).lower(st, batch)
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        assert cost.get("flops", 0) > 0
        coll = rl.collective_bytes(compiled.as_text())
        assert coll.total_bytes > 0      # sharded training must communicate
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        terms = rl.roofline_terms(cost, coll, 16, rl.model_flops(cfg, shape))
        assert terms.compute_s > 0 and terms.bottleneck in (
            "compute", "memory", "collective")
        """, n_devices=16)
