"""Property tests: stream-direct matmul over randomized bundles,
widths and layout strategies agrees with the float host reference, and
is bit-invariant to the layout strategy.

Skipped gracefully where hypothesis is not installed (the deterministic
equivalence suite in test_stream_matmul.py always runs).  Under
``HYPOTHESIS_PROFILE=ci`` (see conftest) the sweep is derandomized so
CI failures reproduce exactly.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings

from conftest import build_stream_case, stream_matmul_cases
from repro.core.baselines import homogeneous_layout
from repro.kernels.ref import stream_matmul_ref
from repro.kernels.stream_matmul import stream_matmul, stream_words


def _run_case(case, x):
    import jax.numpy as jnp

    _, _, _, prog, buf, tabs = case
    sw = stream_words(prog, buf)
    got = stream_matmul(jnp.asarray(x), sw, tabs.w_tab, tabs.s_tab,
                        bits=tabs.bits, group_size=tabs.group_size,
                        interpret=True)
    return np.asarray(got), np.asarray(sw), tabs


@given(stream_matmul_cases())
@settings(max_examples=10, deadline=None)
def test_matches_host_reference(case_params):
    """pack -> stream-direct matmul == float reference (any bits,
    ragged M/K/N, both bus widths, both strategies)."""
    bits, g, k, n, m, bus, strategy = case_params
    layout_fn = None if strategy == "iris" else homogeneous_layout
    case = build_stream_case(bits, g, k, n, m=bus, layout_fn=layout_fn)
    rng = np.random.default_rng(bits * 31 + k + n + m)
    x = rng.standard_normal((m, k)).astype(np.float32)
    got, sw, tabs = _run_case(case, x)
    want = np.asarray(stream_matmul_ref(
        x, sw, tabs.w_tab, tabs.s_tab, bits=bits, group_size=g))
    assert got.shape == (m, n)
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@given(stream_matmul_cases())
@settings(max_examples=6, deadline=None)
def test_layout_strategy_invariance(case_params):
    """The same codes through two different layouts produce *bit
    identical* matmul outputs — the slot tables fully absorb the
    placement."""
    bits, g, k, n, m, bus, _ = case_params
    rng = np.random.default_rng(k * 7 + n)
    x = rng.standard_normal((m, k)).astype(np.float32)
    iris, _, _ = _run_case(build_stream_case(bits, g, k, n, m=bus), x)
    homo, _, _ = _run_case(
        build_stream_case(bits, g, k, n, m=bus,
                          layout_fn=homogeneous_layout), x)
    np.testing.assert_array_equal(iris, homo)
