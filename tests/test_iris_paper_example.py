"""Faithful-reproduction tests: every worked number in the paper.

§4 example (Table 3/4, Figs. 3-5), Table 6 (Inverse Helmholtz), and
Table 7 (Matrix Multiplication).
"""
import pytest

from repro.core.baselines import (
    hls_padded_layout,
    homogeneous_layout,
    naive_layout,
)
from repro.core.iris import schedule
from repro.core.task import (
    INV_HELMHOLTZ,
    PAPER_EXAMPLE,
    ArraySpec,
    LayoutProblem,
    make_problem,
    matmul_problem,
)


class TestSection4Example:
    def test_table4_heights_and_deltas(self):
        p = PAPER_EXAMPLE
        by = {a.name: a for a in p.arrays}
        assert p.d_max == 6
        # Table 4 rows: delta_j and h(j)
        assert by["A"].delta(p.m) == 8 and by["A"].height(p.m) == 2
        assert by["C"].delta(p.m) == 8 and by["C"].height(p.m) == 2
        assert by["E"].delta(p.m) == 6 and by["E"].height(p.m) == 2
        assert by["B"].delta(p.m) == 6 and by["B"].height(p.m) == 3
        assert by["D"].delta(p.m) == 5 and by["D"].height(p.m) == 4
        # release times r_j = d_max - d_j
        assert [p.release_time(a) for a in p.arrays] == [4, 0, 3, 0, 3]
        assert p.p_tot == 69

    def test_naive_fig3(self):
        m = naive_layout(PAPER_EXAMPLE).metrics()
        assert m.c_max == 19
        assert m.l_max == 13           # "D would arrive 13 cycles after d=6"
        assert m.efficiency == pytest.approx(69 / (19 * 8))   # 45.4%

    def test_homogeneous_fig4(self):
        m = homogeneous_layout(PAPER_EXAMPLE).metrics()
        assert m.c_max == 13
        assert m.l_max == 7
        assert m.efficiency == pytest.approx(69 / (13 * 8))   # 66.3%

    def test_iris_fig5(self):
        lay = schedule(PAPER_EXAMPLE)
        lay.validate()
        m = lay.metrics()
        assert m.c_max == 9
        assert m.l_max == 3
        assert m.efficiency == pytest.approx(69 / (9 * 8))    # 95.8%
        assert m.wasted_bits == 3                             # "wasting only 3 bits"

    def test_layouts_are_valid(self):
        for fn in (naive_layout, homogeneous_layout, hls_padded_layout):
            fn(PAPER_EXAMPLE).validate()


class TestTable6InvHelmholtz:
    """Table 6: layout metrics with varied delta/W."""

    def test_naive_column(self):
        m = homogeneous_layout(INV_HELMHOLTZ).metrics()
        assert m.c_max == 697
        assert m.efficiency == pytest.approx(0.998, abs=5e-4)
        assert m.fifo_depth == {"u": 998, "S": 90, "D": 998}

    @pytest.mark.parametrize(
        "dw,c_max,eff,l_max,fifo_s",
        [
            (4, 696, 0.999, 333, 30),
            (3, 704, 0.988, 341, 30),
            (2, 711, 0.979, 348, 15),
            (1, 1361, 0.511, 998, 0),
        ],
    )
    def test_iris_columns(self, dw, c_max, eff, l_max, fifo_s):
        p = make_problem(
            256,
            [(a.name, a.width, a.depth, a.due) for a in INV_HELMHOLTZ.arrays],
            max_lanes=dw,
        )
        lay = schedule(p)
        lay.validate()
        m = lay.metrics()
        assert m.c_max == c_max
        assert m.efficiency == pytest.approx(eff, abs=1e-3)
        assert m.l_max == l_max
        assert m.fifo_depth["S"] == fifo_s

    def test_iris_fifo_reduction_vs_naive(self):
        """Paper: -33% u, -36% D, -67% S (approximately)."""
        naive = homogeneous_layout(INV_HELMHOLTZ).metrics().fifo_depth
        iris = schedule(INV_HELMHOLTZ).metrics().fifo_depth
        assert iris["u"] <= naive["u"] * 0.68
        assert iris["D"] <= naive["D"] * 0.65
        assert iris["S"] <= naive["S"] * 0.34

    def test_dw1_eliminates_fifos(self):
        """delta/W=1: one element per array per cycle -> no extra ports."""
        p = make_problem(
            256,
            [(a.name, a.width, a.depth, a.due) for a in INV_HELMHOLTZ.arrays],
            max_lanes=1,
        )
        lay = schedule(p)
        assert all(d == 0 for d in lay.fifo_depths())
        assert max(lay.max_concurrent_elems()) == 1


class TestTable7MatMul:
    def test_w64_naive(self):
        m = homogeneous_layout(matmul_problem(64, 64)).metrics()
        assert m.c_max == 314
        assert m.l_max == 157
        assert m.efficiency == pytest.approx(0.995, abs=5e-4)
        assert m.fifo_depth == {"A": 468, "B": 468}

    def test_w64_iris(self):
        lay = schedule(matmul_problem(64, 64))
        lay.validate()
        m = lay.metrics()
        assert m.c_max == 313
        assert m.l_max == 156
        assert m.efficiency == pytest.approx(0.998, abs=5e-4)
        assert m.fifo_depth == {"A": 312, "B": 312}   # paper: -33% memory

    @pytest.mark.parametrize(
        "wa,wb,naive_fifo,iris_eff_min",
        [
            # Paper's FIFO-depth rows reproduce exactly; its custom-width
            # C_max/eff rows are internally inconsistent (DESIGN.md §2), so
            # we assert our reproduction and the qualitative claim.
            ((33), (31), {"A": 535, "B": 546}, 0.97),
            ((30), (19), {"A": 546, "B": 576}, 0.96),
        ],
    )
    def test_custom_widths(self, wa, wb, naive_fifo, iris_eff_min):
        p = matmul_problem(wa, wb)
        nm = homogeneous_layout(p).metrics()
        assert nm.fifo_depth == naive_fifo
        im = schedule(p).metrics()
        assert im.efficiency > nm.efficiency        # Iris beats naive
        assert im.efficiency >= iris_eff_min
        assert im.c_max < nm.c_max
        assert im.l_max < nm.l_max
        assert sum(im.fifo_depth.values()) < sum(nm.fifo_depth.values())

    def test_hls_padding_is_worse_for_custom_widths(self):
        """§1 motivation: HLS lane-padding wastes bandwidth on odd widths."""
        p = matmul_problem(33, 31)
        hls = hls_padded_layout(p).metrics()
        iris = schedule(p).metrics()
        assert iris.efficiency > hls.efficiency + 0.20


class TestProblemSpec:
    def test_json_roundtrip(self):
        p = PAPER_EXAMPLE
        q = LayoutProblem.from_json(p.to_json())
        assert q == p

    def test_invalid_specs_rejected(self):
        with pytest.raises(ValueError):
            ArraySpec("x", 0, 4, 0)
        with pytest.raises(ValueError):
            ArraySpec("x", 4, 0, 0)
        with pytest.raises(ValueError):
            ArraySpec("x", 4, 4, -1)
        with pytest.raises(ValueError):
            LayoutProblem(m=8, arrays=(ArraySpec("x", 9, 1, 0),)).arrays[0].delta(8)
        with pytest.raises(ValueError):
            make_problem(8, [("x", 2, 2, 0), ("x", 3, 2, 0)])

    def test_element_wider_than_bus(self):
        p = make_problem(8, [("w", 16, 4, 0)])
        with pytest.raises(ValueError):
            schedule(p)
