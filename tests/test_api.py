"""The `repro.api` façade: registries, Plan laziness, cross-backend
equivalence, layer-stack planning, and the compatibility re-export
policy (every pre-façade import path must keep resolving).
"""
import numpy as np
import pytest

import repro
from repro import api
from repro.api import make_problem
from repro.core import LayoutCache

# The three acceptance problems: the paper §4 worked example, a
# non-power-of-two-width problem, and a lane-capped bundle-style problem.
PROBLEMS = {
    "paper_example": api.PAPER_EXAMPLE,
    "non_pow2": make_problem(
        64, [("a", 3, 40, 4), ("b", 5, 24, 8), ("c", 6, 16, 12),
             ("d", 11, 9, 2)]),
    "lane_capped_bundle": make_problem(
        64, [("w", 4, 96, 6), ("s", 16, 24, 6), ("n", 8, 16, 2)],
        max_lanes=2),
}


# ----------------------------------------------------------------------
# cross-backend equivalence: every strategy x every decode backend
# ----------------------------------------------------------------------
@pytest.mark.parametrize("prob_name", sorted(PROBLEMS))
@pytest.mark.parametrize("strategy", api.strategies())
def test_cross_backend_equivalence(strategy, prob_name):
    """pack -> decode roundtrips bit-for-bit on both backends."""
    prob = PROBLEMS[prob_name]
    pl = api.plan(prob, strategy, cache=None).validate()
    codes = api.random_codes(prob, seed=7)
    buf = pl.pack(codes)
    out_np = pl.decode(buf, backend="numpy")
    out_pl = pl.decode(buf, backend="pallas", interpret=True)
    for name, want in codes.items():
        assert np.array_equal(out_np[name], want), (strategy, name)
        assert np.array_equal(out_pl[name], out_np[name]), (strategy, name)
        assert out_np[name].dtype == out_pl[name].dtype == np.uint64


def test_c_backend_emits_both_listings():
    pl = api.plan(api.PAPER_EXAMPLE)
    src = pl.emit(target="c", artifact="both")
    assert "void pack(" in src          # paper Listing 1
    assert "void read_data(" in src     # paper Listing 2
    assert pl.emit(target="c") == pl.emit(target="c", artifact="decode")
    with pytest.raises(ValueError, match="artifact"):
        pl.emit(target="c", artifact="verilog")


# ----------------------------------------------------------------------
# registries
# ----------------------------------------------------------------------
def test_unknown_strategy_lists_registered_names():
    with pytest.raises(KeyError) as ei:
        api.plan(api.PAPER_EXAMPLE, "irsi")
    msg = str(ei.value)
    for name in api.strategies():
        assert name in msg


def test_unknown_backend_lists_registered_names():
    pl = api.plan(api.PAPER_EXAMPLE)
    with pytest.raises(KeyError) as ei:
        pl.decode(np.zeros((9, 1), np.uint8), backend="cuda")
    msg = str(ei.value)
    for name in api.backends():
        assert name in msg


def test_backend_capability_errors_name_alternatives():
    pl = api.plan(api.PAPER_EXAMPLE)
    with pytest.raises(NotImplementedError, match="numpy"):
        pl.decode(np.zeros((9, 1), np.uint8), backend="c")
    with pytest.raises(NotImplementedError, match="'c'"):
        pl.emit(target="numpy")


def test_duplicate_registration_rejected():
    with pytest.raises(KeyError, match="already registered"):
        api.STRATEGIES.register("iris", lambda p, **kw: None)


def test_custom_strategy_registers_and_plans():
    from repro.core.baselines import naive_layout

    api.STRATEGIES.register(
        "reversed_naive",
        lambda p, **kw: naive_layout(p), overwrite=True)
    try:
        m = api.plan(api.PAPER_EXAMPLE, "reversed_naive").metrics
        assert m.c_max == 19
        assert "reversed_naive" in api.strategies()
        assert api.compare(api.PAPER_EXAMPLE)["reversed_naive"].c_max == 19
    finally:
        del api.STRATEGIES._entries["reversed_naive"]


# ----------------------------------------------------------------------
# Plan semantics
# ----------------------------------------------------------------------
def test_plan_is_lazy_and_memoized():
    cache = LayoutCache()
    pl = api.plan(api.PAPER_EXAMPLE, cache=cache)
    assert cache.misses == 0            # nothing scheduled yet
    lay = pl.layout
    assert cache.misses == 1
    assert pl.layout is lay             # memoized, no second run
    assert pl.metrics is pl.metrics
    assert pl.decode_plan is pl.decode_plan
    assert cache.misses == 1


def test_plan_routes_through_shared_cache_by_default():
    p = make_problem(32, [("x", 3, 50, 5), ("y", 7, 30, 9)])
    from repro.core.iris import DEFAULT_CACHE

    api.plan(p).layout
    h0 = DEFAULT_CACHE.hits
    api.plan(p).layout                  # identical problem: cache hit
    assert DEFAULT_CACHE.hits == h0 + 1


def test_plan_many_dedupes_without_shared_cache():
    p = make_problem(32, [("x", 3, 50, 5), ("y", 7, 30, 9)])
    plans = api.plan_many([p, p, p], cache=None)
    layouts = [pl.layout for pl in plans]
    cache = plans[0].cache
    assert cache.misses == 1 and cache.hits == 2
    assert all(lay.count_intervals == layouts[0].count_intervals
               for lay in layouts)


def test_plan_stream_bytes_matches_buffer():
    pl = api.plan(api.PAPER_EXAMPLE)
    buf = pl.pack(api.random_codes(pl.problem))
    assert pl.stream_bytes == buf.size == pl.c_max * pl.problem.m // 8


def test_compare_covers_whole_registry():
    out = api.compare(api.PAPER_EXAMPLE)
    assert list(out) == api.strategies()
    assert out["iris"].c_max == 9 and out["naive"].c_max == 19


# ----------------------------------------------------------------------
# layer-stack planning (shared by serve --packed and packing reports)
# ----------------------------------------------------------------------
class _Cfg:
    name = "toy"
    d_model, d_ff = 64, 128
    n_heads, n_kv_heads, head_dim = 4, 2, 16
    n_layers = 5


def test_plan_layer_stack_schedules_once():
    from repro.quant import QuantSpec

    stack = api.plan_layer_stack(_Cfg, QuantSpec(bits=4, group_size=32),
                                 m=512, cache=LayoutCache())
    assert stack.n_layers == _Cfg.n_layers
    assert stack.scheduler_runs == 1
    assert stack.cache_hits == _Cfg.n_layers - 1
    first = stack.plans[0].layout
    assert all(pl.layout.count_intervals == first.count_intervals
               for pl in stack.plans)
    assert stack.stream_bytes_per_layer == stack.c_max_per_layer * 512 // 8
    assert 0 < stack.b_eff <= 1


def test_plan_layer_stack_agrees_with_serving_report():
    from repro.core.packing import serving_stream_report
    from repro.quant import QuantSpec

    qspec = QuantSpec(bits=4, group_size=32)
    cache = LayoutCache()
    stack = api.plan_layer_stack(_Cfg, qspec, m=512, n_layers=1, cache=cache)
    rep = serving_stream_report(_Cfg, qspec, m=512, cache=cache)
    assert rep["iris_MiB_per_layer"] == pytest.approx(
        stack.stream_bytes_per_layer / 2**20)
    assert rep["n_decode_units"] == stack.plans[0].decode_plan.n_units


# ----------------------------------------------------------------------
# compatibility: every pre-façade import path keeps resolving
# ----------------------------------------------------------------------
def test_old_import_paths_still_resolve():
    from repro.core.baselines import (       # noqa: F401
        ALL_BASELINES,
        hls_padded_layout,
        homogeneous_layout,
        naive_layout,
    )
    from repro.core.codegen import (         # noqa: F401
        decode_plan,
        emit_c_decode,
        emit_c_pack,
        pack_arrays,
        random_codes,
        unpack_arrays,
    )
    from repro.core.dse import sweep_max_lanes, sweep_widths  # noqa: F401
    from repro.core.iris import (            # noqa: F401
        DEFAULT_CACHE,
        LayoutCache,
        schedule,
        schedule_many,
    )
    from repro.core.layout import Layout, LayoutMetrics  # noqa: F401
    from repro.core.packing import (         # noqa: F401
        bundle_problem,
        layer_bundle_spec,
        pack_bundle,
        serving_stream_report,
    )
    from repro.core.task import (            # noqa: F401
        INV_HELMHOLTZ,
        PAPER_EXAMPLE,
        ArraySpec,
        LayoutProblem,
        make_problem,
        matmul_problem,
    )

    # curated exports alias the originals, not copies — and the
    # pre-façade compat aliases now warn, naming the repro.api
    # replacement, while still resolving to the same object
    with pytest.deprecated_call(match="repro.api"):
        assert repro.core.schedule is schedule
    with pytest.deprecated_call(match="repro.api"):
        assert repro.schedule is schedule
    with pytest.deprecated_call(match="repro.api.PAPER_EXAMPLE"):
        assert repro.core.PAPER_EXAMPLE is PAPER_EXAMPLE


def test_deprecated_packed_params_alias():
    """`PackedParams` warns, names the replacement, and still works."""
    with pytest.deprecated_call(match="repro.api.PackedTree"):
        from repro.models.quantized import PackedParams
    assert PackedParams is api.PackedTree


def test_curated_all_exports_resolve():
    import warnings

    with warnings.catch_warnings():
        # the compat aliases in __all__ warn by design; they must still
        # all resolve
        warnings.simplefilter("ignore", DeprecationWarning)
        for name in repro.core.__all__:
            assert getattr(repro.core, name) is not None
        for name in repro.__all__:
            assert getattr(repro, name) is not None
        for name in api.__all__:
            assert getattr(api, name) is not None


def test_version_sourced_from_pyproject():
    import pathlib
    import re

    assert re.fullmatch(r"\d+\.\d+.*", repro.__version__)
    pyproject = (pathlib.Path(repro.__file__).resolve().parents[2]
                 / "pyproject.toml")
    m = re.search(r'^version\s*=\s*"([^"]+)"', pyproject.read_text(),
                  re.MULTILINE)
    assert m is not None
    assert repro.__version__ == m.group(1)
