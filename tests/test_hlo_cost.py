"""Loop-aware HLO cost extraction vs ground truth (unrolled references)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestLoopAwareFlops:
    def test_scan_matches_unrolled(self):
        w = jnp.zeros((128, 128))
        x = jnp.zeros((128, 128))

        def scanned(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=8)
            return y

        def unrolled(x, w):
            for _ in range(8):
                x = x @ w
            return x

        fs = analyze(_compiled_text(scanned, x, w))
        fu = analyze(_compiled_text(unrolled, x, w))
        want = 8 * 2 * 128 ** 3
        assert fs.flops == pytest.approx(want, rel=0.01)
        assert fu.flops == pytest.approx(want, rel=0.01)
        assert fs.n_while_loops == 1 and fs.max_trip_count == 8

    def test_nested_scan_multiplies(self):
        w = jnp.zeros((64, 64))
        x = jnp.zeros((64, 64))

        def nested(x, w):
            def outer(c, _):
                def inner(c2, _):
                    return c2 @ w, None
                c, _ = jax.lax.scan(inner, c, None, length=4)
                return c, None
            y, _ = jax.lax.scan(outer, x, None, length=8)
            return y

        f = analyze(_compiled_text(nested, x, w))
        assert f.flops == pytest.approx(32 * 2 * 64 ** 3, rel=0.01)

    def test_plain_matmul(self):
        a = jnp.zeros((32, 100))
        b = jnp.zeros((100, 48))
        f = analyze(_compiled_text(lambda a, b: a @ b, a, b))
        assert f.flops == pytest.approx(2 * 32 * 100 * 48, rel=0.01)

    def test_hbm_bytes_scale_with_loop(self):
        w = jnp.zeros((256, 256))
        x = jnp.zeros((256, 256))

        def scanned(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=16)
            return y

        def once(x, w):
            return jnp.tanh(x @ w)

        fs = analyze(_compiled_text(scanned, x, w))
        f1 = analyze(_compiled_text(once, x, w))
        assert fs.hbm_bytes > 8 * f1.hbm_bytes   # ~16x modulo fusion noise


class TestCollectiveScaling:
    def test_collective_inside_loop_is_multiplied(self):
        import subprocess
        import sys
        import textwrap
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count=8")
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_debug_mesh
            from repro.launch.hlo_cost import analyze
            mesh = make_debug_mesh((8,), ("model",))
            w = jnp.zeros((128, 128))
            x = jnp.zeros((64, 128))
            sh_w = NamedSharding(mesh, P(None, "model"))
            sh_x = NamedSharding(mesh, P())

            def fn(x, w):
                def body(c, _):
                    # contraction over the sharded dim -> all-reduce per step
                    h = c @ w                       # (64, 128) sharded col
                    c2 = h @ w.T                    # psum
                    return c2, None
                y, _ = jax.lax.scan(body, x, None, length=8)
                return y

            with mesh:
                txt = jax.jit(fn, in_shardings=(sh_x, sh_w)).lower(
                    x, w).compile().as_text()
            c = analyze(txt)
            single = 64 * 128 * 4
            assert c.collective_bytes >= 7 * single, (
                c.collective_bytes, single)
            print("COLL_OK", c.collective_bytes)
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, timeout=300,
                           env={**__import__("os").environ,
                                "PYTHONPATH": "src"})
        assert r.returncode == 0, r.stderr[-2000:]
        assert "COLL_OK" in r.stdout
