"""Property tests for the model-integration packing layer and the
beyond-paper scheduler refinement.

Skipped gracefully where hypothesis is not installed.
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from conftest import bundles
from repro.core.packing import (
    bundle_problem,
    layer_bundle_spec,
    pack_bundle,
)
from repro.quant import QuantSpec


@given(bundles(), st.sampled_from([512, 1024, 4096]))
@settings(max_examples=40, deadline=None)
def test_bundle_layouts_valid_and_dense(bundle, m):
    pb = pack_bundle(bundle, m=m)
    pb.layout.validate()
    assert pb.metrics_iris["B_eff"] > 0.5
    # the unified stream can't be smaller than the useful bits
    useful = sum(b.width_bits * b.n_elems for b in bundle)
    assert pb.stream_bytes * 8 >= useful


@given(bundles())
@settings(max_examples=40, deadline=None)
def test_due_dates_follow_stages(bundle):
    """Dataflow due dates are nondecreasing in stage order."""
    prob = bundle_problem(bundle, m=1024)
    by_stage = {}
    for b, a in zip(bundle, prob.arrays):
        by_stage.setdefault(b.stage, []).append(a.due)
    stages = sorted(by_stage)
    for s1, s2 in zip(stages, stages[1:]):
        assert max(by_stage[s1]) <= max(by_stage[s2])


@given(st.integers(2, 8))
@settings(max_examples=7, deadline=None)
def test_layer_bundle_scales_with_bits(bits):
    spec = QuantSpec(bits=bits, group_size=64)
    bundle = layer_bundle_spec(256, 512, 4, 2, 64, spec)
    weights = [b for b in bundle if not b.name.endswith("_scales")
               and "norm" not in b.name]
    assert all(b.width_bits == bits for b in weights)
    # scales: one per (group, out-channel)
    scales = [b for b in bundle if b.name.endswith("_scales")]
    assert len(scales) == len(weights)
    for w, s in zip(weights, scales):
        assert s.n_elems == w.n_elems // 64


def test_fill_residual_beyond_paper_refinement():
    """The LRM leftover-bits refinement (DESIGN.md §2) never hurts and
    helps on residual-heavy problems."""
    from repro.core.iris import schedule
    from repro.core.task import make_problem
    rng = np.random.default_rng(0)
    helped = 0
    for trial in range(25):
        specs = [(f"a{i}", int(rng.integers(3, 30)),
                  int(rng.integers(4, 40)), int(rng.integers(0, 30)))
                 for i in range(rng.integers(2, 7))]
        p = make_problem(64, specs)
        base = schedule(p, fill_residual=False).metrics()
        fill = schedule(p, fill_residual=True).metrics()
        assert fill.c_max <= base.c_max
        helped += fill.c_max < base.c_max
    assert helped >= 1            # it finds real wins on random instances
