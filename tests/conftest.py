"""Shared test fixtures and problem builders.

Dedupes the deterministic problem lists and hypothesis composites that
used to be copy-pasted across test_exec_plan*, test_kernels and
test_packing_properties, and hosts the stream-matmul case builder the
equivalence/property suites share.

Hypothesis is optional (the container may not ship it): everything
hypothesis-flavoured is guarded, and the property-test modules keep
their ``pytest.importorskip`` gates.  When hypothesis *is* present, two
profiles are registered — ``ci`` (derandomized, fixed seed database:
reproducible CI runs) and ``dev`` — selected by ``HYPOTHESIS_PROFILE``.
"""
import os

import numpy as np

# the deterministic problem sets live in repro.analysis.suite — one
# source of truth shared by these tests and the analysis-gate CI job
from repro.analysis.suite import (  # noqa: F401  (test-suite re-exports)
    DECODE_PROBLEMS,
    EXEC_PROBLEMS,
    GATE_PROBLEMS,
    GOLDEN_PROBLEM,
)
from repro.core.task import make_problem


# ----------------------------------------------------------------------
# stream-matmul case builder (equivalence + property suites)
# ----------------------------------------------------------------------
def build_stream_case(bits: int, group_size: int, k: int, n: int, *,
                      m: int = 512, layout_fn=None, max_lanes=None,
                      seed: int = 0):
    """Quantize a random (K, N) matrix, pack it (with its scales) into an
    Iris stream, and return everything a stream-direct matmul needs.

    Returns ``(codes, qt, layout, prog, buf, tabs)`` where ``codes`` is
    the (K, N) uint8 code matrix, ``qt`` the QuantizedTensor (for float
    references), ``buf`` the packed ``(c_max, m/8)`` buffer and ``tabs``
    the :class:`~repro.core.exec_plan.StreamTables`.

    ``layout_fn`` defaults to the Iris scheduler; pass a baseline to
    exercise strategy invariance.  ``max_lanes`` schedules the weight
    array lane-capped (paper §3.3 constraint) — that path bypasses
    ``bundle_problem`` and builds the problem directly.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.exec_plan import (
        lower_exec,
        pack_compiled,
        stream_matmul_tables,
    )
    from repro.core.iris import schedule
    from repro.core.packing import (
        BundleTensor,
        bundle_problem,
        pad_bundle_elements,
    )
    from repro.quant import QuantSpec, quantize

    g = group_size
    spec = QuantSpec(bits=bits, group_size=g)
    w = jax.random.normal(jax.random.PRNGKey(seed + bits * 1000 + k + n),
                          (k, n), jnp.float32)
    qt = quantize(w, spec)
    codes = np.asarray(qt.codes)
    u16 = np.asarray(jax.lax.bitcast_convert_type(
        qt.scales, jnp.uint16)).astype(np.uint64)
    data = {"w": codes.reshape(-1).astype(np.uint64),
            "w_scales": u16.reshape(-1)}
    if max_lanes is not None:
        prob = make_problem(
            m, [("w", bits, k * n, 1), ("w_scales", 16, (k // g) * n, 1)],
            max_lanes=max_lanes)
        ew = None
    else:
        bundle = [BundleTensor("w", bits, k * n, 1),
                  BundleTensor("w_scales", 16, (k // g) * n, 1)]
        prob = bundle_problem(bundle, m=m)
        ew = (bits, 16)
    lay = (layout_fn or schedule)(prob)
    prog = lower_exec(lay, elem_widths=ew)
    padded = pad_bundle_elements(prob, prog, data) if ew is not None else data
    buf = pack_compiled(lay, padded, program=prog)
    tabs = stream_matmul_tables(lay, "w", (k, n), scales="w_scales",
                                group_size=g, program=prog)
    return codes, qt, lay, prog, buf, tabs


def two_pass_oracle(x, lay, prog, buf, bits: int, group_size: int,
                    k: int, n: int, *, block_m: int = 128,
                    block_n: int = 128, block_k: int = 512):
    """The legacy two-pass path: fused Pallas decode materializes dense
    codes/scales, then the lane-packed Pallas matmul consumes them.

    For widths ``packed_matmul`` cannot lane-pack, the codes are
    re-biased into 8-bit containers (``c + 128 - 2^(bits-1)``), which
    leaves every dequantized float value identical — so the oracle
    remains *bit-exact* for any ``bits <= 8``.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.layout_decode import decode_layout_fused
    from repro.kernels.packed_matmul import SUPPORTED_BITS, packed_matmul

    g = group_size
    dec = decode_layout_fused(lay, buf, program=prog, interpret=True)
    codes = np.asarray(dec["w"])[:k * n].reshape(k, n)
    scales = jax.lax.bitcast_convert_type(
        jnp.asarray(np.asarray(dec["w_scales"])[:(k // g) * n]
                    .astype(np.uint16).reshape(k // g, n)), jnp.bfloat16)
    if bits in SUPPORTED_BITS:
        mm_bits = bits
    else:
        codes = codes + (128 - (1 << (bits - 1)))
        mm_bits = 8
    from repro.quant import pack_codes_u32
    pw = pack_codes_u32(jnp.asarray(codes.astype(np.uint8)), mm_bits)
    return packed_matmul(x, pw, scales, bits=mm_bits, group_size=g,
                         block_m=block_m, block_n=block_n, block_k=block_k,
                         interpret=True)


# ----------------------------------------------------------------------
# packed KV-cache random-walk oracle (deterministic + property suites)
# ----------------------------------------------------------------------
def run_kv_walk(bits, hd, ops, seed, *, page_tokens=4, n_slots=3,
                max_seq=8):
    """Replay append/reset ``ops`` against a PackedKVCache and a dense
    numpy mirror of the quantize -> dequantize values, then assert the
    packed pages decode bit-exactly to the mirror.

    ``ops``: sequence of ``("reset", slot)`` or ``("append", [slots])``.
    Each slot keeps its own clock (continuous batching); appends past
    capacity are dropped.  Shared by the always-on seeded subset in
    test_kvcache.py and the hypothesis walk in test_kvcache_property.py.
    """
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.kvcache import PackedKVCache, dequantize_kv, quantize_kv

    cfg = get_config("smollm-135m").reduced(
        n_layers=1, n_heads=4, n_kv_heads=2, head_dim=hd, d_model=4 * hd,
        d_ff=64, vocab_size=64)
    rng = np.random.default_rng(seed)
    kvc = PackedKVCache.create(cfg, bits=bits, page_tokens=page_tokens,
                               n_slots=n_slots, max_seq=max_seq)
    smax = kvc.smax
    want_k = np.zeros((n_slots, smax, 2, hd), np.float32)
    want_v = np.zeros_like(want_k)
    clock = [0] * n_slots
    for op, arg in ops:
        if op == "reset":
            kvc = kvc.reset(arg)
            want_k[arg] = want_v[arg] = 0.0
            clock[arg] = 0
            continue
        slots = [s for s in arg if clock[s] < smax]
        if not slots:
            continue
        k = jnp.asarray(rng.normal(size=(len(slots), 2, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(len(slots), 2, hd)), jnp.float32)
        pos = jnp.asarray([clock[s] for s in slots], jnp.int32)
        kvc = kvc.append(k, v, pos, jnp.asarray(slots, jnp.int32), layer=0)
        kq = np.asarray(dequantize_kv(*quantize_kv(k, bits), bits))
        vq = np.asarray(dequantize_kv(*quantize_kv(v, bits), bits))
        for i, s in enumerate(slots):
            want_k[s, clock[s]] = kq[i]
            want_v[s, clock[s]] = vq[i]
            clock[s] += 1
    kf, vf = kvc.dense_kv(0)
    np.testing.assert_array_equal(np.asarray(kf), want_k)
    np.testing.assert_array_equal(np.asarray(vf), want_v)
    return kvc


# ----------------------------------------------------------------------
# golden-file serialization
# ----------------------------------------------------------------------
def serialize_exec_program(prog) -> dict:
    """JSON-stable dump of an ExecProgram's lowered tables.

    Covers everything the kernels consume: destination words/shifts,
    piece bookkeeping, the fused-decode slot table (nonzero entries
    only, as (row, col, tab) triplets), the per-array gathers and the
    stream-direct global bit offsets.
    """
    kt = prog.kernel
    nz = np.argwhere(kt.tab != 0)
    return {
        "m": prog.m,
        "c_max": prog.c_max,
        "row_bytes": prog.row_bytes,
        "wpr": prog.wpr,
        "elem_widths": list(prog.elem_widths),
        "piece_depths": list(prog.piece_depths),
        "piece_base": list(prog.piece_base),
        "word": prog.word.tolist(),
        "shift": prog.shift.tolist(),
        "host_arrays": list(prog.host_arrays),
        "kernel": {
            "words32": kt.words32,
            "lanes": kt.lanes,
            "tab_nonzero": [[int(r), int(c), int(kt.tab[r, c])]
                            for r, c in nz],
            "gathers": [[int(i), g.tolist()] for i, g in kt.gathers],
        },
        "stream_bit_offsets": [
            prog.stream_bit_offsets(i).tolist()
            for i in range(len(prog.piece_depths))
            if prog.elem_widths[i] <= 32
        ],
    }


# ----------------------------------------------------------------------
# hypothesis: profiles + shared composites (all guarded)
# ----------------------------------------------------------------------
try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    hypothesis.settings.register_profile(
        "ci", derandomize=True, deadline=None, print_blob=True)
    hypothesis.settings.register_profile("dev", deadline=None)
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "dev"))

    @st.composite
    def problems(draw):
        """Random LayoutProblems: §4-style, non-power-of-two widths and
        bus, lane-capped, multi-interval (shared by the exec-plan and
        stream-matmul property suites)."""
        m = draw(st.sampled_from([24, 40, 64, 128, 256]))
        n = draw(st.integers(2, 5))
        max_lanes = draw(st.sampled_from([None, 1, 2, 4]))
        specs = []
        for i in range(n):
            width = draw(st.integers(1, min(64, m)))
            depth = draw(st.integers(1, 400))
            due = draw(st.integers(0, 40))       # spread -> multi-interval
            specs.append((f"a{i}", width, depth, due))
        return make_problem(m, specs, max_lanes=max_lanes)

    @st.composite
    def bundles(draw):
        """Random layer bundles (model-integration packing layer)."""
        from repro.core.packing import BundleTensor

        n = draw(st.integers(2, 6))
        out = []
        for i in range(n):
            out.append(BundleTensor(
                name=f"t{i}",
                width_bits=draw(st.integers(2, 32)),
                n_elems=draw(st.integers(100, 50_000)),
                stage=draw(st.integers(0, 5)),
            ))
        return out

    @st.composite
    def stream_matmul_cases(draw):
        """Shrinking-friendly stream-matmul problems: (bits, group_size,
        K, N, M, m, strategy).  Shrinks toward small shapes and the
        plain Iris strategy."""
        bits = draw(st.integers(2, 8))
        g = draw(st.sampled_from([32, 64]))
        k = g * draw(st.integers(1, 5))
        n = draw(st.integers(1, 150))
        mm = draw(st.integers(1, 33))
        bus = draw(st.sampled_from([64, 512]))
        strategy = draw(st.sampled_from(["iris", "homogeneous"]))
        return bits, g, k, n, mm, bus, strategy
