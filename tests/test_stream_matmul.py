"""Stream-direct packed matmul: layout-equivalence lockdown.

The contract under test: ``kernels.stream_matmul`` — which gathers
quantized weights *straight from the packed Iris stream* inside the
matmul prologue — must be **bit-identical** to the legacy two-pass
oracle (fused Pallas layout-decode -> lane-packed Pallas matmul), for
every quantization width, every layout strategy, ragged shapes,
lane-capped schedules and §4-style small buses.  Both kernels share the
inline dequant-prologue + ``jnp.dot`` structure, so XLA lowers their
reductions identically and exact equality is the right assertion (a
plain ``jnp.dot`` reference is *not* bit-stable at M=1, where XLA's
small-M dot lowering is fusion-sensitive — those cells get the host
reference with float tolerance instead).

For widths packed_matmul cannot lane-pack (3/5/6/7), the oracle
re-biases codes into 8-bit containers, which preserves every
dequantized float exactly — see ``conftest.two_pass_oracle``.

All kernels run interpret=True (CPU container; TPU is the lowering
target).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import build_stream_case, two_pass_oracle
from repro.core.baselines import homogeneous_layout, naive_layout
from repro.core.exec_plan import lower_exec, pack_compiled, stream_matmul_tables
from repro.core.iris import schedule
from repro.core.packing import pad_bundle_elements
from repro.core.task import make_problem
from repro.kernels.ops import HostFallbackWarning, decode_layout_fused
from repro.kernels.ref import stream_matmul_ref
from repro.kernels.stream_matmul import stream_matmul, stream_words


def _x(m, k, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, k), jnp.float32)


def _run(case, x, **kw):
    _, _, _, prog, buf, tabs = case
    sw = stream_words(prog, buf)
    return stream_matmul(x, sw, tabs.w_tab, tabs.s_tab, bits=tabs.bits,
                         group_size=tabs.group_size, interpret=True, **kw)


# ----------------------------------------------------------------------
# bit-identity vs the two-pass oracle
# ----------------------------------------------------------------------
class TestBitIdentity:
    # ragged M (incl. the fusion-sensitive M=1), non-power-of-two N,
    # K that is a non-power-of-two multiple of the group
    SHAPES = [(16, 256, 128), (7, 192, 96), (1, 384, 33)]

    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    @pytest.mark.parametrize("shape", SHAPES)
    def test_matches_two_pass_oracle(self, bits, shape):
        m, k, n = shape
        case = build_stream_case(bits, 64, k, n)
        _, _, lay, prog, buf, _ = case
        x = _x(m, k, seed=bits)
        got = np.asarray(_run(case, x))
        want = np.asarray(two_pass_oracle(x, lay, prog, buf, bits, 64, k, n))
        assert got.shape == (m, n)
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("bits", [2, 3, 8])
    def test_matches_host_reference(self, bits):
        """Float agreement with the pure-host reference (covers the
        oracle itself; tolerance because XLA may fuse differently)."""
        m, k, n = 5, 128, 40
        case = build_stream_case(bits, 32, k, n)
        _, _, _, prog, buf, tabs = case
        x = _x(m, k, seed=bits + 7)
        got = np.asarray(_run(case, x))
        sw = np.asarray(stream_words(prog, buf))
        want = np.asarray(stream_matmul_ref(
            np.asarray(x), sw, tabs.w_tab, tabs.s_tab, bits=bits,
            group_size=32))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_dequant_value_agreement(self):
        """Stream-direct == x @ dequantize(w): the gathered weights are
        the true quantized values, not merely self-consistent bits."""
        from repro.quant import dequantize

        k, n = 128, 24
        case = build_stream_case(4, 32, k, n)
        _, qt, _, _, _, _ = case
        x = _x(9, k, seed=3)
        got = np.asarray(_run(case, x))
        want = np.asarray(x @ dequantize(qt).astype(jnp.float32))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
# layout-strategy invariance
# ----------------------------------------------------------------------
class TestLayoutInvariance:
    def test_strategies_bit_identical(self):
        """Iris, homogeneous and naive layouts scatter the same elements
        to different stream addresses; the slot tables must make the
        matmul output *bit-identical* across all three — N=130 also
        exercises the padded-N lane path."""
        m, k, n, bits, g = 5, 320, 130, 3, 64
        outs = []
        x = _x(m, k, seed=11)
        for fn in (schedule, homogeneous_layout, naive_layout):
            case = build_stream_case(bits, g, k, n, layout_fn=fn)
            outs.append(np.asarray(_run(case, x)))
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[0], outs[2])
        # and the shared value is the two-pass result
        case = build_stream_case(bits, g, k, n)
        _, _, lay, prog, buf, _ = case
        want = np.asarray(two_pass_oracle(x, lay, prog, buf, bits, g, k, n,
                                          block_n=130))
        np.testing.assert_array_equal(outs[0], want)


# ----------------------------------------------------------------------
# scheduling-constraint corners: lane caps and §4-style buses
# ----------------------------------------------------------------------
class TestSchedulingCorners:
    def test_lane_capped_schedule(self):
        """max_lanes=2 (§3.3) forces deep multi-row pieces; the global
        bit offsets must still address every element exactly."""
        m, k, n, bits, g = 4, 128, 16, 4, 32
        case = build_stream_case(bits, g, k, n, m=256, max_lanes=2)
        _, _, lay, prog, buf, _ = case
        x = _x(m, k, seed=5)
        got = np.asarray(_run(case, x))
        want = np.asarray(two_pass_oracle(x, lay, prog, buf, bits, g, k, n))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("bus", [24, 40])
    def test_small_nonpow2_bus(self, bus):
        """§4-scale buses (m=24 like the worked example's 8-bit rows,
        m=40 non-power-of-two): many elements straddle u32 words."""
        m, k, n, bits, g = 3, 64, 5, 3, 32
        case = build_stream_case(bits, g, k, n, m=bus)
        _, _, lay, prog, buf, _ = case
        x = _x(m, k, seed=bus)
        got = np.asarray(_run(case, x))
        want = np.asarray(two_pass_oracle(x, lay, prog, buf, bits, g, k, n))
        np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------------------
# host fallback: unit widths > 32 (satellite: HostFallbackWarning)
# ----------------------------------------------------------------------
class TestHostFallback:
    # 64 units of 40 bits = 128 elements of 20 bits, plus bf16 scales
    K, N, G = 16, 8, 8

    def _problem(self):
        return make_problem(128, [("w", 40, self.K * self.N // 2, 1),
                                  ("s", 16, (self.K // self.G) * self.N, 1)])

    def test_fused_decode_warns(self):
        """Unit widths > 32 silently fell back to host unpack before;
        now the fused decode raises HostFallbackWarning naming them."""
        from repro.core.codegen import random_codes
        from repro.kernels.ops import reset_host_fallback_warnings

        reset_host_fallback_warnings()
        p = self._problem()
        lay = schedule(p)
        buf = pack_compiled(lay, random_codes(p, seed=0))
        with pytest.warns(HostFallbackWarning) as rec:
            decode_layout_fused(lay, buf, interpret=True)
        w = rec[0].message
        assert ("w", 40) in w.arrays
        assert "40" in str(w) and "w" in str(w.arrays[0])

    def test_fallback_warns_once_per_layout_and_array(self):
        """Serving loops decode the same layout thousands of times; the
        fallback warning fires once per (layout signature, array), not
        per call — and the reset helper re-arms it."""
        import warnings

        from repro.core.codegen import random_codes
        from repro.kernels.ops import reset_host_fallback_warnings

        reset_host_fallback_warnings()
        p = self._problem()
        lay = schedule(p)
        buf = pack_compiled(lay, random_codes(p, seed=0))
        with pytest.warns(HostFallbackWarning):
            decode_layout_fused(lay, buf, interpret=True)
        # further decodes of the same layout: silent
        with warnings.catch_warnings():
            warnings.simplefilter("error", HostFallbackWarning)
            decode_layout_fused(lay, buf, interpret=True)
            decode_layout_fused(lay, buf, interpret=True)
        # reset re-arms the warning for the same layout
        reset_host_fallback_warnings()
        with pytest.warns(HostFallbackWarning) as rec:
            decode_layout_fused(lay, buf, interpret=True)
        assert ("w", 40) in rec[0].message.arrays

    def test_stream_direct_serves_wide_units_natively(self):
        """The same layout lowered at *element* granularity (20-bit
        elements inside the 40-bit units) needs no host path at all —
        stream-direct matmul consumes it exactly."""
        rng = np.random.default_rng(1)
        k, n, g = self.K, self.N, self.G
        codes = rng.integers(0, 1 << 20, size=(k, n), dtype=np.uint64)
        scales = np.asarray(
            jax.lax.bitcast_convert_type(
                jnp.asarray(rng.normal(size=(k // g, n)), jnp.bfloat16),
                jnp.uint16)).astype(np.uint64)
        p = self._problem()
        lay = schedule(p)
        prog = lower_exec(lay, elem_widths=(20, 16))
        assert prog.host_arrays == ()          # nothing left for the host
        data = pad_bundle_elements(
            p, prog, {"w": codes.reshape(-1), "s": scales.reshape(-1)})
        buf = pack_compiled(lay, data, program=prog)
        tabs = stream_matmul_tables(lay, "w", (k, n), scales="s",
                                    group_size=g, program=prog)
        x = _x(4, k, seed=9)
        got = np.asarray(stream_matmul(
            x, stream_words(prog, buf), tabs.w_tab, tabs.s_tab, bits=20,
            group_size=g, interpret=True))
        want = stream_matmul_ref(
            np.asarray(x), np.asarray(stream_words(prog, buf)),
            tabs.w_tab, tabs.s_tab, bits=20, group_size=g)
        np.testing.assert_allclose(got, np.asarray(want),
                                   rtol=1e-5, atol=1e-3)


# ----------------------------------------------------------------------
# validation surface
# ----------------------------------------------------------------------
class TestValidation:
    def _layout(self):
        case = build_stream_case(4, 32, 64, 8)
        return case[2], case[3]

    def test_unknown_array_name(self):
        lay, prog = self._layout()
        with pytest.raises(KeyError, match="nope"):
            stream_matmul_tables(lay, "nope", (64, 8), scales="w_scales",
                                 group_size=32, program=prog)

    def test_bad_group_size(self):
        lay, prog = self._layout()
        with pytest.raises(ValueError, match="group_size"):
            stream_matmul_tables(lay, "w", (64, 8), scales="w_scales",
                                 group_size=48, program=prog)

    def test_scale_width_must_be_bf16(self):
        lay, prog = self._layout()
        with pytest.raises(ValueError, match="16"):
            stream_matmul_tables(lay, "w", (64, 8), scales="w",
                                 group_size=32, program=prog)

    def test_shape_exceeds_capacity(self):
        lay, prog = self._layout()
        with pytest.raises(ValueError, match="pieces"):
            stream_matmul_tables(lay, "w", (64, 512), scales="w_scales",
                                 group_size=32, program=prog)

    def test_wide_weights_rejected(self):
        p = make_problem(128, [("w", 40, 64, 1), ("s", 16, 16, 1)])
        lay = schedule(p)
        with pytest.raises(ValueError, match="32"):
            stream_matmul_tables(lay, "w", (16, 8), scales="s",
                                 group_size=8)

    def test_kernel_rejects_bad_dtypes(self):
        case = build_stream_case(4, 32, 64, 8)
        _, _, _, prog, buf, tabs = case
        sw = stream_words(prog, buf)
        with pytest.raises(ValueError, match="uint32"):
            stream_matmul(_x(2, 64), sw.astype(jnp.int32), tabs.w_tab,
                          tabs.s_tab, bits=4, group_size=32, interpret=True)
