"""Property suite for the packed KV-cache append path.

Random append/evict/reset walks against a dense numpy mirror of the
quantize -> dequantize values (the walk harness lives in conftest, so
the seeded deterministic subset in test_kvcache.py still runs where
hypothesis is not installed; this module skips gracefully there).
"""
import numpy as np
import pytest

pytest.importorskip("jax")
pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from conftest import run_kv_walk  # noqa: E402

N_SLOTS = 3

ops = st.lists(
    st.one_of(
        st.tuples(st.just("reset"), st.integers(0, N_SLOTS - 1)),
        st.tuples(st.just("append"),
                  st.lists(st.integers(0, N_SLOTS - 1), min_size=1,
                           max_size=N_SLOTS, unique=True).map(sorted)),
    ),
    max_size=14,
)


@settings(max_examples=25, deadline=None)
@given(bits=st.sampled_from([3, 4, 8]), hd=st.sampled_from([4, 5, 6]),
       walk=ops, seed=st.integers(0, 2**16 - 1))
def test_random_walk_matches_dense_oracle(bits, hd, walk, seed):
    """Any interleaving of ragged appends and slot resets leaves pages
    that decode bit-exactly to the quantize->dequantize mirror."""
    run_kv_walk(bits, hd, walk, seed)


@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from([3, 4]), seed=st.integers(0, 2**16 - 1),
       n=st.integers(1, 8))
def test_full_fill_then_evict_is_pristine(bits, seed, n):
    """Filling to capacity then evicting every slot returns a cache
    indistinguishable from a fresh one (no residue in padding bits)."""
    walk = [("append", list(range(N_SLOTS)))] * n + \
        [("reset", s) for s in range(N_SLOTS)]
    kvc = run_kv_walk(bits, 5, walk, seed)
    assert not np.asarray(kvc.pages).any()
