"""DSE sweep coverage: monotone lane-cap behavior + cache transparency."""
from repro.core.dse import sweep_max_lanes, sweep_widths
from repro.core.iris import LayoutCache
from repro.core.task import INV_HELMHOLTZ, matmul_problem

LANE_CAPS = [1, 2, 3, 4, None]


def test_sweep_max_lanes_monotone_efficiency():
    """Paper Table 6: widening the delta/W cap can only help density.

    Efficiency is nondecreasing and C_max nonincreasing in the lane cap;
    the FIFO cost (decode resources) is what the knob trades away.
    """
    rows = sweep_max_lanes(INV_HELMHOLTZ, LANE_CAPS, cache=LayoutCache())
    assert [r["max_lanes"] for r in rows] == LANE_CAPS
    for lo, hi in zip(rows, rows[1:]):
        assert hi["eff"] >= lo["eff"] - 1e-12
        assert hi["cmax"] <= lo["cmax"]
        assert hi["lmax"] <= lo["lmax"]
    # the uncapped column reproduces the paper's Helmholtz numbers
    assert rows[-1]["cmax"] == 696
    assert rows[0]["fifo"] == 0          # one lane -> no staging at all


def test_sweep_max_lanes_cached_equals_uncached():
    cached = sweep_max_lanes(INV_HELMHOLTZ, LANE_CAPS, cache=LayoutCache())
    uncached = sweep_max_lanes(INV_HELMHOLTZ, LANE_CAPS, cache=None)
    assert cached == uncached
    # a second pass over a warm cache must also be identical
    cache = LayoutCache()
    first = sweep_max_lanes(INV_HELMHOLTZ, LANE_CAPS, cache=cache)
    warm = sweep_max_lanes(INV_HELMHOLTZ, LANE_CAPS, cache=cache)
    assert warm == first
    assert cache.hits >= len(LANE_CAPS)


def test_sweep_max_lanes_reuses_cache_across_sweeps():
    cache = LayoutCache()
    sweep_max_lanes(INV_HELMHOLTZ, LANE_CAPS, cache=cache)
    runs_first = cache.misses
    sweep_max_lanes(INV_HELMHOLTZ, [2, 4, None], cache=cache)
    assert cache.misses == runs_first    # overlapping caps: zero new runs


def test_sweep_widths_iris_beats_naive():
    pairs = [(64, 64), (33, 31), (30, 19)]
    rows = sweep_widths(matmul_problem, pairs, cache=LayoutCache())
    assert [r["widths"] for r in rows] == pairs
    for r in rows:
        assert r["iris_eff"] >= r["naive_eff"] - 1e-12
        assert r["iris_cmax"] <= r["naive_cmax"]
        assert 0 < r["iris_eff"] <= 1


def test_sweep_widths_cached_equals_uncached():
    pairs = [(64, 64), (33, 31)]
    assert sweep_widths(matmul_problem, pairs, cache=LayoutCache()) \
        == sweep_widths(matmul_problem, pairs, cache=None)
