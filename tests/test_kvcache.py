"""repro.kvcache: Iris-planned packed KV-cache streams.

Covers the subsystem end to end: planning (sequence-length-independent
signature, cache-hit-on-reuse, appends never re-plan), the masked-RMW
append path against the quantize/dequantize oracle, the stream-direct
attention kernel's bit identity with the dense decode path, the numpy
host oracle, the ``kvcache`` analysis pass, and the packed-checkpoint
KV round trip gated by ``python -m repro.analysis ckpt``.
"""
import json
import pathlib

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.kvcache import (  # noqa: E402
    PackedKVCache,
    dequantize_kv,
    kv_bundle,
    plan_kv_stack,
    quantize_kv,
)


def tiny_cfg(**kw):
    base = dict(n_layers=2, d_model=40, n_heads=4, n_kv_heads=2, d_ff=64,
                vocab_size=64)
    base.update(kw)
    return get_config("smollm-135m").reduced(**base)


def rand_kv(rng, n_slots, hkv, hd):
    k = jnp.asarray(rng.normal(size=(n_slots, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n_slots, hkv, hd)), jnp.float32)
    return k, v


def fill(kvc, rng, steps, *, layers=None, slots=None):
    """Append ``steps`` tokens to every slot in ``slots`` on ``layers``."""
    man = kvc.manifest
    slots = np.arange(man.n_slots) if slots is None else np.asarray(slots)
    layers = range(man.n_layers) if layers is None else layers
    sl = jnp.asarray(slots, jnp.int32)
    for t in range(steps):
        pos = jnp.full((len(slots),), t, jnp.int32)
        for layer in layers:
            k, v = rand_kv(rng, len(slots), man.n_kv_heads, man.head_dim)
            kvc = kvc.append(k, v, pos, sl, layer=layer)
    return kvc


# ----------------------------------------------------------------------
# planning: paged growth model
# ----------------------------------------------------------------------
def test_kv_bundle_validates():
    cfg = tiny_cfg()
    with pytest.raises(ValueError, match="bits"):
        kv_bundle(cfg, 1, 8)
    with pytest.raises(ValueError, match="page_tokens"):
        kv_bundle(cfg, 4, 0)
    names = [b.name for b in kv_bundle(cfg, 4, 8)]
    assert names == ["kv/k", "kv/k_scales", "kv/v", "kv/v_scales"]


def test_signature_is_sequence_length_independent():
    """The scheduling instance depends on the page, not the sequence:
    caches sized for different max_seq share one layout signature."""
    cfg = tiny_cfg()
    a = PackedKVCache.create(cfg, bits=3, page_tokens=4, n_slots=1,
                             max_seq=8)
    b = PackedKVCache.create(cfg, bits=3, page_tokens=4, n_slots=5,
                             max_seq=64)
    assert a.manifest.signature == b.manifest.signature
    assert a.n_pages == 2 and b.n_pages == 16


def test_create_hits_layout_cache_on_reuse():
    from repro.core.iris import LayoutCache

    cfg = tiny_cfg()
    lc = LayoutCache()
    a = PackedKVCache.create(cfg, bits=4, page_tokens=4, n_slots=2,
                             max_seq=8, cache=lc)
    assert a.plan_stats == {"scheduler_runs": 1, "cache_hits": 1}
    b = PackedKVCache.create(cfg, bits=4, page_tokens=4, n_slots=3,
                             max_seq=32, cache=lc)
    assert b.plan_stats["scheduler_runs"] == 0
    assert b.plan_stats["cache_hits"] == 2


def test_appends_never_replan():
    """The acceptance gate: growing the cache by appending tokens must
    not touch the scheduler — the planner miss counter stays frozen."""
    from repro.core.iris import LayoutCache

    cfg = tiny_cfg()
    lc = LayoutCache()
    stack = plan_kv_stack(cfg, bits=3, page_tokens=4, cache=lc)
    assert stack.scheduler_runs == 1
    kvc = PackedKVCache.create(cfg, bits=3, page_tokens=4, n_slots=2,
                               max_seq=16, cache=lc)
    misses0, hits0 = lc.misses, lc.hits
    kvc = fill(kvc, np.random.default_rng(0), 9)      # crosses 3 pages
    kvc.dense_kv(0)
    kvc.stream_tables()
    assert lc.misses == misses0, "an append re-planned the layout"
    assert lc.hits == hits0


# ----------------------------------------------------------------------
# append path vs the quantize/dequantize oracle
# ----------------------------------------------------------------------
@pytest.mark.parametrize("bits,hd", [(3, 5), (4, 6), (8, 4)])
def test_append_bit_exact_vs_quant_oracle(bits, hd):
    """Round-tripping through packed pages reproduces exactly the
    quantize -> dequantize values (non-power-of-two head dims too)."""
    cfg = tiny_cfg(n_heads=4, n_kv_heads=2, head_dim=hd,
                   d_model=4 * hd)
    rng = np.random.default_rng(bits)
    kvc = PackedKVCache.create(cfg, bits=bits, page_tokens=4, n_slots=3,
                               max_seq=12)
    want_k = np.zeros((3, 12, 2, hd), np.float32)
    want_v = np.zeros((3, 12, 2, hd), np.float32)
    for t in range(7):
        k, v = rand_kv(rng, 3, 2, hd)
        pos = jnp.full((3,), t, jnp.int32)
        kvc = kvc.append(k, v, pos, jnp.arange(3), layer=1)
        want_k[:, t] = np.asarray(dequantize_kv(*quantize_kv(k, bits),
                                                bits))
        want_v[:, t] = np.asarray(dequantize_kv(*quantize_kv(v, bits),
                                                bits))
    kf, vf = kvc.dense_kv(1)
    assert (np.asarray(kf)[:, :7] == want_k[:, :7]).all()
    assert (np.asarray(vf)[:, :7] == want_v[:, :7]).all()
    # untouched layer stays zero pages
    assert not np.asarray(kvc.pages)[0].any()


def test_ragged_append_and_reset():
    """Interleaved ragged appends land in the right slots; reset/evict
    zero exactly the chosen slot's pages."""
    cfg = tiny_cfg()
    hd = cfg.head_dim
    rng = np.random.default_rng(7)
    kvc = PackedKVCache.create(cfg, bits=4, page_tokens=4, n_slots=3,
                               max_seq=8)
    # slot 1 gets tokens 0..2, slots 0/2 get token 0 only
    k, v = rand_kv(rng, 3, 2, hd)
    kvc = kvc.append(k, v, jnp.zeros(3, jnp.int32), jnp.arange(3), layer=0)
    for t in (1, 2):
        k1, v1 = rand_kv(rng, 1, 2, hd)
        kvc = kvc.append(k1, v1, jnp.asarray([t]), jnp.asarray([1]),
                         layer=0)
    kf, _ = kvc.dense_kv(0)
    assert np.asarray(kf)[1, 2].any() and not np.asarray(kf)[0, 2].any()
    pages_before = np.asarray(kvc.pages).copy()
    kvc2 = kvc.reset(1)
    p2 = np.asarray(kvc2.pages)
    assert not p2[:, 1].any()
    assert (p2[:, [0, 2]] == pages_before[:, [0, 2]]).all()
    kvc3 = kvc.evict(jnp.asarray([0, 2]))
    p3 = np.asarray(kvc3.pages)
    assert not p3[:, 0].any() and not p3[:, 2].any()
    assert (p3[:, 1] == pages_before[:, 1]).all()


def test_append_is_idempotent_overwrite():
    """Re-appending at an occupied position is a clean overwrite (the
    masked RMW leaves no residue of the old token)."""
    cfg = tiny_cfg()
    rng = np.random.default_rng(11)
    kvc = PackedKVCache.create(cfg, bits=3, page_tokens=4, n_slots=1,
                               max_seq=4)
    k0, v0 = rand_kv(rng, 1, 2, cfg.head_dim)
    k1, v1 = rand_kv(rng, 1, 2, cfg.head_dim)
    a = kvc.append(k1, v1, jnp.asarray([0]), jnp.asarray([0]), layer=0)
    b = kvc.append(k0, v0, jnp.asarray([0]), jnp.asarray([0]), layer=0)
    b = b.append(k1, v1, jnp.asarray([0]), jnp.asarray([0]), layer=0)
    assert (np.asarray(a.pages) == np.asarray(b.pages)).all()


# ----------------------------------------------------------------------
# stream attention: bit identity with the dense decode path
# ----------------------------------------------------------------------
@pytest.mark.parametrize("bits,heads,hd", [(3, (4, 2), 6), (4, (4, 4), 5),
                                           (8, (6, 2), 4)])
def test_stream_attention_bit_identical_to_dense(bits, heads, hd):
    from repro.models.attention import decode_attention
    from repro.kvcache.kernels import stream_attention_cache

    h, hkv = heads
    cfg = tiny_cfg(n_heads=h, n_kv_heads=hkv, head_dim=hd, d_model=h * hd)
    rng = np.random.default_rng(bits + hd)
    kvc = PackedKVCache.create(cfg, bits=bits, page_tokens=4, n_slots=3,
                               max_seq=12)
    kvc = fill(kvc, rng, 6, layers=[0])
    pos = jnp.asarray([5, 2, 0])                 # ragged clocks
    slots = jnp.arange(3)
    q = jnp.asarray(rng.normal(size=(3, 1, h, hd)), jnp.bfloat16)
    got = stream_attention_cache(kvc, q, pos, slots, layer=0)
    want = decode_attention(q, *kvc.dense_kv(0, slots), pos)
    assert got.dtype == want.dtype
    assert (np.asarray(got).view(np.uint16) ==
            np.asarray(want).view(np.uint16)).all()


def test_stream_attention_ref_oracle():
    """The numpy host oracle: extraction/dequant is *bit* exact against
    dense_kv; the full attention output is allclose."""
    from repro.kernels.ref import stream_attention_ref, stream_kv_ref
    from repro.kvcache.kernels import stream_attention_cache

    cfg = tiny_cfg()
    hd = cfg.head_dim
    rng = np.random.default_rng(21)
    kvc = PackedKVCache.create(cfg, bits=4, page_tokens=4, n_slots=2,
                               max_seq=8)
    kvc = fill(kvc, rng, 5, layers=[0])
    slots = jnp.arange(2)
    tabs = kvc.stream_tables()
    words = np.asarray(kvc.slot_words(0, slots))
    kf, vf = kvc.dense_kv(0, slots)
    for i in range(2):
        kr, vr = stream_kv_ref(words[i], tabs, bits=4)
        assert (kr == np.asarray(kf)[i]).all()
        assert (vr == np.asarray(vf)[i]).all()
    pos = jnp.asarray([4, 4])
    q = jnp.asarray(rng.normal(size=(2, 1, cfg.n_heads, hd)), jnp.bfloat16)
    got = np.asarray(stream_attention_cache(kvc, q, pos, slots, layer=0),
                     np.float32)
    ref = stream_attention_ref(words, np.asarray(q, np.float32),
                               np.asarray(pos), tabs, bits=4)
    assert np.allclose(got, ref, atol=2e-2)


def test_packed_decode_step_stream_vs_dense_oracle():
    """Model-level gate: kv='packed' with the stream kernel produces
    logits bit-identical to the dense-oracle attention over the same
    packed pages, and ragged slot batches match the full batch."""
    from repro import api
    from repro.models.model import Model
    from repro.models.quantized import packed_decode_step
    from repro.quant import QuantSpec

    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab_size=128)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    tree = api.pack_tree(cfg, params, QuantSpec(bits=4, group_size=32),
                         m=512)

    def run(kv_attention):
        state = model.init_decode_state(2, 16)
        state["packed_kv"] = PackedKVCache.create(
            cfg, bits=4, page_tokens=4, n_slots=2, max_seq=16)
        outs = []
        for tok in ([5, 9], [7, 3]):
            logits, state = packed_decode_step(
                cfg, tree, state, jnp.asarray(tok, jnp.int32),
                interpret=True, kv="packed", kv_attention=kv_attention)
            outs.append(np.asarray(logits))
        return outs, state

    a, st_a = run("stream")
    b, _ = run("dense")
    for x, y in zip(a, b):
        assert (x == y).all()
    assert np.asarray(st_a["pos"]).tolist() == [2, 2]
    # ragged: stepping only slot 1 matches the full-batch row
    state = model.init_decode_state(2, 16)
    state["packed_kv"] = PackedKVCache.create(
        cfg, bits=4, page_tokens=4, n_slots=2, max_seq=16)
    full, _ = packed_decode_step(cfg, tree, state,
                                 jnp.asarray([5, 9], jnp.int32),
                                 interpret=True, kv="packed")
    ragged, st = packed_decode_step(cfg, tree, state,
                                    jnp.asarray([9], jnp.int32),
                                    interpret=True, kv="packed",
                                    slot_ids=jnp.asarray([1], jnp.int32))
    assert (np.asarray(full)[[1]] == np.asarray(ragged)).all()
    assert np.asarray(st["pos"]).tolist() == [0, 1]


def test_packed_decode_step_requires_kv_state():
    from repro.models.quantized import packed_decode_step

    with pytest.raises(ValueError, match="kv"):
        packed_decode_step(None, None, {}, None, kv="nonsense")


# ----------------------------------------------------------------------
# pytree / jit compatibility
# ----------------------------------------------------------------------
def test_kvcache_is_a_pytree():
    cfg = tiny_cfg()
    rng = np.random.default_rng(5)
    kvc = fill(PackedKVCache.create(cfg, bits=4, page_tokens=4, n_slots=2,
                                    max_seq=8), rng, 3)
    leaves, treedef = jax.tree_util.tree_flatten(kvc)
    assert len(leaves) == 1 and leaves[0] is kvc.pages
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.manifest == kvc.manifest
    assert back.provenance == "pytree"

    @jax.jit
    def through(c):
        return c

    out = through(kvc)
    assert (np.asarray(out.pages) == np.asarray(kvc.pages)).all()
    placed = jax.device_put(kvc)
    assert (np.asarray(placed.pages) == np.asarray(kvc.pages)).all()


# ----------------------------------------------------------------------
# analysis + checkpoint gates
# ----------------------------------------------------------------------
def test_verify_kvcache_healthy_and_corrupted():
    from repro.analysis import stream_sha256
    from repro.analysis.passes import AnalysisContext, _expected_write_mask

    cfg = tiny_cfg()
    rng = np.random.default_rng(2)
    kvc = fill(PackedKVCache.create(cfg, bits=3, page_tokens=4, n_slots=2,
                                    max_seq=8), rng, 5, layers=[0])
    digest = stream_sha256(kvc.host_pages())
    rep = kvc.verify(pages_digest=digest)
    assert rep.ok, rep.render()
    assert "kvcache" in rep.passes
    # payload bit flip -> digest catches it
    bad = kvc._replace_pages(kvc.pages.at[0, 0, 0, 0, 0].set(
        kvc.pages[0, 0, 0, 0, 0] ^ jnp.uint32(1 << 3)))
    r = bad.verify(pages_digest=digest)
    assert [f.rule_id for f in r.errors] == ["kvcache/pages-digest"]
    # a bit outside the payload mask -> stray-bits catches it (the
    # masked append path can never produce one)
    exp = _expected_write_mask(AnalysisContext(program=kvc.program()),
                               kvc.manifest.logical())
    zr, zq = np.argwhere(exp != np.uint32(0xFFFFFFFF))[-1]
    free = int(np.flatnonzero(
        ~((exp[zr, zq] >> np.arange(32)) & 1).astype(bool))[0])
    bad2 = kvc._replace_pages(kvc.pages.at[0, 0, 0, zr, zq].set(
        kvc.pages[0, 0, 0, zr, zq] | jnp.uint32(1 << free)))
    assert any(f.rule_id == "kvcache/stray-bits"
               for f in bad2.verify().errors)


def test_checkpoint_kv_round_trip(tmp_path):
    from repro import api
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.models.model import Model
    from repro.quant import QuantSpec

    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab_size=128)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    tree = api.pack_tree(cfg, params, QuantSpec(bits=4, group_size=32),
                         m=512)
    rng = np.random.default_rng(9)
    kvc = fill(PackedKVCache.create(cfg, bits=4, page_tokens=4, n_slots=2,
                                    max_seq=16), rng, 5)
    mgr = CheckpointManager(tmp_path)
    mgr.save_packed(7, tree, kv=kvc)
    rep = mgr.verify_packed(7)
    assert rep.ok, rep.render()
    assert "kvcache" in rep.passes
    kvc2 = mgr.restore_kv(7)
    assert kvc2.provenance == "checkpoint"
    assert (np.asarray(kvc2.pages) == np.asarray(kvc.pages)).all()
    for layer in range(2):
        a, b = kvc.dense_kv(layer), kvc2.dense_kv(layer)
        assert (np.asarray(a[0]) == np.asarray(b[0])).all()
        assert (np.asarray(a[1]) == np.asarray(b[1])).all()
    # pre-KV checkpoints still load, and probe as None
    mgr.save_packed(8, tree)
    assert mgr.restore_kv(8) is None
    pt, _ = mgr.restore_packed(8)
    assert pt.manifest.arch == tree.manifest.arch


def test_analysis_cli_gates_kv_checkpoint(tmp_path, capsys):
    """``python -m repro.analysis ckpt`` must pass a clean KV snapshot
    and fail a corrupted one (exit code is the CI gate)."""
    import repro.analysis.__main__ as cli
    from repro import api
    from repro.analysis import AnalysisError
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.models.model import Model
    from repro.quant import QuantSpec

    cfg = get_config("smollm-135m").reduced(
        n_layers=1, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab_size=128)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    tree = api.pack_tree(cfg, params, QuantSpec(bits=4, group_size=32),
                         m=512)
    rng = np.random.default_rng(13)
    kvc = fill(PackedKVCache.create(cfg, bits=4, page_tokens=4, n_slots=1,
                                    max_seq=8), rng, 3)
    mgr = CheckpointManager(tmp_path)
    d = pathlib.Path(mgr.save_packed(1, tree, kv=kvc))
    assert cli.main(["ckpt", str(tmp_path), "--step", "1"]) == 0
    # flip one page bit on disk
    man = json.loads((d / "manifest.json").read_text())
    for meta in man["leaves"]:
        arr = np.load(d / meta["file"])
        if arr.dtype == np.uint32 and arr.ndim == 5:
            arr[0, 0, 0, 0, 0] ^= np.uint32(1)
            np.save(d / meta["file"], arr)
            break
    assert cli.main(["ckpt", str(tmp_path), "--step", "1"]) == 1
    with pytest.raises(AnalysisError, match="kvcache/pages-digest"):
        mgr.restore_kv(1)
    capsys.readouterr()


# ----------------------------------------------------------------------
# deterministic random-walk subset of the property suite (always runs;
# the hypothesis version lives in test_kvcache_property.py)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("bits,hd,seed", [(3, 5, 0), (4, 6, 1), (8, 4, 2)])
def test_random_walk_matches_dense_oracle(bits, hd, seed):
    from conftest import run_kv_walk

    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(12):
        if rng.random() < 0.25:
            ops.append(("reset", int(rng.integers(0, 3))))
        else:
            ops.append(("append", sorted(
                set(int(x) for x in rng.integers(0, 3, size=2)))))
    run_kv_walk(bits, hd, ops, seed)
