"""Fused device pack kernel: bit-identity with the host pack paths.

The contract is absolute: ``pack_layout_fused`` returns byte-for-byte
the buffer ``pack_compiled`` (and transitively the legacy
``pack_arrays``) produces, for every granularity, straddle pattern, and
host-width fallback.  Round-trips close the loop through the fused
decode kernel.
"""
import warnings

import numpy as np
import pytest

from repro.core.codegen import pack_arrays, random_codes
from repro.core.exec_plan import (
    lower_exec,
    pack_compiled,
    pack_kernel_tables,
)
from repro.core.iris import schedule
from repro.core.task import PAPER_EXAMPLE, make_problem
from repro.kernels.layout_decode import decode_layout_fused
from repro.kernels.layout_pack import pack_layout_fused


def _identical(problem, *, elem_widths=None, seed=0, codes=None):
    lay = schedule(problem, cache=None)
    if codes is None:
        codes = random_codes(problem, seed=seed)
    prog = lower_exec(lay, elem_widths)
    ref = pack_compiled(lay, codes, program=prog)
    out = pack_layout_fused(lay, codes, program=prog)
    assert out.dtype == ref.dtype and out.shape == ref.shape
    assert np.array_equal(ref, out)
    return lay, prog, codes, ref


def test_paper_example_identical():
    lay, _prog, codes, buf = _identical(PAPER_EXAMPLE)
    # and against the legacy per-slot packer
    assert np.array_equal(buf, pack_arrays(lay, codes))


def test_word_straddling_widths_identical():
    # odd widths force contributions that straddle u32 word boundaries
    p = make_problem(96, [("a", 3, 300, 4), ("b", 7, 150, 9),
                          ("c", 11, 90, 2), ("d", 30, 41, 7)])
    _identical(p)


def test_randomized_small_problems_identical():
    rng = np.random.default_rng(0)
    for trial in range(12):
        m = int(rng.choice([8, 32, 64, 128]))
        n = int(rng.integers(1, 6))
        specs = [(f"a{i}", int(rng.integers(1, min(m, 17))),
                  int(rng.integers(1, 200)), int(rng.integers(0, 30)))
                 for i in range(n)]
        _identical(make_problem(m, specs), seed=trial)


def test_element_granularity_identical():
    # sub-element pieces: 24-bit elements lowered as 8-bit pieces
    p = make_problem(64, [("x", 24, 50, 3), ("y", 8, 120, 6)])
    lay = schedule(p, cache=None)
    prog = lower_exec(lay, elem_widths=(8, 8))
    rng = np.random.default_rng(1)
    data = {"x": rng.integers(0, 1 << 8, prog.piece_depths[0],
                              dtype=np.uint64),
            "y": rng.integers(0, 1 << 8, prog.piece_depths[1],
                              dtype=np.uint64)}
    ref = pack_compiled(lay, data, program=prog)
    out = pack_layout_fused(lay, data, program=prog)
    assert np.array_equal(ref, out)


def test_host_width_fallback_identical_and_warns():
    p = make_problem(128, [("wide", 48, 40, 5), ("narrow", 8, 100, 5)])
    lay = schedule(p, cache=None)
    codes = random_codes(p, seed=2)
    ref = pack_compiled(lay, codes)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        from repro.kernels import layout_pack

        layout_pack.reset_host_fallback_warnings()
        out = pack_layout_fused(lay, codes)
    assert np.array_equal(ref, out)
    assert any("host" in str(x.message) for x in w)
    # warned once per (layout, array): a second pack stays quiet
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        pack_layout_fused(lay, codes)
    assert not any("host" in str(x.message) for x in w2)


def test_all_host_width_problem():
    p = make_problem(128, [("w1", 40, 30, 2), ("w2", 48, 25, 5)])
    lay = schedule(p, cache=None)
    codes = random_codes(p, seed=3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        out = pack_layout_fused(lay, codes)
    assert np.array_equal(pack_compiled(lay, codes), out)


def test_roundtrip_through_fused_decode():
    p = make_problem(64, [("a", 5, 200, 4), ("b", 12, 80, 8)])
    lay = schedule(p, cache=None)
    codes = random_codes(p, seed=4)
    buf = pack_layout_fused(lay, codes)
    back = decode_layout_fused(lay, buf)
    for k, v in codes.items():
        assert np.array_equal(np.asarray(back[k]).astype(np.uint64), v)


def test_input_validation_mirrors_pack_compiled():
    lay = schedule(PAPER_EXAMPLE, cache=None)
    codes = random_codes(PAPER_EXAMPLE, seed=0)
    missing = dict(codes)
    name = next(iter(missing))
    del missing[name]
    with pytest.raises(KeyError):
        pack_layout_fused(lay, missing)
    short = dict(codes)
    short[name] = codes[name][:-1]
    with pytest.raises(ValueError):
        pack_layout_fused(lay, short)
    over = dict(codes)
    width = next(a.width for a in PAPER_EXAMPLE.arrays if a.name == name)
    if width < 64:
        over[name] = codes[name] | np.uint64(1 << width)
        with pytest.raises(ValueError):
            pack_layout_fused(lay, over)


def test_pack_tables_memoized_and_jit_reused():
    p = make_problem(32, [("a", 4, 100, 3), ("b", 6, 60, 7)])
    lay = schedule(p, cache=None)
    prog = lower_exec(lay)
    t1 = pack_kernel_tables(prog)
    t2 = pack_kernel_tables(prog)
    assert t1 is t2
    codes = random_codes(p, seed=5)
    pack_layout_fused(lay, codes, program=prog)
    fn1 = prog.jit_cache.get(("pack", 4096, True))
    pack_layout_fused(lay, codes, program=prog)
    assert prog.jit_cache.get(("pack", 4096, True)) is fn1
    # a rebound layout (cache hit) shares the program and hence the trace
    rebound = lay.rebind(make_problem(
        32, [("x", 4, 100, 3), ("y", 6, 60, 7)]))
    assert lower_exec(rebound) is prog


def test_api_plan_pack_backend():
    from repro import api

    pl = api.plan(PAPER_EXAMPLE, cache=None)
    codes = random_codes(PAPER_EXAMPLE, seed=6)
    host = pl.pack(codes)
    dev = pl.pack(codes, backend="pallas")
    assert np.array_equal(host, dev)
    with pytest.raises(NotImplementedError):
        pl.pack(codes, backend="no-such-backend")


def test_ops_reexport():
    from repro.kernels import ops

    assert ops.pack_layout_fused is pack_layout_fused


def test_tile_rows_do_not_change_bits():
    p = make_problem(64, [("a", 3, 500, 4), ("b", 9, 200, 11)])
    lay = schedule(p, cache=None)
    codes = random_codes(p, seed=7)
    ref = pack_compiled(lay, codes)
    for tile in (8, 64, 4096):
        out = pack_layout_fused(lay, codes, tile_rows=tile)
        assert np.array_equal(ref, out), tile
