"""Per-architecture smoke tests: reduced config, one forward + train-grad +
decode step on CPU, asserting output shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import Model

B, S = 2, 32


def _reduced_model(arch):
    cfg = get_config(arch).reduced()
    return Model(cfg, remat="none"), cfg


def _batch(cfg, key):
    kt, kl, kf = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
    }
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(
            kf, (B, cfg.encoder.n_ctx, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    model, cfg = _reduced_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux, _ = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_and_grad_finite(arch):
    model, cfg = _reduced_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert flat, "no grads produced"
    for g in flat:
        assert np.isfinite(np.asarray(g, dtype=np.float32)).all()
    # loss should be near log(V) for random init
    assert 0.1 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    model, cfg = _reduced_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    state = model.init_decode_state(B, max_seq=64)
    cross_kv = None
    if cfg.encoder is not None:
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder.n_ctx, cfg.d_model))
        memory = jax.jit(model.encode)(params, frames)
        cross_kv = model.precompute_cross_kv(params, memory)
    step = jax.jit(model.decode_step)
    tokens = jnp.zeros((B,), jnp.int32)
    for i in range(3):
        logits, state = step(params, state, tokens, cross_kv)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert (np.asarray(state["pos"]) == i + 1).all()
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-3b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must agree with the parallel forward pass.

    MoE capacity is raised so no tokens drop (capacity-based dispatch
    legitimately differs between batch sizes otherwise) and the check runs
    in float32 — in bf16 the two mathematically identical paths diverge
    measurably after ~16 layers (verified: f32 agreement is ~3e-5)."""
    _, cfg = _reduced_model(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 8), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    logits_par, _, _ = jax.jit(model.forward)(params, batch)
    state = model.init_decode_state(B, max_seq=16)
    step = jax.jit(model.decode_step)
    outs = []
    for i in range(8):
        lg, state = step(params, state, toks[:, i], None)
        outs.append(lg)
    logits_seq = jnp.stack(outs, axis=1)
    # MoE models: top-k routing is discrete, so ~1e-6 fusion-order noise
    # can flip near-tie expert choices and bump a few logits by ~4e-3;
    # dense/ssm models agree to ~3e-5 (isolated mixers agree to ~2e-6).
    atol = 2e-2 if cfg.moe is not None else 1e-3
    np.testing.assert_allclose(
        np.asarray(logits_seq, np.float32),
        np.asarray(logits_par, np.float32), rtol=1e-3, atol=atol)


def test_moe_capacity_dispatch_matches_reference():
    """Scatter-dispatch MoE == dense oracle when capacity is ample."""
    from repro.models.moe import apply_moe, apply_moe_reference, init_moe
    cfg = get_config("arctic-480b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y, aux = apply_moe(cfg, p, x)
    y_ref = apply_moe_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)
    assert np.isfinite(float(aux))


def test_param_counts_match_reduced_tree():
    """ModelConfig.param_count ~ actual init tree size (reduced configs)."""
    for arch in ("smollm-135m", "moonshot-v1-16b-a3b"):
        model, cfg = _reduced_model(arch)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        approx = cfg.param_count()
        assert 0.5 * approx < actual < 2.0 * approx, (arch, actual, approx)
