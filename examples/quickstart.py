"""Quickstart: the Iris layout pipeline end to end in ~60 seconds.

1. Solve the paper's §4 worked example under every registered layout
   strategy through the `repro.api` façade and print the metrics.
2. Pack real data into the Iris layout and decode it through both
   registered decode backends (numpy oracle + Pallas kernel in
   interpret mode), asserting bit-for-bit agreement.
3. Train a tiny LM for a few steps with the full fault-tolerant runtime.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro import api


def main() -> None:
    # ------------------------------------------------------------------
    print("=== 1. Paper §4 example (every registered strategy) ===")
    for name in api.strategies():
        m = api.plan(api.PAPER_EXAMPLE, name).metrics
        print(f"{name:12s} C_max={m.c_max:3d}  L_max={m.l_max:3d}  "
              f"B_eff={m.efficiency:.1%}")
    pl = api.plan(api.PAPER_EXAMPLE).validate()
    print("\nIris layout (rows = bus cycles, letters = arrays):")
    print(pl.render())

    # ------------------------------------------------------------------
    print("\n=== 2. Pack + decode roundtrip (numpy and pallas backends) ===")
    codes = api.random_codes(pl.problem, seed=42)
    buf = pl.pack(codes)
    print(f"packed buffer: {buf.shape[0]} cycles x {buf.shape[1]} bytes")
    outs = {b: pl.decode(buf, backend=b) for b in ("numpy", "pallas")}
    for name, want in codes.items():
        for backend, out in outs.items():
            assert np.array_equal(out[name], want), (backend, name)
    print("numpy == pallas == original data for all arrays  [OK]")

    # ------------------------------------------------------------------
    print("\n=== 3. Tiny fault-tolerant training run ===")
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLMPipeline
    from repro.launch.steps import build_train_step, init_train_state
    from repro.optim.adamw import AdamWConfig
    from repro.runtime.train_loop import TrainLoopConfig, run_training

    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
        vocab_size=64, head_dim=32)
    step_fn = jax.jit(build_train_step(
        cfg, AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60)))
    pipe = SyntheticLMPipeline(64, 32, 4, seed=0)
    with tempfile.TemporaryDirectory() as ckpt:
        rep = run_training(
            step_fn, lambda: init_train_state(cfg, jax.random.PRNGKey(0)),
            pipe, ckpt, TrainLoopConfig(total_steps=60, ckpt_interval=20))
    first = sum(rep.losses[:5]) / 5
    last = sum(rep.losses[-5:]) / 5
    print(f"loss (5-step mean): {first:.3f} -> {last:.3f} "
          f"over {rep.steps_run} steps  "
          f"[{'OK' if last < first else 'noisy'}]")


if __name__ == "__main__":
    main()
