"""Quickstart: the Iris layout algorithm end to end in ~60 seconds.

1. Solve the paper's §4 worked example and print the layouts.
2. Pack real data into the Iris layout and decode it with the Pallas
   kernel (interpret mode on CPU).
3. Train a tiny LM for a few steps with the full fault-tolerant runtime.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro.core.baselines import homogeneous_layout, naive_layout
from repro.core.codegen import pack_arrays, random_codes
from repro.core.iris import schedule
from repro.core.task import PAPER_EXAMPLE
from repro.kernels.ops import decode_layout


def main() -> None:
    # ------------------------------------------------------------------
    print("=== 1. Paper §4 example ===")
    p = PAPER_EXAMPLE
    for name, fn in (("naive (Fig 3)", naive_layout),
                     ("homogeneous (Fig 4)", homogeneous_layout),
                     ("iris (Fig 5)", schedule)):
        m = fn(p).metrics()
        print(f"{name:22s} C_max={m.c_max:3d}  L_max={m.l_max:3d}  "
              f"B_eff={m.efficiency:.1%}")
    print("\nIris layout (rows = bus cycles, letters = arrays):")
    print(schedule(p).render())

    # ------------------------------------------------------------------
    print("\n=== 2. Pack + Pallas decode roundtrip ===")
    lay = schedule(p)
    codes = random_codes(p, seed=42)
    buf = pack_arrays(lay, codes)
    print(f"packed buffer: {buf.shape[0]} cycles x {buf.shape[1]} bytes")
    out = decode_layout(lay, buf, interpret=True)
    for name, want in codes.items():
        got = np.asarray(out[name], dtype=np.uint64)
        assert np.array_equal(got, want), name
    print("kernel decode == original data for all arrays  [OK]")

    # ------------------------------------------------------------------
    print("\n=== 3. Tiny fault-tolerant training run ===")
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLMPipeline
    from repro.launch.steps import build_train_step, init_train_state
    from repro.optim.adamw import AdamWConfig
    from repro.runtime.train_loop import TrainLoopConfig, run_training

    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
        vocab_size=64, head_dim=32)
    step_fn = jax.jit(build_train_step(
        cfg, AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60)))
    pipe = SyntheticLMPipeline(64, 32, 4, seed=0)
    with tempfile.TemporaryDirectory() as ckpt:
        rep = run_training(
            step_fn, lambda: init_train_state(cfg, jax.random.PRNGKey(0)),
            pipe, ckpt, TrainLoopConfig(total_steps=60, ckpt_interval=20))
    first = sum(rep.losses[:5]) / 5
    last = sum(rep.losses[-5:]) / 5
    print(f"loss (5-step mean): {first:.3f} -> {last:.3f} "
          f"over {rep.steps_run} steps  "
          f"[{'OK' if last < first else 'noisy'}]")


if __name__ == "__main__":
    main()
