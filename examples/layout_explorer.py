"""Design-space exploration with Iris (paper §1: "rapid design-space
exploration while tuning the width of custom-precision data types").

Everything drives the `repro.api` façade: the per-strategy comparison
iterates the strategy registry, the sweeps run through the shared layout
cache, and the serving-stream DSE reuses the layer-stack planner.

Run:  PYTHONPATH=src python examples/layout_explorer.py [--arch smollm-135m]
"""
import argparse

from repro import api
from repro.configs import get_config
from repro.core.dse import sweep_max_lanes, sweep_widths
from repro.core.packing import serving_stream_report
from repro.quant import QuantSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args()

    print("=== Strategy registry on the §4 example (Figs. 3-5) ===")
    print(f"{'strategy':>12s} {'C_max':>6s} {'L_max':>6s} {'B_eff':>7s}")
    for name, m in api.compare(api.PAPER_EXAMPLE).items():
        print(f"{name:>12s} {m.c_max:>6d} {m.l_max:>6d} "
              f"{m.efficiency:>7.1%}")

    print("\n=== Custom-precision width sweep (paper Table 7 style) ===")
    print(f"{'widths':>12s} {'naive eff':>10s} {'iris eff':>10s} "
          f"{'iris C_max':>10s} {'iris L_max':>10s}")
    for row in sweep_widths(api.matmul_problem, [(64, 64), (48, 40), (33, 31),
                                                 (30, 19), (17, 13)]):
        print(f"{row['widths']!s:>12s} {row['naive_eff']:>10.3f} "
              f"{row['iris_eff']:>10.3f} {row['iris_cmax']:>10d} "
              f"{row['iris_lmax']:>10d}")

    print("\n=== delta/W constraint sweep (paper Table 6 style) ===")
    print(f"{'d/W':>4s} {'eff':>8s} {'L_max':>7s} {'fifo':>8s}")
    for row in sweep_max_lanes(api.INV_HELMHOLTZ, [None, 4, 3, 2, 1]):
        print(f"{str(row['max_lanes']):>4s} {row['eff']:>8.3f} "
              f"{row['lmax']:>7d} {row['fifo']:>8d}")

    print(f"\n=== Serving-stream DSE for {args.arch} ===")
    cfg = get_config(args.arch)
    print(f"{'bits':>4s} {'iris MiB/L':>11s} {'pad MiB/L':>10s} "
          f"{'bf16 MiB/L':>11s} {'B_eff':>7s}")
    for bits in (3, 4, 5, 6, 8):
        r = serving_stream_report(cfg, QuantSpec(bits=bits, group_size=128))
        print(f"{bits:>4d} {r['iris_MiB_per_layer']:>11.2f} "
              f"{r['padded_MiB_per_layer']:>10.2f} "
              f"{r['bf16_MiB_per_layer']:>11.2f} "
              f"{r['iris_efficiency']:>7.4f}")


if __name__ == "__main__":
    main()
