"""End-to-end driver (the paper's kind: serving/data movement): serve a
small LM with batched requests where the decode-step weights are
int-quantized, Iris-organized, and dequantized on load by the Pallas
matmul — dense bf16 weights never exist in memory.

Reports per-token weight-streaming bytes vs the bf16 and padded-int
baselines (the memory-roofline win of the paper's technique), plus the
Iris layout metrics of the per-layer stream bundles.

Run:  PYTHONPATH=src python examples/packed_serving.py [--bits 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import get_config
from repro.models.model import Model
from repro.models.quantized import bytes_per_token_report, packed_decode_step
from repro.quant import QuantSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("smollm-135m").reduced(
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=512, head_dim=64)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    spec = QuantSpec(bits=args.bits, group_size=64)

    print(f"=== Quantize + pack ({args.bits}-bit, model {cfg.name} "
          f"reduced) ===")
    # the one front door: quantize -> plan -> pack, one call, one pytree
    pp = api.pack_tree(cfg, params, spec, m=512)
    print(pp.summary())
    rep = bytes_per_token_report(cfg, pp)
    print(f"weight stream per decode token: packed={rep['packed_MiB']:.2f} "
          f"MiB  padded-int={rep['padded_int_MiB']:.2f} MiB  "
          f"bf16={rep['bf16_MiB']:.2f} MiB")
    print(f"reduction vs bf16: {rep['bf16_MiB']/rep['packed_MiB']:.2f}x")

    print("\n=== Iris stream layout per layer (repro.api façade) ===")
    stack = api.plan_layer_stack(cfg, spec, m=512)
    hom = api.compare(stack.problem, strategies=("homogeneous",))
    print(f"B_eff={stack.b_eff:.4f} "
          f"L_max={stack.plans[0].metrics.l_max} "
          f"(homogeneous: {hom['homogeneous'].l_max}); "
          f"decode units={stack.plans[0].decode_plan.n_units}; "
          f"{stack.n_layers} layers from {stack.scheduler_runs} "
          f"scheduler run(s)")

    print("\n=== Batched generation (packed decode path) ===")
    state = model.init_decode_state(args.batch, max_seq=64)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, args.batch),
                       dtype=jnp.int32)
    outs = [[] for _ in range(args.batch)]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens):
        logits, state = packed_decode_step(cfg, pp, state, toks,
                                           interpret=True)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(args.batch):
            outs[i].append(int(toks[i]))
    dt = time.perf_counter() - t0
    for i, o in enumerate(outs):
        print(f"request {i}: {o}")
    print(f"\n{args.batch * args.new_tokens} tokens in {dt:.1f}s "
          f"(interpret-mode Pallas on CPU; TPU is the lowering target)")

    print("\n=== Packed checkpoint (the HBM stream is the checkpoint) ===")
    import pathlib
    import tempfile

    from repro.checkpoint.checkpoint import CheckpointManager

    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep_n=1)
        path = mgr.save_packed(0, pp)
        pt2, _ = mgr.restore_packed()
        same = all(
            np.array_equal(np.asarray(pp.packed[k]), np.asarray(pt2.packed[k]))
            for k in pp.packed)
        size = sum(f.stat().st_size for f in pathlib.Path(path).iterdir())
        print(f"restore bit-identical={same} layout={pt2.provenance} "
              f"on-disk={size/2**20:.2f} MiB")

    # cross-check against the dense path for the first step
    state2 = model.init_decode_state(args.batch, max_seq=64)
    t = jnp.asarray(rng.integers(0, cfg.vocab_size, args.batch), jnp.int32)
    dlog, _ = jax.jit(model.decode_step)(params, state2, t, None)
    qlog, _ = packed_decode_step(cfg, pp, state2, t, interpret=True)
    agree = float((np.argmax(np.asarray(dlog), -1)
                   == np.argmax(np.asarray(qlog), -1)).mean())
    print(f"top-1 agreement packed vs dense: {agree:.0%}  [OK]")


if __name__ == "__main__":
    main()
