"""Train a language model end to end with the fault-tolerant runtime.

Default preset trains a ~20M-param smollm-family model for 300 steps on
the structured synthetic stream (loss drops well below the unigram
floor).  ``--preset full`` uses the real smollm-135m config (~135M params
— hours on this CPU container; the default preset exercises every code
path at a size the container can finish).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import pathlib

import jax
import numpy as np

import repro
from repro.configs import get_config
from repro.data.pipeline import SyntheticLMPipeline
from repro.launch.steps import build_train_step, init_train_state
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainLoopConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", choices=["small", "full"], default="small")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="artifacts/train_lm_ckpt")
    args = ap.parse_args()

    base = get_config("smollm-135m")
    if args.preset == "full":
        cfg = base
    else:
        cfg = base.reduced(n_layers=6, d_model=384, n_heads=6,
                           n_kv_heads=2, d_ff=1024, vocab_size=2048,
                           head_dim=64, max_seq_len=args.seq_len)
    n_params = cfg.param_count()
    print(f"iris-repro {repro.__version__}")
    print(f"config: {cfg.n_layers}L d={cfg.d_model} "
          f"({n_params/1e6:.1f}M params), seq={args.seq_len}, "
          f"batch={args.batch}, steps={args.steps}")

    step_fn = jax.jit(
        build_train_step(cfg, AdamWConfig(
            lr=3e-3, warmup_steps=20, total_steps=args.steps)),
        donate_argnums=(0,))
    pipe = SyntheticLMPipeline(cfg.vocab_size, args.seq_len, args.batch,
                               seed=0)
    ckpt = pathlib.Path(args.ckpt)
    rep = run_training(
        step_fn, lambda: init_train_state(cfg, jax.random.PRNGKey(0)),
        pipe, str(ckpt),
        TrainLoopConfig(total_steps=args.steps,
                        ckpt_interval=max(10, args.steps // 6),
                        log_interval=10))
    ls = rep.losses
    uniform = float(np.log(cfg.vocab_size))
    print(f"restarts={rep.restarts} stragglers={rep.stragglers} "
          f"resumed_from={rep.resumed_from}")
    if not ls:
        print("nothing to do (already trained to --steps; "
              "use a fresh --ckpt to retrain)")
        return
    print(f"loss: start={ls[0]:.3f}  step50={ls[min(49, len(ls)-1)]:.3f}  "
          f"final={rep.final_loss:.3f}  (uniform={uniform:.3f})")
    tail = float(np.mean(ls[-10:]))
    assert tail < 0.8 * uniform, f"model failed to learn ({tail:.3f})"
    print("loss well below the uniform floor  [OK]")


if __name__ == "__main__":
    main()
